"""Self-test for repro.core.distributed on 8 simulated devices.

Run via: XLA_FLAGS=--xla_force_host_platform_device_count=8 python scripts/distributed_check.py
(tests/test_distributed.py spawns this as a subprocess so the main pytest
process keeps its single-device view.)
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed, hilbert, knn_graph
from repro.core.types import ForestConfig, GraphParams
from repro.data import ann_datasets

from repro.launch.mesh import data_mesh

assert len(jax.devices()) == 8, jax.devices()
mesh = data_mesh(8)

N, D = 4096, 96
cfg = ForestConfig(bits=4, key_bits=192, leaf_size=32)
data = ann_datasets.lowrank_embeddings(N, D, n_clusters=16, r=8, seed=0)
pts = jnp.asarray(data)
lo, hi = jnp.min(pts, axis=0), jnp.max(pts, axis=0)

# --- 1. distributed sample sort == single-device Hilbert sort -------------
pts_sh = jax.device_put(pts, NamedSharding(mesh, P("data", None)))
keys_o, pay_o, n_valid, ovf = distributed.distributed_hilbert_order(
    pts_sh, mesh, cfg, lo, hi
)
assert int(jnp.sum(ovf)) == 0, f"sample-sort overflow: {jnp.sum(ovf)}"
nv = np.asarray(n_valid)
print("per-shard valid counts:", nv, "(balance", nv.max() / nv.mean(), ")")
assert nv.sum() == N

# stitch valid prefixes -> global order
ko = np.asarray(keys_o).reshape(8, -1, keys_o.shape[1])
go = np.asarray(pay_o["gid"]).reshape(8, -1)
got_keys = np.concatenate([ko[r, : nv[r]] for r in range(8)])
got_gids = np.concatenate([go[r, : nv[r]] for r in range(8)])

ref_order, ref_keys = hilbert.hilbert_sort(
    pts, bits=cfg.bits, key_bits=cfg.key_bits, lo=lo, hi=hi
)
np.testing.assert_array_equal(got_keys, np.asarray(ref_keys))
# gids may differ within equal-key ties; keys must match exactly (above);
# check gid sets match per key run by comparing sorted gids overall
assert sorted(got_gids.tolist()) == list(range(N))
print("OK: distributed sample sort matches single-device Hilbert order")

# --- 2. distributed kNN graph recall ≈ single-device ----------------------
params = GraphParams(n_orders=12, k1=32, k2=64, k=10, seed=0)
gt = ann_datasets.exact_knn_graph(data, 10)
ids_d, _, ovf_total = distributed.distributed_knn_graph(
    pts, params, cfg, mesh
)
assert ovf_total == 0, ovf_total
rec_d = ann_datasets.recall_at_k(np.asarray(ids_d), gt)

ids_s, _ = knn_graph.build_knn_graph(pts, params, forest_cfg=cfg)
rec_s = ann_datasets.recall_at_k(np.asarray(ids_s), gt)
print(f"recall distributed={rec_d:.3f} single={rec_s:.3f}")
assert rec_d > rec_s - 0.05, (rec_d, rec_s)
assert rec_d > 0.5, rec_d

# no self edges / duplicates
idn = np.asarray(ids_d)
assert not np.any(idn == np.arange(N)[:, None])

# --- 3. elastic re-mesh restore: save single-layout, restore sharded -------
import tempfile

from repro.checkpoint import restore as ck_restore
from repro.checkpoint import save as ck_save

tree = {
    "w": jnp.asarray(np.arange(64 * 8, dtype=np.float32).reshape(64, 8)),
    "step": jnp.int32(7),
}
with tempfile.TemporaryDirectory() as d:
    ck_save(d, 7, tree)  # written from the trivial single-device layout
    sh = {
        "w": NamedSharding(mesh, P("data", None)),   # new job: 8-way sharded
        "step": NamedSharding(mesh, P()),
    }
    got, _ = ck_restore(d, 7, jax.eval_shape(lambda: tree), shardings=sh)
    assert got["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
print("OK: elastic re-mesh restore (1-device ckpt -> 8-way sharded)")
print("ALL DISTRIBUTED CHECKS PASSED")
