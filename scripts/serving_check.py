"""Self-test for the serving engine on 8 simulated devices.

Run via: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
             python scripts/serving_check.py
(tests/test_engine.py spawns this as a subprocess so the main pytest
process keeps its single-device view; the CI serving job runs it
directly.)

Checks, in order:
  1. Engine-batched search over the sharded (static) layout is bit-equal
     to a direct ``index.search`` over the concatenated batch.
  2. Same bit-equality on the sharded-MUTABLE layout mid-churn (buffered
     rows + sealed generations + tombstones in flight).
  3. Forced background maintenance on the sharded-mutable layout: the
     shadow compacts, concurrent writes replay with identical external
     ids, the swap bumps the epoch, and post-swap engine search is
     bit-equal to a direct search on the swapped index.
  4. Pipelined multi-chunk search on the sharded layout is bit-equal to
     the direct path (double-buffered staging changes timing only).
  5. Engine-routed RetrievalStore: ``serving_engine()`` attachment serves
     kNN-LM lookups, routes appends/deletes, and ``store.compact()``
     becomes an off-path swap.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
).strip()

import threading

import jax
import numpy as np

from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.index import (
    IndexConfig,
    ShardedHilbertIndex,
    ShardedMutableHilbertIndex,
)
from repro.serve import MaintenancePolicy, RetrievalEngine, pipelined_search
from repro.serve.retrieval import RetrievalStore, knn_lm_mix

N, D, Q = 3000, 32, 48
CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0),
    query_chunk=16,
    shards=4,
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


def main() -> None:
    assert jax.device_count() >= 8, jax.devices()
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    data, queries = np.asarray(data), np.asarray(queries)

    # 1. engine batching on the sharded static layout
    static = ShardedHilbertIndex.build(data, CFG)
    direct_i, direct_d = static.search(queries, SP)
    eng = RetrievalEngine(static, SP, max_batch=16)
    cuts = [0, 5, 8, 20, 21, 37, Q]
    tickets = [eng.submit(queries[a:b]) for a, b in zip(cuts[:-1], cuts[1:])]
    while eng.step():
        pass
    got_i = np.concatenate([t.ids for t in tickets])
    got_d = np.concatenate([t.dists for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i))
    np.testing.assert_array_equal(got_d, np.asarray(direct_d))
    assert eng.metrics.counter("batches") < len(tickets)
    print("[1] sharded static: engine batching bit-equal OK")

    # 2. engine batching on the sharded-mutable layout mid-churn
    mut = ShardedMutableHilbertIndex.build(
        data[:2000], CFG, buffer_capacity=256, max_segments=8
    )
    ids0 = mut.insert(data[2000:2600])
    mut.delete(np.asarray(ids0[:100]))
    direct_i, direct_d = mut.search(queries, SP)
    eng2 = RetrievalEngine(mut, SP)
    ids, dists = eng2.search(queries)
    np.testing.assert_array_equal(ids, np.asarray(direct_i))
    np.testing.assert_array_equal(dists, np.asarray(direct_d))
    print("[2] sharded mutable mid-churn: engine search bit-equal OK")

    # 3. forced maintenance: shadow compact + write replay + epoch swap
    old_index = eng2.index
    stop = threading.Event()
    inserted = []

    def writer():
        s = 2600
        while not stop.is_set() and s < N:
            inserted.append((s, eng2.insert(data[s : s + 50])))
            s += 50

    th = threading.Thread(target=writer)
    th.start()
    try:
        assert eng2.maintain_once(force=True)
    finally:
        stop.set()
        th.join()
    assert eng2.epoch == 1 and eng2.index is not old_index
    n_written = sum(i.shape[0] for _, i in inserted)
    stats = eng2.maintenance_stats()
    assert stats["n_live"] == 2500 + n_written, stats
    for s, rid in inserted:
        np.testing.assert_array_equal(
            np.asarray(rid), np.arange(s, s + rid.shape[0])
        )
    ni, nd = eng2.index.search(queries, SP)
    ei, ed = eng2.search(queries)
    np.testing.assert_array_equal(ei, np.asarray(ni))
    np.testing.assert_array_equal(ed, np.asarray(nd))
    print(f"[3] sharded maintenance swap OK ({n_written} rows replayed)")

    # 4. pipelined multi-chunk search, sharded layout
    pi, pd = pipelined_search(static, queries, SP, query_chunk=16)
    di, dd = static.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(dd))
    print("[4] sharded pipelined search bit-equal OK")

    # 5. engine-routed RetrievalStore serving kNN-LM
    vals = np.arange(N, dtype=np.int32) % 97
    store = RetrievalStore.build(data, vals, CFG, shards=4)
    logits = np.asarray(
        np.random.default_rng(0).normal(size=(8, 97)), np.float32
    )
    baseline = np.asarray(
        knn_lm_mix(logits, queries[:8], store, SP, lam=0.3)
    )
    engine = store.serving_engine(
        SP, maintenance=MaintenancePolicy(), start=True
    )
    routed = np.asarray(knn_lm_mix(logits, queries[:8], store, SP, lam=0.3))
    np.testing.assert_array_equal(routed, baseline)
    new_ids = store.append(data[:16], vals[:16])
    assert store.delete(np.asarray(new_ids)) == 16
    store.compact()  # forced off-path swap through the engine
    assert engine.metrics.counter("swaps") == 1
    after = np.asarray(knn_lm_mix(logits, queries[:8], store, SP, lam=0.3))
    engine.stop(drain=True)
    assert after.shape == baseline.shape
    print("[5] engine-routed RetrievalStore + compact-as-swap OK")

    print("ALL SERVING CHECKS PASSED")


if __name__ == "__main__":
    main()
