"""Self-test for the serving engine on 8 simulated devices.

Run via: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
             python scripts/serving_check.py
(tests/test_engine.py spawns this as a subprocess so the main pytest
process keeps its single-device view; the CI serving job runs it
directly.)

Checks, in order:
  1. Engine-batched search over the sharded (static) layout is bit-equal
     to a direct ``index.search`` over the concatenated batch.
  2. Same bit-equality on the sharded-MUTABLE layout mid-churn (buffered
     rows + sealed generations + tombstones in flight).
  3. Forced background maintenance on the sharded-mutable layout: the
     shadow compacts, concurrent writes replay with identical external
     ids, the swap bumps the epoch, and post-swap engine search is
     bit-equal to a direct search on the swapped index.
  4. Pipelined multi-chunk search on the sharded layout is bit-equal to
     the direct path (double-buffered staging changes timing only).
  5. Engine-routed RetrievalStore: ``serving_engine()`` attachment serves
     kNN-LM lookups, routes appends/deletes, and ``store.compact()``
     becomes an off-path swap.
  6. Reader concurrency: serve_threads=2 workers + 3 reader threads + a
     paced writer + a forced swap on the sharded-mutable layout — every
     ticket acked, searches shared the read lock, probe results
     bit-equal to a direct search on the epoch that served them.
  7. Out-of-process compaction on the sharded-mutable layout: the
     compactor child round-trips the 4-shard bundle, the swap timeline
     proves the serve lock was held exclusively ONLY at snapshot + swap,
     and post-swap search is bit-equal to direct.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
).strip()

import threading

import jax
import numpy as np

from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.index import (
    IndexConfig,
    ShardedHilbertIndex,
    ShardedMutableHilbertIndex,
)
from repro.serve import MaintenancePolicy, RetrievalEngine, pipelined_search
from repro.serve.retrieval import RetrievalStore, knn_lm_mix

N, D, Q = 3000, 32, 48
CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0),
    query_chunk=16,
    shards=4,
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


def main() -> None:
    assert jax.device_count() >= 8, jax.devices()
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    data, queries = np.asarray(data), np.asarray(queries)

    # 1. engine batching on the sharded static layout
    static = ShardedHilbertIndex.build(data, CFG)
    direct_i, direct_d = static.search(queries, SP)
    eng = RetrievalEngine(static, SP, max_batch=16)
    cuts = [0, 5, 8, 20, 21, 37, Q]
    tickets = [eng.submit(queries[a:b]) for a, b in zip(cuts[:-1], cuts[1:])]
    while eng.step():
        pass
    got_i = np.concatenate([t.ids for t in tickets])
    got_d = np.concatenate([t.dists for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i))
    np.testing.assert_array_equal(got_d, np.asarray(direct_d))
    assert eng.metrics.counter("batches") < len(tickets)
    print("[1] sharded static: engine batching bit-equal OK")

    # 2. engine batching on the sharded-mutable layout mid-churn
    mut = ShardedMutableHilbertIndex.build(
        data[:2000], CFG, buffer_capacity=256, max_segments=8
    )
    ids0 = mut.insert(data[2000:2600])
    mut.delete(np.asarray(ids0[:100]))
    direct_i, direct_d = mut.search(queries, SP)
    eng2 = RetrievalEngine(mut, SP)
    ids, dists = eng2.search(queries)
    np.testing.assert_array_equal(ids, np.asarray(direct_i))
    np.testing.assert_array_equal(dists, np.asarray(direct_d))
    print("[2] sharded mutable mid-churn: engine search bit-equal OK")

    # 3. forced maintenance: shadow compact + write replay + epoch swap
    old_index = eng2.index
    stop = threading.Event()
    inserted = []

    def writer():
        s = 2600
        while not stop.is_set() and s < N:
            inserted.append((s, eng2.insert(data[s : s + 50])))
            s += 50

    th = threading.Thread(target=writer)
    th.start()
    try:
        assert eng2.maintain_once(force=True)
    finally:
        stop.set()
        th.join()
    assert eng2.epoch == 1 and eng2.index is not old_index
    n_written = sum(i.shape[0] for _, i in inserted)
    stats = eng2.maintenance_stats()
    assert stats["n_live"] == 2500 + n_written, stats
    for s, rid in inserted:
        np.testing.assert_array_equal(
            np.asarray(rid), np.arange(s, s + rid.shape[0])
        )
    ni, nd = eng2.index.search(queries, SP)
    ei, ed = eng2.search(queries)
    np.testing.assert_array_equal(ei, np.asarray(ni))
    np.testing.assert_array_equal(ed, np.asarray(nd))
    print(f"[3] sharded maintenance swap OK ({n_written} rows replayed)")

    # 4. pipelined multi-chunk search, sharded layout
    pi, pd = pipelined_search(static, queries, SP, query_chunk=16)
    di, dd = static.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(dd))
    print("[4] sharded pipelined search bit-equal OK")

    # 5. engine-routed RetrievalStore serving kNN-LM
    vals = np.arange(N, dtype=np.int32) % 97
    store = RetrievalStore.build(data, vals, CFG, shards=4)
    logits = np.asarray(
        np.random.default_rng(0).normal(size=(8, 97)), np.float32
    )
    baseline = np.asarray(
        knn_lm_mix(logits, queries[:8], store, SP, lam=0.3)
    )
    engine = store.serving_engine(
        SP, maintenance=MaintenancePolicy(), start=True
    )
    routed = np.asarray(knn_lm_mix(logits, queries[:8], store, SP, lam=0.3))
    np.testing.assert_array_equal(routed, baseline)
    new_ids = store.append(data[:16], vals[:16])
    assert store.delete(np.asarray(new_ids)) == 16
    store.compact()  # forced off-path swap through the engine
    assert engine.metrics.counter("swaps") == 1
    after = np.asarray(knn_lm_mix(logits, queries[:8], store, SP, lam=0.3))
    engine.stop(drain=True)
    assert after.shape == baseline.shape
    print("[5] engine-routed RetrievalStore + compact-as-swap OK")

    # 6. reader concurrency: shared read lock under writer + forced swap
    mut6 = ShardedMutableHilbertIndex.build(
        data[:2000], CFG, buffer_capacity=256, max_segments=8
    )
    eng6 = RetrievalEngine(
        mut6, SP, maintenance=None, serve_threads=2, max_batch=16,
        start=True,
    )
    stop6 = threading.Event()
    errors6, counts6 = [], [0, 0, 0]

    def reader6(i):
        r = np.random.default_rng(i)
        try:
            while not stop6.is_set():
                a = int(r.integers(0, Q - 8))
                t = eng6.submit(queries[a : a + 8])
                rids, rdists = t.result(timeout=120)
                assert rids.shape == (8, SP.k)
                counts6[i] += 1
        except BaseException as e:
            errors6.append(e)
            stop6.set()

    readers6 = [
        threading.Thread(target=reader6, args=(i,), daemon=True)
        for i in range(len(counts6))
    ]
    for t in readers6:
        t.start()
    try:
        for _ in range(2):
            rid6 = eng6.insert(data[2000 : 2000 + 300])
            eng6.delete(np.asarray(rid6[::5]))
        # writer quiescent: probe the frozen epoch, then swap it out
        epoch_index, epoch = eng6.index, eng6.epoch
        probes6 = [eng6.submit(queries[a : a + 8]) for a in range(0, 32, 8)]
        for t in probes6:
            t.result(timeout=120)
        assert eng6.maintain_once(force=True)
        assert eng6.epoch == epoch + 1
    finally:
        stop6.set()
        for t in readers6:
            t.join(60)
        eng6.stop()
    assert not errors6, errors6[:1]
    assert all(c > 0 for c in counts6), counts6
    assert eng6.metrics.counter("completed") == eng6.metrics.counter(
        "admitted"
    )
    for t in probes6:
        assert t.epoch == epoch
        wi, wd = epoch_index.search(t.queries, SP, allow_rewrite=False)
        np.testing.assert_array_equal(t.ids, np.asarray(wi))
        np.testing.assert_array_equal(t.dists, np.asarray(wd))
    s6 = eng6._serve_lock.stats()
    assert s6["read_acquisitions"] > 0 and s6["write_acquisitions"] > 0
    total6 = sum(counts6) + len(probes6)
    print(f"[6] reader concurrency OK ({total6} tickets acked, "
          f"{int(s6['read_acquisitions'])} shared reads, "
          f"{int(s6['write_acquisitions'])} exclusive writes)")

    # 7. out-of-process compaction + lock-exclusivity timeline
    mut7 = ShardedMutableHilbertIndex.build(
        data[:2000], CFG, buffer_capacity=256, max_segments=8
    )
    ids7 = mut7.insert(data[2000:2400])
    mut7.delete(np.asarray(ids7[:80]))
    eng7 = RetrievalEngine(
        mut7, SP, maintenance=MaintenancePolicy(),
        compaction="subprocess",
    )
    assert eng7.maintain_once(force=True)
    tl = eng7.last_swap_timeline
    assert tl["compaction"] == "subprocess"
    # the serve lock is exclusive ONLY at snapshot + swap; the child
    # compact and the catch-up replay run with searches flowing
    assert tl["snapshot_locked"] and tl["swap_locked"], tl
    assert not tl["compact_locked"] and not tl["replay_locked"], tl
    assert tl["compactor_phases"]["child_phases_s"], tl
    wi7, wd7 = eng7.index.search(queries, SP, allow_rewrite=False)
    ei7, ed7 = eng7.search(queries)
    np.testing.assert_array_equal(ei7, np.asarray(wi7))
    np.testing.assert_array_equal(ed7, np.asarray(wd7))
    stats7 = eng7.maintenance_stats()
    assert stats7["n_live"] == 2000 + 400 - 80, stats7
    print("[7] out-of-process compaction OK "
          f"(child {tl['compactor_phases']['child_ms']:.0f} ms, "
          f"swap locked {tl['swap_ms']:.1f} ms)")

    print("ALL SERVING CHECKS PASSED")


if __name__ == "__main__":
    main()
