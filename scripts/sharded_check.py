"""Self-test for repro.index.sharded on 8 simulated devices.

Run via: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
             python scripts/sharded_check.py
(tests/test_sharded.py spawns this as a subprocess so the main pytest
process keeps its single-device view.)

Checks, in order:
  1. hilbert_partition (sample-sort path) concatenates to the global
     master Hilbert order.
  2. Multi-shard search is set-equivalent to single-device search on the
     same data under pool-saturating params (both exact → same id sets,
     same sorted distances bit-for-bit), in ONE jitted dispatch per chunk.
  2b. Merge strategies: the butterfly tree reduction (``merge="tree"``,
     what "auto" picks on 8 shards) is bit-equal in sorted distances to
     the flat ``merge="gather"`` reference, pruning changes nothing
     bit-for-bit, ``search_local`` + one host merge reproduces the
     merged distances, each strategy stays one dispatch per chunk, and
     a non-pow2 shard count falls back (auto) or raises (explicit tree).
  3. Non-divisible n and fully-empty shards (sentinel-free padding):
     still set-equivalent; padding duplicates merge away.
  4. memory_report per-device bytes ≈ total/n_shards, cross-checked
     against the arrays' actual addressable shards.
  5. v3 checkpoints: same-count reload bit-equal; 8→1 reshard
     bit-identical to the single-device fused path; v2 single-index
     bundle adopted + resharded to 8.
  6. Sharded RetrievalStore: kNN-LM lookups through the merged top-k,
     save/load round-trip.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed
from repro.core.search import hilbert_master_sort
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    SearchParams,
    ShardedHilbertIndex,
    build_auto,
)
from repro.launch.mesh import data_mesh
from repro.serve.retrieval import RetrievalStore, knn_lm_mix

assert len(jax.devices()) == 8, jax.devices()

N, D, Q = 2048, 24, 16
CFG = IndexConfig(
    forest=ForestConfig(n_trees=2, bits=4, key_bits=96, leaf_size=16, seed=0)
)
# Pool-saturating params: stage 1 covers every row on both layouts, so both
# searches are exact over the same globally-quantized ADC distances and the
# result id sets must coincide (ties aside — the data is continuous random,
# so exact distance ties are measure-zero).
SP = SearchParams(k1=N, k2=N, h=1, k=10)

data, queries = ann_datasets.lowrank_dataset_with_queries(
    N, Q, D, n_clusters=8, seed=0
)
data = np.asarray(data)
queries = jnp.asarray(queries)


def assert_set_equal(ids_a, ids_b, label):
    for ra, rb in zip(np.asarray(ids_a), np.asarray(ids_b)):
        sa = set(ra[ra >= 0].tolist())
        sb = set(rb[rb >= 0].tolist())
        assert sa == sb, (label, sorted(sa ^ sb))
    print(f"OK: {label}")


# --- 1. sample-sort partition == global master order ----------------------
parts = distributed.hilbert_partition(jnp.asarray(data), CFG.forest)
ref_order, _ = hilbert_master_sort(
    jnp.asarray(data), CFG.forest,
    jnp.min(jnp.asarray(data), axis=0), jnp.max(jnp.asarray(data), axis=0),
)
got = np.concatenate(parts)
assert sorted(got.tolist()) == list(range(N))
# equal-key ties may order differently between the two sorts; compare keys
# via positions: both orders must agree wherever keys are unique, which the
# continuous data guarantees almost surely — assert exact match.
np.testing.assert_array_equal(got, np.asarray(ref_order))
print("OK: hilbert_partition (sample sort) matches master Hilbert order")

# --- 2. multi-shard set-equivalence + single dispatch per chunk -----------
sharded = build_auto(jnp.asarray(data), CFG)
assert isinstance(sharded, ShardedHilbertIndex) and sharded.n_shards == 8
single = HilbertIndex.build(jnp.asarray(data), CFG)

ids_s, d2_s = sharded.search(queries, SP)
assert sharded.last_dispatch_count == 1, sharded.last_dispatch_count
ids_1, d2_1 = single.search(queries, SP)
assert_set_equal(ids_s, ids_1, "8-shard search set-equivalent to 1-device")
np.testing.assert_array_equal(
    np.sort(np.asarray(d2_s), axis=1), np.sort(np.asarray(d2_1), axis=1)
)
print("OK: sorted distances bit-equal across layouts")

# chunked: one jitted dispatch per chunk, results unchanged
ids_c, _ = sharded.search(queries, SP, query_chunk=4)
assert sharded.last_dispatch_count == 4, sharded.last_dispatch_count
np.testing.assert_array_equal(np.asarray(ids_c), np.asarray(ids_s))
print("OK: one dispatch per query chunk (4 chunks -> 4 dispatches)")

# no duplicate ids in any result row (padding rows merged away)
for row in np.asarray(ids_s):
    live = row[row >= 0]
    assert len(set(live.tolist())) == len(live), row

# --- 2b. merge strategies: tree vs gather parity --------------------------
ids_g, d2_g = sharded.search(queries, SP, merge="gather")
assert sharded.last_dispatch_count == 1, sharded.last_dispatch_count
ids_t, d2_t = sharded.search(queries, SP, merge="tree")
assert sharded.last_dispatch_count == 1, sharded.last_dispatch_count
# both outputs are distance-sorted, so sorted-d2 bit-equality is direct
# equality; ids may only differ inside exact-distance ties
np.testing.assert_array_equal(np.asarray(d2_t), np.asarray(d2_g))
assert_set_equal(ids_t, ids_g, "tree reduction id-sets == gather reference")
print("OK: tree reduction sorted-d2 bit-equal to merge='gather'")

# config default "auto" resolved to the tree on 8 shards: same executable,
# so the section-2 results above must be bit-equal to the explicit tree
np.testing.assert_array_equal(np.asarray(ids_s), np.asarray(ids_t))
np.testing.assert_array_equal(np.asarray(d2_s), np.asarray(d2_t))
print("OK: merge='auto' on 8 shards is the tree path, bit-equal")

# distance-bound pruning is exact: bit-equal INCLUDING ids
ids_p, d2_p = sharded.search(queries, SP, merge="tree", prune=True)
np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_t))
np.testing.assert_array_equal(np.asarray(d2_p), np.asarray(d2_t))
print("OK: prune=True bit-equal to the unpruned tree (ids included)")

# search_local = the same dispatch minus the reduction: one host-side
# flat merge of the per-shard deflated top-k's reproduces the merged
# distances exactly
from repro.core.search import merge_topk

loc_i, loc_d = sharded.search_local(queries, SP)
assert loc_i.shape == (8, Q, SP.k), loc_i.shape
_, host_d = merge_topk(
    jnp.moveaxis(loc_i, 0, 1).reshape(Q, -1),
    jnp.moveaxis(loc_d, 0, 1).reshape(Q, -1),
    k=SP.k,
)
np.testing.assert_array_equal(np.asarray(host_d), np.asarray(d2_t))
print("OK: search_local + host flat merge reproduces merged distances")

# non-pow2 shard counts: "auto" falls back to gather; explicit tree raises
sh3 = ShardedHilbertIndex.build(jnp.asarray(data), CFG, mesh=data_mesh(3))
i3a, d3a = sh3.search(queries, SP)
i3g, d3g = sh3.search(queries, SP, merge="gather")
np.testing.assert_array_equal(np.asarray(i3a), np.asarray(i3g))
np.testing.assert_array_equal(np.asarray(d3a), np.asarray(d3g))
try:
    sh3.search(queries, SP, merge="tree")
except ValueError:
    print("OK: 3 shards: auto==gather; explicit merge='tree' raises")
else:
    raise AssertionError("merge='tree' on 3 shards must raise")

# --- 3. non-divisible n + fully-empty shards ------------------------------
for n_odd in (N + 3, 11):  # 11 over 8 shards: n_pad=2, shards 6..7 empty
    d_odd = np.asarray(
        ann_datasets.lowrank_embeddings(n_odd, D, n_clusters=4, r=4, seed=2)
    )
    sp_odd = SearchParams(k1=n_odd, k2=n_odd, h=1, k=min(10, n_odd))
    sh_odd = ShardedHilbertIndex.build(jnp.asarray(d_odd), CFG)
    si_odd = HilbertIndex.build(jnp.asarray(d_odd), CFG)
    io_s, _ = sh_odd.search(queries, sp_odd)
    io_1, _ = si_odd.search(queries, sp_odd)
    assert_set_equal(
        io_s, io_1,
        f"n={n_odd} (pads={sh_odd.pad_max}, "
        f"empty={int((sh_odd.n_valid == 0).sum())}) set-equivalent",
    )

# --- 4. per-device resident bytes ≈ total / n_shards ----------------------
rep = sharded.memory_report()
per_dev = rep["per_device_bytes"][0]
assert abs(per_dev - (rep["sharded_bytes"] / 8 + rep["replicated_bytes"])) <= 8
# cross-check the model against physical placement: every stacked leaf
# must put exactly 1/8 of its bytes on each device.
leaves = list(sharded.stack) + (
    [sharded.points] if sharded.points is not None else []
)
measured = {}
for leaf in leaves:
    for s in leaf.addressable_shards:
        measured[s.device] = measured.get(s.device, 0) + s.data.nbytes
assert len(measured) == 8
for dev, nbytes in measured.items():
    assert nbytes == rep["sharded_bytes"] // 8, (dev, nbytes)
frac = per_dev / rep["resident_bytes"]
assert frac < 0.2, frac  # ~1/8 plus small replicated overhead
print(f"OK: per-device residency measured == model ({per_dev} B/device, "
      f"{frac:.3f} of total)")

# --- 5. v3 checkpoints: reload, reshard, v2 adoption ----------------------
with tempfile.TemporaryDirectory() as tmp:
    p3 = os.path.join(tmp, "v3")
    sharded.save(p3)
    re8 = ShardedHilbertIndex.load(p3)  # default mesh: 8 devices
    i8, d8 = re8.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(i8), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(d8), np.asarray(d2_s))
    print("OK: v3 reload at same shard count is bit-equal")

    re1 = ShardedHilbertIndex.load(p3, mesh=data_mesh(1))
    i1, d1 = re1.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(ids_1))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2_1))
    print("OK: 8->1 reshard-on-load bit-identical to 1-device fused search")

    p2 = os.path.join(tmp, "v2")
    single.save(p2)  # a plain format_version-2 single-index bundle
    adopted = ShardedHilbertIndex.load(p2)  # resharded onto 8 devices
    assert adopted.n_shards == 8
    ia, _ = adopted.search(queries, SP)
    assert_set_equal(ia, ids_1, "v2 bundle adopted + resharded to 8")

# --- 6. sharded retrieval serving -----------------------------------------
rng = np.random.default_rng(0)
V = 64
vals = jnp.asarray(rng.integers(0, V, N).astype(np.int32))
store = RetrievalStore.build(jnp.asarray(data), vals, CFG, shards=8)
assert store.is_sharded
sp_serve = SearchParams(k1=32, k2=64, h=1, k=8)
ids_r, _ = store.lookup(jnp.asarray(data[:4]), sp_serve)
assert int(np.asarray(ids_r)[0, 0]) == 0  # self-hit rank 0
logits = jnp.asarray(rng.normal(size=(4, V)).astype(np.float32))
logp = knn_lm_mix(logits, jnp.asarray(data[:4]), store, sp_serve)
assert np.isfinite(np.asarray(logp)).all()
srep = store.memory_report()
assert srep["per_device_bytes"][0] < srep["total_bytes"] / 4
with tempfile.TemporaryDirectory() as tmp:
    sp_path = os.path.join(tmp, "store")
    store.save(sp_path)
    lo = RetrievalStore.load(sp_path)
    assert lo.is_sharded
    i2, _ = lo.lookup(jnp.asarray(data[:4]), sp_serve)
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ids_r))

    # repeated in-place saves version the state sidecar (never rewriting
    # the step the previous manifest references) and prune stale steps
    store.save(sp_path)
    store.save(sp_path)
    steps = sorted(
        n for n in os.listdir(os.path.join(sp_path, "state"))
        if n.startswith("step_")
    )
    assert len(steps) <= 2, steps
    assert RetrievalStore.load(sp_path).is_sharded

    # loading onto a ONE-device mesh reshards into the single-device
    # mutable layout (same ids, same values, streaming writes intact)
    lo1 = RetrievalStore.load(sp_path, mesh=data_mesh(1))
    assert not lo1.is_sharded and lo1.index.n_live == N
    i1d, _ = lo1.lookup(jnp.asarray(data[:4]), sp_serve)
    assert int(np.asarray(i1d)[0, 0]) == 0

    # rebuild-and-swap over an OLD MUTABLE save: the sharded save must
    # shadow the stale mutable manifest, or loaders would silently serve
    # the pre-rebuild corpus
    swap_path = os.path.join(tmp, "swap")
    old = RetrievalStore.build(jnp.asarray(data[:256]), vals[:256], CFG)
    old.save(swap_path)
    store.save(swap_path)
    swapped = RetrievalStore.load(swap_path)
    assert swapped.is_sharded and swapped.sharded.n_live == N
    # ...and switching back to mutable shadows the sharded manifest
    old.save(swap_path)
    back = RetrievalStore.load(swap_path)
    assert not back.is_sharded and back.index.n_live == 256
print("OK: sharded RetrievalStore serves merged kNN-LM lookups + round-trips")

print("ALL SHARDED CHECKS PASSED")
