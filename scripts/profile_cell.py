"""Profile one dry-run cell: collective bytes by shape (loop-scaled) +
biggest arrays.  Usage: python scripts/profile_cell.py <arch> <shape>"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys
import dataclasses
from collections import Counter

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import dryrun, shardings as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.optim import OptimizerConfig
from repro.train.train_loop import TrainConfig, abstract_train_state, make_train_step

arch, shape = sys.argv[1], sys.argv[2]
cfg = configs.get_config(arch)
mesh = make_production_mesh()
rules = dryrun.rules_for(cfg, shape, mesh)
seq, batch, kind = configs.SHAPES[shape]

with mesh:
    if kind == "train":
        n_micro = dryrun.microbatches_for(cfg, seq, batch,
                                          seq_sharded=(rules.seq is not None))
        print(f"n_micro={n_micro} seq_shard={rules.seq} fsdp={rules.fsdp}")
        tcfg = TrainConfig(n_microbatches=n_micro, optimizer=OptimizerConfig(
            moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32"))
        cfg2 = dataclasses.replace(cfg, remat_policy="full")
        state = abstract_train_state(cfg2, tcfg)
        state_sh = shlib.tree_shardings(state, mesh, rules)
        bspecs = dryrun.input_specs(cfg2, shape)
        bsh = jax.tree.map(lambda l: NamedSharding(
            mesh, P(rules.batch, *([None] * (l.ndim - 1)))), bspecs)
        compiled = jax.jit(make_train_step(cfg2, tcfg, rules),
                           in_shardings=(state_sh, bsh),
                           out_shardings=(state_sh, None)
                           ).lower(state, bspecs).compile()
    else:
        params = model.abstract_params(cfg)
        params_sh = shlib.tree_shardings(params, mesh, rules)
        ins = dryrun.input_specs(cfg, shape)
        caches_sh = shlib.tree_shardings(ins["caches"], mesh, rules)
        tok_sh = NamedSharding(mesh, P(rules.batch, None))

        def serve_step(params, tokens, idx, caches):
            return model.decode_step(cfg, params, tokens, idx, caches, rules)

        compiled = jax.jit(
            serve_step,
            in_shardings=(params_sh, tok_sh, NamedSharding(mesh, P()), caches_sh),
            out_shardings=(NamedSharding(mesh, P(rules.batch, rules.vocab)),
                           caches_sh),
        ).lower(params, ins["tokens"], ins["idx"], ins["caches"]).compile()

txt = compiled.as_text()
from repro.launch.dryrun import (_COMP_HDR, _WHILE_RE, _CONST_RE,
                                 _DTYPE_BYTES, _COLL_RE)

comps, entry, cur = {}, None, None
for line in txt.splitlines():
    m = _COMP_HDR.match(line.strip())
    if m:
        cur = m.group(2)
        comps[cur] = []
        if m.group(1):
            entry = cur
        continue
    if cur:
        comps[cur].append(line)


def trip(cond):
    cs = [int(x) for l in comps.get(cond, ()) for x in _CONST_RE.findall(l)]
    return max(cs) if cs else 1


shape_bytes = Counter()


def walk(name, mult, seen):
    if name in seen:
        return
    seen = seen | {name}
    for line in comps.get(name, ()):
        cm = _COLL_RE.search(line)
        if cm:
            dt, dims, kind_ = cm.group(1), cm.group(2), cm.group(3)
            b = _DTYPE_BYTES.get(dt, 4)
            for d in dims.split(","):
                if d:
                    b *= int(d)
            shape_bytes[f"{kind_} {dt}[{dims}]"] += mult * b
        wm = _WHILE_RE.search(line)
        if wm:
            cond = wm.group(1) or wm.group(4)
            body = wm.group(2) or wm.group(3)
            walk(body, mult * trip(cond), seen)


walk(entry, 1, frozenset())
print("== collectives by shape (loop-scaled, per device) ==")
for k, v in shape_bytes.most_common(10):
    print(f"{v/1e9:10.2f} GB  {k}")
sizes = Counter()
for m in re.finditer(r"%[\w\.\-]+ = (\w+)\[([0-9,]+)\]", txt):
    dt, dims = m.group(1), m.group(2)
    b = _DTYPE_BYTES.get(dt, 4)
    for d in dims.split(","):
        b *= int(d)
    sizes[f"{dt}[{dims}]"] = max(sizes[f"{dt}[{dims}]"], b)
print("== biggest arrays ==")
for shp, b in sizes.most_common(6):
    print(f"{b/1e9:10.2f} GB  {shp}")
