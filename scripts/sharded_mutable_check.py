"""Self-test for repro.index.sharded_mutable on 8 simulated devices.

Run via: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
             python scripts/sharded_mutable_check.py
(tests/test_sharded_mutable.py spawns this as a subprocess so the main
pytest process keeps its single-device view.)

Checks, in order:
  1. Fresh build: search bit-equal to a static ShardedHilbertIndex over
     the same corpus, in ONE jitted dispatch per chunk.
  2. Interleaved insert/delete stream (flush-sealed generations, skewed
     inserts producing empty shards in a generation, tombstoned buffer
     rows) keeps finding exact nearest neighbors, still one dispatch.
  3. Full compaction re-balances across shards: post-compact search is
     BIT-EQUAL to a fresh ShardedHilbertIndex build on the surviving rows
     (the acceptance criterion).
  4. format_version-4 save/load round-trips bit-equal, with buffered rows
     and tombstones in flight; a second save dedups unchanged bundles and
     prunes stale ones.
  5. v3 (static sharded) checkpoints adopt into the mutable facade
     bit-equal, then accept writes; 8->4 reshard-on-load equals a fresh
     4-shard build over the survivors.
  6. Sharded-mutable RetrievalStore: append/delete while serving (the
     calls that used to raise), kNN-LM mix end to end, save/load,
     v3-store adoption.
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SearchParams
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    IndexConfig,
    ShardedHilbertIndex,
    ShardedMutableHilbertIndex,
    build_auto,
)
from repro.launch.mesh import data_mesh
from repro.serve.retrieval import RetrievalStore, knn_lm_mix

assert len(jax.devices()) == 8, jax.devices()

N, D, Q = 1024, 16, 12
CFG = IndexConfig(
    forest=ForestConfig(n_trees=2, bits=4, key_bits=64, leaf_size=16, seed=0)
)
SP = SearchParams(k1=32, k2=64, h=1, k=10)

data, queries = ann_datasets.lowrank_dataset_with_queries(
    N + 512, Q, D, n_clusters=8, seed=0
)
data = np.asarray(data)
queries = jnp.asarray(queries)
extra = data[N:]
data = data[:N]
rng = np.random.default_rng(0)
MESH = data_mesh(8)


def expect_bitequal(mut, fresh, live_ids, label):
    """mut's ext-id results == fresh's row-id results mapped through live_ids."""
    fi, fd = fresh.search(queries, SP)
    mi, md = mut.search(queries, SP)
    assert mut.last_dispatch_count == 1, mut.last_dispatch_count
    exp = np.where(np.asarray(fi) >= 0,
                   live_ids[np.clip(np.asarray(fi), 0, None)], -1)
    np.testing.assert_array_equal(exp, np.asarray(mi), err_msg=label)
    np.testing.assert_array_equal(np.asarray(fd), np.asarray(md),
                                  err_msg=label)
    print(f"OK: {label}")


# --- 1. fresh build bit-equal to the static sharded index -----------------
idx = ShardedMutableHilbertIndex.build(
    jnp.asarray(data), CFG, mesh=MESH, buffer_capacity=64, max_segments=4
)
# both sharded facades share the LRU-bounded compiled-dispatch cache
from repro.index.facade import BoundedJitCache
from repro.index.sharded_mutable import _CHUNK_FN_CACHE_MAX

assert isinstance(idx._chunk_fns, BoundedJitCache)
assert idx._chunk_fns.max_entries == _CHUNK_FN_CACHE_MAX
static = ShardedHilbertIndex.build(jnp.asarray(data), CFG, mesh=MESH)
expect_bitequal(idx, static, np.arange(N, dtype=np.int32),
                "fresh build == static sharded (1 dispatch)")

# --- 2. interleaved stream: flushes, skew (empty shards), tombstones ------
live = {int(i) for i in range(N)}
skew = np.tile(data[3][None, :], (96, 1)) + rng.normal(
    0, 1e-3, (96, D)
).astype(np.float32)
sk_ids = idx.insert(skew)          # skewed: routes to one curve range
live.update(int(i) for i in sk_ids)
assert idx.n_segments > 1, "skewed inserts should have sealed generations"

drop = rng.choice(np.asarray(sorted(live)), 150, replace=False)
idx.delete(drop)
live -= {int(i) for i in drop}
ins2 = idx.insert(extra[:200])     # spread inserts
live.update(int(i) for i in ins2)
idx.delete(ins2[:40])              # some still buffered when deleted
live -= {int(i) for i in ins2[:40]}
idx.delete(sk_ids[:50])
live -= {int(i) for i in sk_ids[:50]}

ids_s, d_s = idx.search(queries, SP)
assert idx.last_dispatch_count == 1
live_ids, live_pts = idx._gather_live()
assert set(int(i) for i in live_ids) == live
got = np.asarray(ids_s)
assert not np.isin(got[got >= 0], drop).any(), "tombstones leaked"
assert np.isin(got[got >= 0], live_ids).all(), "stale ids surfaced"

# Probe rows: insert the queries THEMSELVES — buffered rows are searched
# exactly (brute force at distance 0), so each probe id must surface in
# its own query's top-k, and vanish the moment it is tombstoned.
probe = idx.insert(np.asarray(queries))
pi, pd = idx.search(queries, SP)
assert idx.last_dispatch_count == 1
pi = np.asarray(pi)
for r in range(Q):
    assert probe[r] in pi[r], (r, probe[r], pi[r])
    assert pd[r][list(pi[r]).index(probe[r])] <= 1e-6
idx.delete(probe)
live -= {int(i) for i in probe}
pi2, _ = idx.search(queries, SP)
assert not np.isin(np.asarray(pi2), probe).any(), "deleted probes leaked"
print(f"OK: churn stream (segments={idx.n_segments}, "
      f"buffered={idx.n_buffered}, 1 dispatch, probes exact, "
      f"no tombstone leaks)")

# --- 2b. cross-shard merge strategies on the LSM layout -------------------
# Mid-churn state (multiple generations + live buffer + tombstones) is the
# worst case for the reduction: per-generation inflated pools, duplicate
# ids across padding, masked dead rows.  Tree must still match gather.
mg_i, mg_d = idx.search(queries, SP, merge="gather")
mt_i, mt_d = idx.search(queries, SP, merge="tree")
assert idx.last_dispatch_count == 1
np.testing.assert_array_equal(np.asarray(mt_d), np.asarray(mg_d))
mp_i, mp_d = idx.search(queries, SP, merge="tree", prune=True)
np.testing.assert_array_equal(np.asarray(mp_i), np.asarray(mt_i))
np.testing.assert_array_equal(np.asarray(mp_d), np.asarray(mt_d))
print("OK: mid-churn tree reduction bit-equal to gather (prune exact too)")

# --- 3. full compaction == fresh sharded rebuild (ACCEPTANCE) -------------
idx.compact()
assert idx.n_segments == 1 and idx.n_buffered == 0
fresh = ShardedHilbertIndex.build(jnp.asarray(live_pts), CFG, mesh=MESH)
expect_bitequal(idx, fresh, live_ids,
                "post-compact == fresh sharded build on survivors")

# --- 4. v4 save/load round-trip with writes in flight ---------------------
idx.insert(extra[200:260])
idx.delete(live_ids[:7])
a1, b1 = idx.search(queries, SP)
with tempfile.TemporaryDirectory() as td:
    idx.save(td)
    first = {
        os.path.join(dp, f) for dp, _, fs in os.walk(td) for f in fs
    }
    re = ShardedMutableHilbertIndex.load(td, mesh=MESH)
    a2, b2 = re.search(queries, SP)
    assert re.last_dispatch_count == 1
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    # loaded index keeps streaming: routed insert + delete + compact
    re.insert(extra[260:280])
    re.compact()
    # re-save over the same path: unchanged segment bundles are skipped,
    # and a fresh state step replaces the old one (one-gen grace pruning)
    idx.save(td)
    idx.save(td)
    steps = os.listdir(os.path.join(td, "state"))
    assert len(steps) <= 2, steps
    print("OK: v4 save/load round-trip bit-equal (+ dedup/prune on resave)")

# --- 5. v3 adoption + reshard-on-load -------------------------------------
with tempfile.TemporaryDirectory() as td:
    static.save(td)
    adopted = ShardedMutableHilbertIndex.load(td, mesh=MESH)  # v3 -> v4
    si, sd = static.search(queries, SP)
    ai, ad = adopted.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ai))
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(ad))
    adopted.insert(extra[:10])
    adopted.delete([0, 1, 2])
    with tempfile.TemporaryDirectory() as td4:
        adopted.save(td4)                      # v4 round-trip of the adopt
        li, lp = adopted._gather_live()
        re4 = ShardedMutableHilbertIndex.load(td4, mesh=data_mesh(4))
        assert re4.n_shards == 4
        fresh4 = ShardedHilbertIndex.build(
            jnp.asarray(lp), CFG, mesh=data_mesh(4)
        )
        fi, fd = fresh4.search(queries, SP)
        ri, rd = re4.search(queries, SP)
        exp = np.where(np.asarray(fi) >= 0,
                       li[np.clip(np.asarray(fi), 0, None)], -1)
        np.testing.assert_array_equal(exp, np.asarray(ri))
        np.testing.assert_array_equal(np.asarray(fd), np.asarray(rd))
print("OK: v3 adoption bit-equal; 8->4 reshard == fresh 4-shard build")

# --- 6. streaming sharded RetrievalStore ----------------------------------
keys = data[:512]
vals = rng.integers(0, 97, 512).astype(np.int32)
store = RetrievalStore.build(
    jnp.asarray(keys), jnp.asarray(vals), CFG, shards=8, mesh=MESH,
    buffer_capacity=64,
)
assert store.is_sharded
new_ids = store.append(jnp.asarray(extra[:32]),
                       jnp.asarray(np.arange(32, dtype=np.int32)))
store.delete(new_ids[:8])
ids_q, _ = store.lookup(queries, SP)
toks = np.asarray(store.values_at(ids_q))
take = np.asarray(ids_q)
mask = (take >= 0) & (take < 512)
np.testing.assert_array_equal(toks[mask], vals[np.asarray(take)[mask]])
logits = jnp.asarray(rng.normal(size=(Q, 97)), jnp.float32)
mixed = knn_lm_mix(logits, queries, store, SP, lam=0.3)
assert np.isfinite(np.asarray(mixed)).all()
rep = store.memory_report()
assert rep["n_shards"] == 8 and rep["per_device_bytes"][0] > 0
with tempfile.TemporaryDirectory() as td:
    store.save(td)
    store2 = RetrievalStore.load(td, mesh=MESH)
    assert store2.is_sharded
    i1, d1 = store.lookup(queries, SP)
    i2, d2 = store2.lookup(queries, SP)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    store2.append(jnp.asarray(extra[32:40]),
                  jnp.asarray(np.arange(8, dtype=np.int32)))
    store2.compact()
print("OK: sharded RetrievalStore streams (append/delete/compact) + "
      "save/load")

# a PR-4-era v3 STORE checkpoint (static sharded index + values sidecar)
# adopts into the streaming layout on load
from repro import checkpoint as ckpt_lib

with tempfile.TemporaryDirectory() as td:
    base = ShardedHilbertIndex.build(jnp.asarray(keys), CFG, mesh=MESH)
    ckpt_lib.save(os.path.join(td, "store_values"), step=1,
                  tree={"values": vals},
                  extra={"kind": "retrieval_store_sharded"})
    base.save(td, kind="retrieval_store_sharded",
              extra_meta={"values_step": 1})
    old = RetrievalStore.load(td, mesh=MESH)
    assert old.is_sharded
    oi, od = old.lookup(queries, SP)
    bi, bd = base.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(bd), np.asarray(od))
    old.append(jnp.asarray(extra[:4]),
               jnp.asarray(np.arange(4, dtype=np.int32)))  # used to raise
    old.delete([0])
    # in-place v3 -> v4 upgrade: the save must remove the static layout's
    # now-unreachable payload (shards/ bundles + store_values/ sidecar),
    # not just its manifest, and the upgraded checkpoint must reload
    pre, _ = old.lookup(queries, SP)
    old.save(td)
    assert not os.path.exists(os.path.join(td, "sharded_manifest.json"))
    assert not os.path.exists(os.path.join(td, "shards"))
    assert not os.path.exists(os.path.join(td, "store_values"))
    up = RetrievalStore.load(td, mesh=MESH)
    post, _ = up.lookup(queries, SP)
    np.testing.assert_array_equal(np.asarray(pre), np.asarray(post))
print("OK: v3 store checkpoint adopts into the streaming layout "
      "(+ in-place upgrade cleans the static payload)")

# ...including one saved RAM-lean (store_points=False, the old static
# serving default): it serves and absorbs writes, but compaction has no
# raw keys to re-sort and must raise — MutableHilbertIndex.from_index
# semantics, sharded
with tempfile.TemporaryDirectory() as td:
    lean_cfg = IndexConfig(forest=CFG.forest, store_points=False)
    lean = ShardedHilbertIndex.build(jnp.asarray(keys), lean_cfg, mesh=MESH)
    ckpt_lib.save(os.path.join(td, "store_values"), step=1,
                  tree={"values": vals},
                  extra={"kind": "retrieval_store_sharded"})
    lean.save(td, kind="retrieval_store_sharded",
              extra_meta={"values_step": 1})
    old = RetrievalStore.load(td, mesh=MESH)
    assert old.is_sharded
    oi, od = old.lookup(queries, SP)
    bi, bd = lean.search(queries, SP)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(bd), np.asarray(od))
    aid = old.append(jnp.asarray(extra[:6]),
                     jnp.asarray(np.arange(6, dtype=np.int32)))
    old.delete(aid[:2])
    old.lookup(queries, SP)
    try:
        old.compact()
        raise AssertionError("compacting a point-less base must raise")
    except ValueError as e:
        assert "stored points" in str(e)
print("OK: store_points=False v3 store still loads, serves, and streams")

# build_auto returns the streaming facade on request
auto = build_auto(jnp.asarray(data[:256]), CFG, mesh=MESH, mutable=True)
assert isinstance(auto, ShardedMutableHilbertIndex)
print("OK: build_auto(mutable=True) picks ShardedMutableHilbertIndex")

print("ALL SHARDED-MUTABLE CHECKS PASSED")
