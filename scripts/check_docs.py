"""Docs checker: code blocks must parse, doctests must pass, links resolve.

Run: python scripts/check_docs.py [files...]   (default: README.md docs/*.md)

Three checks over every markdown file:

1. **Python code blocks compile** — every ```python fence must be valid
   syntax (illustrative blocks may reference undefined names; they still
   have to parse).
2. **Doctests run** — fenced blocks containing ``>>>`` prompts execute
   under ``doctest`` (the ``python -m doctest`` semantics, applied to
   markdown fences) and their outputs must match.
3. **Links and anchors resolve** — every relative markdown link must point
   at an existing file, and every ``#fragment`` (same-file or cross-file)
   must match a heading's GitHub-style anchor slug.

Exit status is non-zero with a per-problem report on any failure; also run
in-process by ``tests/test_docs.py`` so the tier-1 suite catches doc rot.
"""

from __future__ import annotations

import doctest
import glob
import os
import re
import sys
from typing import List, Tuple

_FENCE = re.compile(r"^```(\w*)\s*$")
# [text](target) — skip images ![..](..) and bare autolinks
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$")


def _slugify(heading: str) -> str:
    """GitHub's anchor algorithm (close enough for ASCII docs)."""
    s = re.sub(r"`([^`]*)`", r"\1", heading)  # code spans keep their text
    s = s.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _code_blocks(text: str) -> List[Tuple[int, str, str]]:
    """(start_line, language, body) for every fenced block."""
    out, lines = [], text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        lang, start = m.group(1).lower(), i + 1
        j = start
        while j < len(lines) and not lines[j].startswith("```"):
            j += 1
        out.append((start + 1, lang, "\n".join(lines[start:j])))
        i = j + 1
    return out


def _anchors(path: str) -> set:
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return set()
    slugs: set = set()
    in_fence = False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            base = _slugify(m.group(2))
            slug, n = base, 1
            while slug in slugs:  # duplicate headings get -1, -2, ...
                slug, n = f"{base}-{n}", n + 1
            slugs.add(slug)
    return slugs


def check_file(path: str) -> List[str]:
    problems: List[str] = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(os.path.abspath(path))

    for line, lang, body in _code_blocks(text):
        if lang not in ("python", "py"):
            continue
        if ">>>" in body:
            runner = doctest.DocTestRunner(verbose=False)
            parser = doctest.DocTestParser()
            try:
                test = parser.get_doctest(body, {}, path, path, line)
            except ValueError as e:
                problems.append(f"{path}:{line}: bad doctest block: {e}")
                continue
            out: List[str] = []
            runner.run(test, out=out.append)
            if runner.failures:
                problems.append(
                    f"{path}:{line}: doctest failed:\n" + "".join(out)
                )
        else:
            try:
                compile(body, f"{path}:{line}", "exec")
            except SyntaxError as e:
                problems.append(
                    f"{path}:{line}: python block does not parse: {e}"
                )

    in_fence = False
    for ln, raw in enumerate(text.splitlines(), 1):
        if raw.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(raw):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # URL scheme
                continue
            file_part, _, frag = target.partition("#")
            tpath = (
                os.path.normpath(os.path.join(base, file_part))
                if file_part else path
            )
            if file_part and not os.path.exists(tpath):
                problems.append(f"{path}:{ln}: broken link -> {target}")
                continue
            if frag and not tpath.endswith((".md", path)):
                continue  # anchors only checked inside markdown
            if frag and frag not in _anchors(tpath):
                problems.append(
                    f"{path}:{ln}: broken anchor -> {target} "
                    f"(no heading slugs to '{frag}')"
                )
    return problems


def main(paths: List[str]) -> int:
    if not paths:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = [os.path.join(root, "README.md")] + sorted(
            glob.glob(os.path.join(root, "docs", "*.md"))
        )
    problems: List[str] = []
    for p in paths:
        problems.extend(check_file(p))
    for msg in problems:
        print(msg)
    print(f"checked {len(paths)} files: "
          f"{'FAILED' if problems else 'ok'} ({len(problems)} problems)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
