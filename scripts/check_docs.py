"""Docs checker: code blocks parse, doctests pass, links + bench claims hold.

Run: python scripts/check_docs.py [files...]
(default: README.md ROADMAP.md docs/*.md)

Four checks over every markdown file:

1. **Python code blocks compile** — every ```python fence must be valid
   syntax (illustrative blocks may reference undefined names; they still
   have to parse).
2. **Doctests run** — fenced blocks containing ``>>>`` prompts execute
   under ``doctest`` (the ``python -m doctest`` semantics, applied to
   markdown fences) and their outputs must match.
3. **Links and anchors resolve** — every relative markdown link must point
   at an existing file, and every ``#fragment`` (same-file or cross-file)
   must match a heading's GitHub-style anchor slug.
4. **Bench claims match the artifacts** — any paragraph that names a
   committed ``BENCH_*.json`` must only quote ``NNN ms`` figures that
   actually appear in that artifact (within rounding).  Latency numbers
   pasted into prose rot silently when the benchmark reruns — this check
   is how the 577ms-vs-964ms drift that motivated it gets caught at CI
   time.  A paragraph can opt out with ``<!-- bench-claims: ignore -->``
   (e.g. when quoting a historical value on purpose).

Exit status is non-zero with a per-problem report on any failure; also run
in-process by ``tests/test_docs.py`` so the tier-1 suite catches doc rot.
"""

from __future__ import annotations

import doctest
import glob
import json
import os
import re
import sys
from typing import Dict, List, Tuple

_FENCE = re.compile(r"^```(\w*)\s*$")
# [text](target) — skip images ![..](..) and bare autolinks
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$")
_BENCH_REF = re.compile(r"\bBENCH_\w+\.json\b")
# "964ms" / "104.2 ms" — requires the unit, so knob names like
# ``deadline_ms`` and bare counts never match
_MS_CLAIM = re.compile(r"(?<![\w.])(\d+(?:\.\d+)?)\s?ms\b")
_BENCH_OPT_OUT = "bench-claims: ignore"


def _slugify(heading: str) -> str:
    """GitHub's anchor algorithm (close enough for ASCII docs)."""
    s = re.sub(r"`([^`]*)`", r"\1", heading)  # code spans keep their text
    s = s.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def _code_blocks(text: str) -> List[Tuple[int, str, str]]:
    """(start_line, language, body) for every fenced block."""
    out, lines = [], text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if not m:
            i += 1
            continue
        lang, start = m.group(1).lower(), i + 1
        j = start
        while j < len(lines) and not lines[j].startswith("```"):
            j += 1
        out.append((start + 1, lang, "\n".join(lines[start:j])))
        i = j + 1
    return out


def _anchors(path: str) -> set:
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError:
        return set()
    slugs: set = set()
    in_fence = False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            base = _slugify(m.group(2))
            slug, n = base, 1
            while slug in slugs:  # duplicate headings get -1, -2, ...
                slug, n = f"{base}-{n}", n + 1
            slugs.add(slug)
    return slugs


def _numeric_leaves(obj, prefix: str = "") -> Dict[str, float]:
    """Flatten a JSON value to {dotted.path: number} over numeric leaves."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for key, val in obj.items():
            out.update(_numeric_leaves(val, f"{prefix}.{key}" if prefix
                                       else str(key)))
    elif isinstance(obj, list):
        for i, val in enumerate(obj):
            out.update(_numeric_leaves(val, f"{prefix}[{i}]"))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def _paragraphs(text: str) -> List[Tuple[int, str]]:
    """(first_line, body) for blank-line-separated blocks outside fences."""
    out, buf, start = [], [], None
    in_fence = False
    for ln, raw in enumerate(text.splitlines(), 1):
        if raw.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        if raw.strip():
            if start is None:
                start = ln
            buf.append(raw)
        elif buf:
            out.append((start, "\n".join(buf)))
            buf, start = [], None
    if buf:
        out.append((start, "\n".join(buf)))
    return out


def _claim_matches(claim_ms: float, values: Dict[str, float]) -> bool:
    """A quoted figure matches if some artifact number rounds to it."""
    for val in values.values():
        if abs(val - claim_ms) < 1.0 or (
            val and abs(val - claim_ms) / abs(val) < 0.005
        ):
            return True
    return False


def check_bench_claims(path: str, text: str, base: str) -> List[str]:
    """Check 4: ``NNN ms`` prose against the named ``BENCH_*.json``."""
    problems: List[str] = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for ln, para in _paragraphs(text):
        refs = sorted(set(_BENCH_REF.findall(para)))
        if not refs or _BENCH_OPT_OUT in para:
            continue
        values: Dict[str, float] = {}
        missing = []
        for ref in refs:
            apath = os.path.join(base, ref)
            if not os.path.exists(apath):
                apath = os.path.join(root, ref)
            if not os.path.exists(apath):
                missing.append(ref)
                continue
            try:
                with open(apath, encoding="utf-8") as f:
                    values.update(_numeric_leaves(json.load(f)))
            except (OSError, ValueError) as e:
                problems.append(f"{path}:{ln}: unreadable artifact {ref}: {e}")
        for ref in missing:
            problems.append(
                f"{path}:{ln}: references {ref} but no such artifact is "
                f"committed"
            )
        if not values:
            continue
        for m in _MS_CLAIM.finditer(para):
            claim = float(m.group(1))
            if not _claim_matches(claim, values):
                problems.append(
                    f"{path}:{ln}: claim '{m.group(0).strip()}' not found in "
                    f"{', '.join(refs)} — stale number? (rerun the bench or "
                    f"fix the prose; opt out with '{_BENCH_OPT_OUT}')"
                )
    return problems


def check_file(path: str) -> List[str]:
    problems: List[str] = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(os.path.abspath(path))

    for line, lang, body in _code_blocks(text):
        if lang not in ("python", "py"):
            continue
        if ">>>" in body:
            runner = doctest.DocTestRunner(verbose=False)
            parser = doctest.DocTestParser()
            try:
                test = parser.get_doctest(body, {}, path, path, line)
            except ValueError as e:
                problems.append(f"{path}:{line}: bad doctest block: {e}")
                continue
            out: List[str] = []
            runner.run(test, out=out.append)
            if runner.failures:
                problems.append(
                    f"{path}:{line}: doctest failed:\n" + "".join(out)
                )
        else:
            try:
                compile(body, f"{path}:{line}", "exec")
            except SyntaxError as e:
                problems.append(
                    f"{path}:{line}: python block does not parse: {e}"
                )

    in_fence = False
    for ln, raw in enumerate(text.splitlines(), 1):
        if raw.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(raw):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # URL scheme
                continue
            file_part, _, frag = target.partition("#")
            tpath = (
                os.path.normpath(os.path.join(base, file_part))
                if file_part else path
            )
            if file_part and not os.path.exists(tpath):
                problems.append(f"{path}:{ln}: broken link -> {target}")
                continue
            if frag and not tpath.endswith((".md", path)):
                continue  # anchors only checked inside markdown
            if frag and frag not in _anchors(tpath):
                problems.append(
                    f"{path}:{ln}: broken anchor -> {target} "
                    f"(no heading slugs to '{frag}')"
                )

    problems.extend(check_bench_claims(path, text, base))
    return problems


def main(paths: List[str]) -> int:
    if not paths:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = [
            os.path.join(root, "README.md"),
            os.path.join(root, "ROADMAP.md"),
        ] + sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    problems: List[str] = []
    for p in paths:
        problems.extend(check_file(p))
    for msg in problems:
        print(msg)
    print(f"checked {len(paths)} files: "
          f"{'FAILED' if problems else 'ok'} ({len(problems)} problems)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
