"""Offline checkpoint scrubber: verify every bundle under a directory tree.

Walks PATH for ``repro.checkpoint`` bundle directories (anything holding
``step_<n>/manifest.json``), re-hashes every array against the manifest's
SHA-256 digests (format_version 5; older bundles get a structural check),
and scans any ``wal.log`` for torn tails.  Run it from cron / before
promoting a checkpoint to serving:

    PYTHONPATH=src python scripts/fsck_index.py /ckpts/store
    PYTHONPATH=src python scripts/fsck_index.py /ckpts/store --quarantine

Exit code 0 = everything verifies; 1 = at least one corrupt step (with
``--quarantine`` those are renamed to ``step_<n>.quarantine/`` so the
online fallback — "newest step that VERIFIES" — never has to re-discover
them).  A torn WAL tail is reported but is NOT corruption: it is the
expected signature of a crash mid-append, and recovery truncates it.

``--selftest`` builds a tiny bundle in a temp dir, flips one bit in the
payload, and asserts detection + quarantine + fallback — the CI smoke
that the scrubber itself works, no corpus needed.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
)

from repro import checkpoint  # noqa: E402
from repro.checkpoint import wal as wal_lib  # noqa: E402
from repro.checkpoint.checkpoint import _STEP_RE  # noqa: E402


def find_bundle_dirs(root: str):
    """Yield every directory under root that holds step_<n> bundles."""
    for dirpath, dirnames, _ in os.walk(root):
        if any(_STEP_RE.match(d) for d in dirnames):
            yield dirpath
            # don't descend into the step dirs themselves
            dirnames[:] = [
                d for d in dirnames if not _STEP_RE.match(d)
                and not d.endswith(".tmp")
            ]


def scrub(root: str, quarantine: bool) -> int:
    """Verify every step of every bundle; returns the corrupt-step count."""
    bad = 0
    bundles = 0
    for bundle in sorted(find_bundle_dirs(root)):
        bundles += 1
        rel = os.path.relpath(bundle, root)
        for step in checkpoint.steps_present(bundle):
            problems = checkpoint.verify_step(bundle, step)
            if not problems:
                print(f"  ok        {rel}/step_{step:08d}")
                continue
            bad += 1
            print(f"  CORRUPT   {rel}/step_{step:08d}")
            for p in problems:
                print(f"            - {p}")
            if quarantine:
                qdir = checkpoint.quarantine_step(bundle, step)
                print(f"            -> quarantined as {os.path.basename(qdir)}")
    for dirpath, _, filenames in os.walk(root):
        for name in filenames:
            if name != "wal.log":
                continue
            wpath = os.path.join(dirpath, name)
            try:
                records, _, torn = wal_lib.read_records(wpath)
            except wal_lib.WalError as e:
                bad += 1
                print(f"  CORRUPT   {os.path.relpath(wpath, root)}: {e}")
                continue
            tail = " (torn tail: recovery will truncate)" if torn else ""
            print(f"  wal       {os.path.relpath(wpath, root)}: "
                  f"{len(records)} intact record(s){tail}")
    if bundles == 0:
        print(f"  (no checkpoint bundles under {root})")
    return bad


def selftest() -> int:
    """Corrupt a bundle on purpose; assert detection, quarantine, fallback."""
    import tempfile

    import numpy as np

    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "bundle")
        rng = np.random.default_rng(0)
        tree = {"w": rng.normal(size=(64, 32)).astype(np.float32),
                "ids": np.arange(2048, dtype=np.int32)}
        checkpoint.save(ckpt, step=0, tree=tree, extra={})
        checkpoint.save(ckpt, step=1, tree=tree, extra={})
        assert checkpoint.verify_step(ckpt, 1) == [], "fresh bundle dirty?"
        npz = os.path.join(ckpt, "step_00000001", "host0.npz")
        size = os.path.getsize(npz)
        with open(npz, "r+b") as f:      # flip one bit mid-payload
            f.seek(size // 2)
            b = f.read(1)
            f.seek(size // 2)
            f.write(bytes([b[0] ^ 0x10]))
        problems = checkpoint.verify_step(ckpt, 1)
        assert problems, "bit flip not detected"
        print(f"  detect    step_00000001: {problems[0]}")
        bad = scrub(ckpt, quarantine=True)
        assert bad == 1, f"expected 1 corrupt step, scrub found {bad}"
        assert checkpoint.latest_step(ckpt) == 0, "quarantine not hidden"
        step = checkpoint.latest_verifiable_step(ckpt)
        assert step == 0, f"fallback resolved {step}, want 0"
        restored, _ = checkpoint.restore(ckpt, step, tree)
        np.testing.assert_array_equal(restored["w"], tree["w"])
        print("  fallback  step_00000000 restores bit-equal")
        # torn WAL tail is reported, not fatal
        wpath = os.path.join(ckpt, "wal.log")
        w = wal_lib.WriteAheadLog(wpath)
        w.append("delete", {"ids": np.arange(4, dtype=np.int32)}, {})
        w.close()
        with open(wpath, "ab") as f:
            f.write(b"\x07\x00\x00\x00partial")   # mid-append crash
        records, _, torn = wal_lib.read_records(wpath)
        assert len(records) == 1 and torn
        assert scrub(ckpt, quarantine=False) == 0
    print("fsck selftest PASSED")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description="verify repro checkpoint bundles offline"
    )
    ap.add_argument("path", nargs="?", help="checkpoint tree to scrub")
    ap.add_argument("--quarantine", action="store_true",
                    help="rename corrupt steps to step_<n>.quarantine/")
    ap.add_argument("--selftest", action="store_true",
                    help="corrupt a scratch bundle and assert detection")
    args = ap.parse_args()
    if args.selftest:
        return selftest()
    if not args.path:
        ap.error("PATH required (or --selftest)")
    print(f"fsck: scrubbing {args.path}")
    bad = scrub(args.path, args.quarantine)
    if bad:
        print(f"fsck: {bad} corrupt step(s)"
              + ("" if args.quarantine else " (re-run with --quarantine)"))
        return 1
    print("fsck: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
