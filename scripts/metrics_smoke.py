#!/usr/bin/env python
"""End-to-end smoke of the observability export surface (CI gate).

Starts ``launch/serve.py`` with the engine, a 100% recall probe, a
Chrome-trace export, and the ``--metrics-port`` endpoint; waits for the
workload to finish (the process lingers with the endpoint up); scrapes
``/metrics`` and asserts the Prometheus exposition parses and every core
series is present; fetches ``/trace`` and validates the Chrome-trace
JSON (saved as a CI artifact alongside the scrape).

Usage:  PYTHONPATH=src python scripts/metrics_smoke.py [outdir]
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import time
import urllib.request

# Series the obs layer must export for a serving engine under churn.
# Counters end in _total; engine_recall_at_k / engine_segments are gauges;
# engine_request_ms is the request-latency summary.
CORE_SERIES = [
    "engine_admitted_total",
    "engine_completed_total",
    "engine_batches_total",
    "engine_swaps_total",
    "engine_maintenance_runs_total",
    "engine_recall_at_k",
    "engine_recall_samples_total",
    "engine_segments",
    "engine_queue_depth",
    "engine_request_ms",
    "engine_queue_wait_ms",
    "index_dispatches_total",
    "index_recompiles_total",
]

PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? (NaN|[-+0-9.eE]+(?:[0-9])?)$"
)


def parse_prometheus(text: str) -> dict:
    """Strict-enough exposition parse: every non-comment line must be
    ``name{labels} value``; returns {bare metric name: sample count}."""
    names: dict = {}
    for line in text.splitlines():
        if not line.strip() or line.startswith("#"):
            continue
        m = PROM_LINE.match(line)
        if m is None:
            raise SystemExit(f"unparseable exposition line: {line!r}")
        bare = line.split("{", 1)[0].split(" ", 1)[0]
        names[bare] = names.get(bare, 0) + 1
    return names


def main() -> None:
    outdir = sys.argv[1] if len(sys.argv) > 1 else "."
    os.makedirs(outdir, exist_ok=True)
    trace_path = os.path.join(outdir, "serve_trace.json")
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), env.get("PYTHONPATH", "")) if p
    )
    env["PYTHONUNBUFFERED"] = "1"
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "gemma3_1b", "--smoke", "--batch", "2",
        "--prompt-len", "16", "--gen", "12",
        "--retrieval", "--churn", "--engine",
        "--recall-probe", "1.0",
        "--metrics-port", "0",
        "--trace-export", trace_path,
        "--linger", "120",
    ]
    proc = subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True,
    )
    url = None
    lines = []
    try:
        # the workload prints the ephemeral endpoint first, the trace-export
        # line when done; scrape between those and the linger deadline
        for line in proc.stdout:
            lines.append(line)
            sys.stdout.write(line)
            m = re.search(r"metrics endpoint at (http://\S+)/metrics", line)
            if m:
                url = m.group(1)
            if "wrote Chrome trace" in line:
                break
        if url is None:
            raise SystemExit("serve.py never printed the metrics endpoint")

        text = urllib.request.urlopen(url + "/metrics", timeout=30).read()
        text = text.decode()
        with open(os.path.join(outdir, "metrics_scrape.txt"), "w") as f:
            f.write(text)
        names = parse_prometheus(text)
        missing = [s for s in CORE_SERIES if s not in names]
        if missing:
            raise SystemExit(
                f"core series missing from /metrics: {missing}\n"
                f"present: {sorted(names)}"
            )

        snap = json.loads(
            urllib.request.urlopen(url + "/metrics.json", timeout=30).read()
        )
        admitted = snap.get("engine_admitted_total", 0)
        if not admitted:
            raise SystemExit("engine_admitted_total is 0: engine saw no load")

        trace = json.loads(
            urllib.request.urlopen(url + "/trace", timeout=30).read()
        )
        events = trace.get("traceEvents", [])
        if not events:
            raise SystemExit("/trace returned no span events")
        ts = [e["ts"] for e in events]
        if ts != sorted(ts):
            raise SystemExit("/trace timestamps are not monotonic")
        span_names = {e["name"] for e in events}
        for expected in ("engine.batch", "engine.search"):
            if expected not in span_names:
                raise SystemExit(
                    f"span {expected!r} missing from trace: {span_names}"
                )
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            proc.kill()

    print(
        f"\nmetrics smoke OK: {len(names)} series "
        f"({int(admitted)} requests admitted), "
        f"{len(events)} trace events -> {trace_path}"
    )


if __name__ == "__main__":
    main()
