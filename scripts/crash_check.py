"""Subprocess crash matrix: SIGKILL at every fault point, prove recovery.

The durability claim under test (docs/DURABILITY.md): with a WAL
attached, a crash at ANY instant loses no acknowledged write, and
recovery is *bit-equal* to a process that never crashed.  This script
makes "any instant" concrete.  Per scenario:

1. **Trace pass** — run the workload child with ``REPRO_FAULT_TRACE``
   set and no faults armed; the child appends one line per fault-point
   hit, enumerating every crash window the workload actually crosses.
2. **Kill matrix** — re-run the identical child once per traced point
   with ``REPRO_FAULTS="<point>@<hit>=kill"`` armed mid-way through that
   point's hit count.  The child SIGKILLs itself at exactly that
   instant (no atexit, no flushing).
3. **Verify pass** — a fresh child loads the checkpoint + WAL from the
   crashed working directory, rebuilds a *reference* index by replaying
   the op ledger from scratch (ops ``[:n_acked]`` or ``[:n_acked+1]`` —
   the one op in flight at the kill may have committed to the WAL
   without its ack reaching the ledger), and asserts the recovered index
   matches one of the two bit-for-bit: search ids AND distances, id
   space, tombstones, values.

Scenarios: ``mutable`` (single-device LSM), ``sharded`` (4-shard index
on 8 virtual CPU devices; curve-routed appends), ``engine`` (writes +
forced maintenance cycles through the serving engine — kills land
inside the compact/replay/swap protocol), ``compactor`` (the engine in
``compaction="subprocess"`` mode: the kill lands in the GRAND-child —
the out-of-process compactor — and the serving process must survive it:
the cycle fails, nothing swaps, results stay bit-equal to pre-maintenance,
and a disarmed retry succeeds.  Arming crosses the process boundary via
``REPRO_COMPACTOR_FAULTS`` / ``REPRO_COMPACTOR_FAULT_TRACE``, so the
workload child itself is never killed — exit 0 + DONE is the expected
outcome of every kill run in this lane.)

The parent stays import-light (no jax); children re-exec this file.

    PYTHONPATH=src python scripts/crash_check.py            # full battery
    PYTHONPATH=src python scripts/crash_check.py --scenario mutable
    PYTHONPATH=src python scripts/crash_check.py --quick    # subset, CI PR lane

Exit 0 = every kill produced a dead child AND a bit-equal recovery.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
)

DIM = 8
N_SHARDS = 4

# Op ledgers.  Pure data so the verify child can rebuild the reference
# exactly; ("save",) and ("maint",) are state-neutral for the id space.
OPS_MUTABLE = [
    ("insert", 24), ("insert", 16), ("delete", (3, 7, 11)), ("insert", 12),
    ("save",), ("insert", 10), ("delete", (0, 20, 40)), ("insert", 20),
    ("save",), ("insert", 8), ("delete", (55, 2)), ("insert", 6),
]
OPS_SHARDED = [
    ("insert", 12), ("delete", (3, 40, 17)), ("insert", 20),
    ("save",), ("insert", 9), ("delete", (64, 70)),
    ("save",), ("insert", 7), ("delete", (1, 90)),
]
OPS_ENGINE = [
    ("insert", 24), ("insert", 16), ("save",), ("insert", 12),
    ("delete", (3, 7, 30)), ("maint",), ("insert", 10),
    ("delete", (0, 41)), ("maint",), ("insert", 8),
]
OPS_COMPACTOR = [
    ("insert", 24), ("insert", 16), ("delete", (3, 7, 11)),
    ("maint",), ("insert", 12), ("delete", (0, 20)),
    ("maint",), ("insert", 10),
]


def _points(tag: int, m: int):
    import numpy as np

    rng = np.random.default_rng(10_000 + tag)
    pts = rng.normal(size=(m, DIM)).astype(np.float32)
    vals = rng.integers(0, 1_000, size=(m,)).astype(np.int32)
    return pts, vals


def _queries():
    import numpy as np

    return np.random.default_rng(77).normal(size=(16, DIM)).astype(np.float32)


def _config():
    from repro.core.types import ForestConfig
    from repro.index import IndexConfig

    return IndexConfig(
        forest=ForestConfig(n_trees=4, bits=4, key_bits=32, leaf_size=16)
    )


def _params():
    from repro.core.types import SearchParams

    return SearchParams(k1=16, k2=32, h=1, k=8)


def _fresh_index(scenario: str, mesh=None):
    """The workload's index, WAL-less; identical ctor in run + reference."""
    if scenario == "sharded":
        from repro.index.sharded_mutable import ShardedMutableHilbertIndex

        if mesh is None:
            from repro.launch.mesh import data_mesh

            mesh = data_mesh(N_SHARDS)
        pts, vals = _points(-1, 96)
        return ShardedMutableHilbertIndex.build(
            pts, _config(), mesh=mesh, values=vals,
            buffer_capacity=8, max_segments=4,
        )
    from repro.index.mutable import MutableHilbertIndex

    return MutableHilbertIndex(_config(), buffer_capacity=16, max_segments=4)


def _apply(idx, engine, ckpt: str, i: int, op) -> None:
    import numpy as np

    kind = op[0]
    writer = engine if engine is not None else idx
    if kind == "insert":
        pts, vals = _points(i, op[1])
        writer.insert(pts, vals)
    elif kind == "delete":
        writer.delete(np.asarray(op[1], np.int32))
    elif kind == "save":
        idx.save(ckpt)
    elif kind == "maint":
        engine.maintain_once(force=True)
    else:
        raise ValueError(f"unknown op {op!r}")


def _ledger_state(ops):
    """(next_id, dead_ids, values_by_id) from pure ledger bookkeeping."""
    nid, dead, values = 0, set(), {}
    for i, op in enumerate(ops):
        if op[0] == "insert":
            _, vals = _points(i, op[1])
            for v in vals:
                values[nid] = int(v)
                nid += 1
        elif op[0] == "delete":
            dead.update(int(x) for x in op[1])
    return nid, dead, values


# ---------------------------------------------------------------- children


def child_run(scenario: str, workdir: str) -> None:
    from repro.checkpoint import WalConfig

    ckpt = os.path.join(workdir, "ckpt")
    acks = os.path.join(workdir, "acks.jsonl")
    # huge sync_interval: fsync points must fire at deterministic record
    # counts, not wall-clock instants, or the kill replay drifts off the
    # trace pass
    wal_cfg = WalConfig(sync_every=4, sync_interval_ms=1e9)
    engine = None
    if scenario == "engine":
        from repro.serve.engine import MaintenancePolicy, RetrievalEngine

        idx = _fresh_index("mutable")
        idx.enable_wal(ckpt, wal_cfg)
        idx.save(ckpt)           # a base checkpoint to recover onto
        _ack(acks, -1)
        engine = RetrievalEngine(
            idx, _params(),
            maintenance=MaintenancePolicy(),
            start=False,         # synchronous: deterministic fault hits
        )
        ops = OPS_ENGINE
    else:
        idx = _fresh_index(scenario)
        idx.enable_wal(ckpt, wal_cfg)
        if scenario == "sharded":
            idx.save(ckpt)       # the corpus base is pre-WAL state
            _ack(acks, -1)
        ops = OPS_SHARDED if scenario == "sharded" else OPS_MUTABLE
    for i, op in enumerate(ops):
        cur = engine.index if engine is not None else idx
        _apply(cur, engine, ckpt, i, op)
        _ack(acks, i)
    print("DONE")


def child_run_compactor(workdir: str) -> None:
    """Serving engine in subprocess-compaction mode under an armed kill.

    When ``REPRO_COMPACTOR_FAULTS`` is set, the FIRST forced maintenance
    cycle's compactor child dies at the armed point; this process (the
    serving parent) must observe a failed cycle and nothing else: same
    epoch, same index object, bit-equal search results, replay log
    closed.  Disarming and retrying must then succeed — the exact
    backoff-and-retry path the maintainer thread takes.
    """
    import numpy as np

    from repro.serve.engine import (
        CompactionChildError,
        MaintenancePolicy,
        MaintenanceTimeout,
        RetrievalEngine,
    )

    acks = os.path.join(workdir, "acks.jsonl")
    armed = bool(os.environ.get("REPRO_COMPACTOR_FAULTS"))
    idx = _fresh_index("mutable")
    engine = RetrievalEngine(
        idx, _params(),
        maintenance=MaintenancePolicy(),
        compaction="subprocess",
        compaction_dir=os.path.join(workdir, "compact"),
        start=False,            # synchronous: deterministic fault hits
    )
    need_kill = armed
    for i, op in enumerate(OPS_COMPACTOR):
        if op[0] == "maint" and need_kill:
            pre_epoch = engine.epoch
            pre_index = engine.index
            qi, qd = (np.asarray(x) for x in engine.search(_queries()))
            try:
                engine.maintain_once(force=True)
                raise SystemExit(
                    "armed compactor kill did not fail the cycle"
                )
            except (CompactionChildError, MaintenanceTimeout) as e:
                print(f"cycle failed as armed: {type(e).__name__}: {e}")
            # the failed cycle must be invisible to serving
            assert engine.epoch == pre_epoch, "epoch moved on failed cycle"
            assert engine.index is pre_index, "index swapped on failed cycle"
            assert engine._write_log is None, "replay log left open"
            ri, rd = (np.asarray(x) for x in engine.search(_queries()))
            assert np.array_equal(qi, ri) and np.array_equal(qd, rd), (
                "results drifted across a failed maintenance cycle"
            )
            # disarm + retry: the maintainer's backoff path in miniature
            os.environ.pop("REPRO_COMPACTOR_FAULTS", None)
            need_kill = False
            assert engine.maintain_once(force=True), "disarmed retry no-op"
            assert engine.epoch == pre_epoch + 1
            _ack(acks, i)
            continue
        _apply(engine.index, engine, None, i, op)
        _ack(acks, i)
    engine.index.save(os.path.join(workdir, "final"))
    print("DONE")


def child_verify_compactor(workdir: str) -> None:
    """Full-ledger verification of the survivor's final saved state."""
    import numpy as np

    from repro.index.mutable import MutableHilbertIndex

    rec = MutableHilbertIndex.load(os.path.join(workdir, "final"))
    nid, dead, values = _ledger_state(OPS_COMPACTOR)
    assert rec._lsm.next_id == nid, (rec._lsm.next_id, nid)
    alive = np.ones(nid, np.bool_)
    alive[sorted(dead & set(range(nid)))] = False
    assert np.array_equal(np.asarray(rec._lsm.alive[:nid]), alive)
    got = np.asarray(rec._lsm.values[:nid])
    want = np.asarray([values[i] for i in range(nid)], got.dtype)
    assert np.array_equal(got, want)
    ids, _ = rec.search(_queries(), _params())
    ids = np.asarray(ids)
    valid = ids[ids >= 0]
    assert alive[valid].all(), "search returned a tombstoned id"
    print(f"VERIFIED full-ledger n_ops={len(OPS_COMPACTOR)}")


def _ack(path: str, i: int) -> None:
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, (json.dumps({"i": i}) + "\n").encode())
        os.fsync(fd)
    finally:
        os.close(fd)


def _recover(scenario: str, ckpt: str):
    """Load checkpoint + WAL replay; bootstrap from empty when the crash
    beat the first manifest commit (the WAL then holds the whole history)."""
    from repro.checkpoint import wal as wal_lib
    from repro.index.mutable import MutableHilbertIndex, replay_wal_records

    if scenario == "sharded":
        from repro.index.sharded_mutable import ShardedMutableHilbertIndex
        from repro.launch.mesh import data_mesh

        try:
            # recover on the WRITER's mesh: defaulting to all local devices
            # would trigger a compact-on-load reshard (a legitimate but
            # geometry-rewriting path) and break segment-level bit-equality
            return ShardedMutableHilbertIndex.load(
                ckpt, mesh=data_mesh(N_SHARDS)
            )
        except FileNotFoundError:
            # killed inside the very first manifest commit: rebuild the
            # (pre-WAL, deterministic) corpus base and replay everything
            idx = _fresh_index("sharded")
    else:
        try:
            return MutableHilbertIndex.load(ckpt)
        except FileNotFoundError:
            idx = _fresh_index("mutable")
    records, wal = wal_lib.open_and_recover(wal_lib.wal_path(ckpt))
    replay_wal_records(idx, records)
    idx._wal = wal
    return idx


def _state_equal(a, b) -> bool:
    import jax
    import numpy as np

    qa = _queries()
    ia, da = (np.asarray(jax.device_get(x)) for x in a.search(qa, _params()))
    ib, db = (np.asarray(jax.device_get(x)) for x in b.search(qa, _params()))
    return (
        np.array_equal(ia, ib)
        and da.tobytes() == db.tobytes()
        and a._lsm.next_id == b._lsm.next_id
        and np.array_equal(a._lsm.alive, b._lsm.alive)
        and np.array_equal(a._lsm.values, b._lsm.values)
    )


def child_verify(scenario: str, workdir: str) -> None:
    import numpy as np

    ckpt = os.path.join(workdir, "ckpt")
    acks = os.path.join(workdir, "acks.jsonl")
    n_acked = 0
    if os.path.exists(acks):
        with open(acks) as f:
            n_acked = sum(
                1 for line in f
                if line.strip() and json.loads(line)["i"] >= 0
            )
    rec = _recover(scenario, ckpt)
    ops = {"mutable": OPS_MUTABLE, "sharded": OPS_SHARDED,
           "engine": OPS_ENGINE}[scenario]

    if scenario == "engine":
        # Maintenance (compact + swap) rewrites segment geometry, so the
        # invariant is id-space exactness, not segment-level bit-equality.
        for j in (n_acked, min(n_acked + 1, len(ops))):
            nid, dead, values = _ledger_state(ops[:j])
            if rec._lsm.next_id != nid:
                continue
            alive = np.ones(nid, np.bool_)
            alive[sorted(dead & set(range(nid)))] = False
            if not np.array_equal(np.asarray(rec._lsm.alive[:nid]), alive):
                continue
            got = np.asarray(rec._lsm.values[:nid])
            want = np.asarray([values[i] for i in range(nid)], got.dtype)
            if not np.array_equal(got, want):
                continue
            ids, _ = rec.search(_queries(), _params())
            ids = np.asarray(ids)
            valid = ids[ids >= 0]
            assert alive[valid].all(), "search returned a tombstoned id"
            print(f"VERIFIED j={j} n_acked={n_acked}")
            return
        raise SystemExit(f"no ledger prefix matches (n_acked={n_acked})")

    mesh = rec.mesh if scenario == "sharded" else None
    for j in (n_acked, min(n_acked + 1, len(ops))):
        ref = _fresh_index(scenario, mesh=mesh)
        for i, op in enumerate(ops[:j]):
            if op[0] in ("save", "maint"):
                continue        # state-neutral; must not touch the workdir
            _apply(ref, None, None, i, op)
        if _state_equal(rec, ref):
            print(f"VERIFIED j={j} n_acked={n_acked}")
            return
    raise SystemExit(
        f"recovered state matches neither ops[:{n_acked}] nor "
        f"ops[:{n_acked + 1}] bit-for-bit"
    )


# ------------------------------------------------------------------ parent


def _child_cmd(mode: str, scenario: str, workdir: str):
    return [sys.executable, os.path.abspath(__file__),
            "--child", mode, "--scenario", scenario, "--workdir", workdir]


def _child_env(scenario: str, **extra) -> dict:
    env = dict(os.environ)
    env.pop("REPRO_FAULTS", None)
    env.pop("REPRO_FAULT_TRACE", None)
    env.pop("REPRO_COMPACTOR_FAULTS", None)
    env.pop("REPRO_COMPACTOR_FAULT_TRACE", None)
    env["JAX_PLATFORMS"] = "cpu"
    if scenario == "sharded":
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=8"
                            ).strip()
    env.update(extra)
    return env


def _run(cmd, env, timeout=600):
    return subprocess.run(cmd, env=env, timeout=timeout,
                          capture_output=True, text=True)


def run_battery(scenarios, point_filter, keep: bool) -> int:
    failures = []
    for scenario in scenarios:
        # the compactor lane kills the GRAND-child (the out-of-process
        # compactor): arming crosses the process boundary via the
        # REPRO_COMPACTOR_* channel, and the workload child is expected
        # to SURVIVE every kill (exit 0 + DONE), proving the serving
        # process shrugs the dead compactor off
        grandchild = scenario == "compactor"
        trace_key = (
            "REPRO_COMPACTOR_FAULT_TRACE" if grandchild
            else "REPRO_FAULT_TRACE"
        )
        fault_key = (
            "REPRO_COMPACTOR_FAULTS" if grandchild else "REPRO_FAULTS"
        )
        root = tempfile.mkdtemp(prefix=f"crash_{scenario}_")
        trace_dir = os.path.join(root, "trace")
        os.makedirs(trace_dir)
        trace_file = os.path.join(trace_dir, "trace.txt")
        print(f"[{scenario}] trace pass ...", flush=True)
        r = _run(_child_cmd("run", scenario, trace_dir),
                 _child_env(scenario, **{trace_key: trace_file}))
        if r.returncode != 0 or "DONE" not in r.stdout:
            print(r.stdout[-2000:] + r.stderr[-2000:])
            failures.append((scenario, "<trace>", "trace pass failed"))
            continue
        hits: dict = {}
        with open(trace_file) as f:
            for line in f:
                name = line.strip()
                if name:
                    hits[name] = hits.get(name, 0) + 1
        points = sorted(hits)
        if scenario == "engine":
            # wal.*/ckpt.* windows are already covered by the plain-index
            # matrices; the engine lane targets the swap protocol itself
            points = [p for p in points if p.startswith("engine.")]
        if grandchild:
            # the compactor lane targets the child protocol's own
            # windows; the ckpt.* save/load machinery the child also
            # crosses is covered by the plain-index matrices
            points = [p for p in points if p.startswith("compactor.")]
        if point_filter:
            points = [p for p in points if any(s in p for s in point_filter)]
        print(f"[{scenario}] {len(points)} fault points: "
              + ", ".join(f"{p} x{hits[p]}" for p in points), flush=True)
        # hit counters are per-process: every compactor child starts
        # fresh, so only hit=1 can fire in the grand-child lane
        matrix = [
            (p, h) for p in points
            for h in ([1] if grandchild
                      else sorted({max(1, hits[p] // 2), hits[p]}))
        ]
        for point, hit in matrix:
            wd = os.path.join(root, f"{point.replace('.', '_')}_{hit}")
            os.makedirs(wd)
            plan = f"{point}@{hit}=kill"
            r = _run(_child_cmd("run", scenario, wd),
                     _child_env(scenario, **{fault_key: plan}))
            if grandchild:
                if r.returncode != 0 or "DONE" not in r.stdout:
                    failures.append((scenario, point,
                                     "serving child did not survive the "
                                     f"compactor kill (rc={r.returncode}): "
                                     + r.stdout[-300:] + r.stderr[-300:]))
                    print(f"  [{scenario}] {plan:<44} PARENT DIED",
                          flush=True)
                    continue
            elif r.returncode != -signal.SIGKILL:
                failures.append((scenario, point,
                                 f"child not killed (rc={r.returncode}); "
                                 "fault point never reached?"))
                print(f"  [{scenario}] {plan:<44} NOT KILLED", flush=True)
                continue
            v = _run(_child_cmd("verify", scenario, wd),
                     _child_env(scenario))
            if v.returncode != 0:
                failures.append((scenario, point,
                                 v.stdout[-400:] + v.stderr[-400:]))
                print(f"  [{scenario}] {plan:<44} RECOVERY FAILED", flush=True)
                continue
            verdict = v.stdout.strip().splitlines()[-1]
            print(f"  [{scenario}] kill @ {plan:<44} {verdict}", flush=True)
        if not keep:
            import shutil

            shutil.rmtree(root, ignore_errors=True)
    print()
    if failures:
        print(f"crash matrix: {len(failures)} FAILURE(S)")
        for scenario, point, msg in failures:
            print(f"  {scenario}/{point}: {msg}")
        return 1
    print("crash matrix: all kills recovered bit-equal, "
          "zero acknowledged writes lost")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--child", choices=["run", "verify"], default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--workdir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--scenario", action="append", default=None,
                    choices=["mutable", "sharded", "engine", "compactor"],
                    help="restrict to these scenarios (default: all)")
    ap.add_argument("--point", action="append", default=None,
                    help="substring filter on fault-point names")
    ap.add_argument("--quick", action="store_true",
                    help="mutable scenario only — the PR-lane subset")
    ap.add_argument("--keep", action="store_true",
                    help="keep crashed workdirs for inspection")
    args = ap.parse_args()
    if args.child:
        scenario = (args.scenario or ["mutable"])[0]
        if scenario == "compactor":
            if args.child == "run":
                child_run_compactor(args.workdir)
            else:
                child_verify_compactor(args.workdir)
        elif args.child == "run":
            child_run(scenario, args.workdir)
        else:
            child_verify(scenario, args.workdir)
        return 0
    scenarios = args.scenario or (
        ["mutable"] if args.quick else
        ["mutable", "sharded", "engine", "compactor"]
    )
    return run_battery(scenarios, args.point or [], args.keep)


if __name__ == "__main__":
    raise SystemExit(main())
