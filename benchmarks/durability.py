"""Durability cost curves: WAL ack-latency overhead and recovery time.

Three questions a deployment asks before turning the WAL on:

* **What does a durably acknowledged row cost?**  Small (32-row) buffered
  appends with a WAL at ``sync_every`` ∈ {1, 8, 32, 128} vs. no WAL.
  The baseline here is a bare ``memcpy`` into the write buffer (~10µs),
  so this curve shows the *absolute* price of framing + ``write()`` and
  where ``fsync`` lands: at ``sync_every=1`` every ack waits on the disk
  (full power-loss durability, ~ms); group commit amortises it across a
  window whose loss a SIGKILL cannot cause (the page cache survives
  process death).
* **What does durability cost sustained ingest?**  Appends at segment
  granularity (each acknowledged batch fills the buffer exactly, so
  every ack includes the Hilbert-sort seal — the true amortised cost of
  a searchable, durable row).  The bench **asserts** the default group
  commit stays **< 10% p50 overhead** on this append path.
* **What does a crash cost at restart?**  ``load()`` replays the WAL
  tail beyond the last checkpoint; recovery wall-clock vs. tail length
  (0 / 64 / 256 records on top of the same base checkpoint).

Results land in ``BENCH_durability.json`` (cwd).  ``--smoke`` shrinks to
CI scale (also runnable via ``python -m benchmarks.run durability``).
"""

import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.checkpoint import WalConfig
from repro.index import ForestConfig, IndexConfig, MutableHilbertIndex

_SYNC_EVERY = (1, 8, 32, 128)
_DEFAULT_SYNC = 32


def _percentiles(samples_ms):
    s = np.sort(np.asarray(samples_ms))
    return (float(s[int(0.50 * (len(s) - 1))]),
            float(s[int(0.99 * (len(s) - 1))]))


def _append_run(cfg, capacity, data, wal_dir=None, sync_every=None):
    """Per-append ms over a batched stream; identical ops in every arm.

    ``data`` is (appends, batch, d): batch < capacity measures the
    buffered-row path, batch == capacity makes every append seal a
    segment (sustained-ingest granularity).
    """
    mut = MutableHilbertIndex(cfg, buffer_capacity=capacity, max_segments=8)
    if wal_dir is not None:
        mut.enable_wal(wal_dir, WalConfig(sync_every=sync_every))
    mut.insert(data[0])               # warm the insert path / jit caches
    out = []
    for i in range(1, data.shape[0]):
        t0 = time.perf_counter()
        mut.insert(data[i])
        out.append(1000 * (time.perf_counter() - t0))
    if mut.wal is not None:
        mut.wal.close()
    return out


def _sweep(result_key, result, cfg, capacity, data, root, syncs):
    base = _append_run(cfg, capacity, data)
    p50_0, p99_0 = _percentiles(base)
    arm_out = {"batch_rows": int(data.shape[1]),
               "no_wal": {"p50_ms": p50_0, "p99_ms": p99_0}}
    print(f"{result_key}:no_wal,{p50_0:.3f},{p99_0:.3f}", flush=True)
    for se in syncs:
        wd = os.path.join(root, f"{result_key}_sync_{se}")
        arm = _append_run(cfg, capacity, data, wal_dir=wd, sync_every=se)
        p50, p99 = _percentiles(arm)
        arm_out[f"sync_{se}"] = {
            "p50_ms": p50, "p99_ms": p99,
            "p50_overhead_pct": round(100 * (p50 - p50_0) / p50_0, 2),
        }
        print(f"{result_key}:sync_{se},{p50:.3f},{p99:.3f}", flush=True)
    result[result_key] = arm_out
    return arm_out


def main(smoke: bool = False) -> dict:
    smoke = smoke or "--smoke" in sys.argv[1:]
    if smoke:
        d, row_appends, seal_cap, seal_appends = 32, 150, 1024, 24
        fcfg = ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16)
        buf_cap, tails = 8192, (0, 64, 128)
    else:
        d, row_appends, seal_cap, seal_appends = 64, 600, 4096, 48
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=256, leaf_size=32)
        buf_cap, tails = 32768, (0, 64, 256)
    cfg = IndexConfig(forest=fcfg)
    rng = np.random.default_rng(0)

    result: dict = {}
    print("arm,p50_ms,p99_ms")
    root = tempfile.mkdtemp(prefix="bench_durability_")
    try:
        # -- buffered-row granularity: the absolute WAL price curve --------
        rows = rng.normal(size=(row_appends, 32, d)).astype(np.float32)
        _sweep("buffered", result, cfg, buf_cap, rows, root, _SYNC_EVERY)

        # -- sealed granularity: sustained durable ingest (asserted) -------
        seals = rng.normal(
            size=(seal_appends, seal_cap, d)).astype(np.float32)
        sealed = _sweep("sealed", result, cfg, seal_cap, seals, root,
                        (_DEFAULT_SYNC,))

        # -- recovery wall-clock vs WAL tail length ------------------------
        result["recovery"] = []
        for tail in tails:
            wd = os.path.join(root, f"recover_{tail}")
            mut = MutableHilbertIndex(cfg, buffer_capacity=buf_cap,
                                      max_segments=8)
            mut.enable_wal(wd, WalConfig(sync_every=_DEFAULT_SYNC))
            mut.insert(rng.normal(size=(2048, d)).astype(np.float32))
            mut.save(wd)              # WAL truncates here: tail starts empty
            n_base = mut._lsm.next_id
            tdata = rng.normal(size=(tail, 32, d)).astype(np.float32)
            for i in range(tail):     # one WAL record per post-save append
                mut.insert(tdata[i])
            mut.wal.close()
            t0 = time.perf_counter()
            rec = MutableHilbertIndex.load(wd)
            load_s = time.perf_counter() - t0
            assert rec._lsm.next_id == n_base + tail * 32
            result["recovery"].append(
                {"tail_records": tail, "load_s": round(load_s, 4)}
            )
            print(f"recover tail={tail:>4} records: {load_s:.3f}s",
                  flush=True)
    finally:
        shutil.rmtree(root, ignore_errors=True)

    overhead = sealed[f"sync_{_DEFAULT_SYNC}"]["p50_overhead_pct"]
    result["default_sync_every"] = _DEFAULT_SYNC
    result["default_p50_overhead_pct"] = overhead
    print(f"\ndefault group-commit (sync_every={_DEFAULT_SYNC}) sustained-"
          f"ingest append p50 overhead: {overhead:.1f}%", flush=True)
    assert overhead < 10.0, (
        f"WAL default group-commit costs {overhead:.1f}% append p50 "
        f"(budget: <10%)"
    )
    with open("BENCH_durability.json", "w") as f:
        json.dump(result, f, indent=2)
    print("wrote BENCH_durability.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
