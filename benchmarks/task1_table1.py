"""Paper Table 1: Task-1 recall/search-time across hyperparameter combos.

PUBMED23 (23M×384) is exercised shape-only in the dry-run; here the
recall/time trade-off curve is reproduced at container scale (N=20k, d=384,
MiniLM-like low-intrinsic-dim geometry) over a scaled (n, k1, k2, h) grid.
The paper's qualitative claims validated: recall@30 > 0.7 achievable;
recall rises with n/k1/k2; time rises roughly linearly in n·k1.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import ForestConfig, HilbertIndex, IndexConfig, SearchParams

N, D, Q = 20000, 384, 500


def main(rows=None):
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=64, seed=0
    )
    gt, _ = ann_datasets.exact_knn(data, queries, 30)
    data_j, queries_j = jnp.asarray(data), jnp.asarray(queries)

    grid = rows or [
        # (n_trees, k1, k2, h) — scaled analogue of Table 1's 16 rows
        (8, 32, 192, 2),
        (8, 48, 256, 2),
        (16, 32, 192, 2),
        (16, 48, 256, 2),
        (16, 64, 384, 2),
        (24, 48, 256, 2),
        (24, 64, 384, 3),
        (32, 64, 512, 3),
    ]
    built = {}
    print("n,k1,k2,h,recall@30,search_ms_per_query,build_s")
    out = []
    for (nt, k1, k2, h) in grid:
        if nt not in built:
            cfg = IndexConfig(
                forest=ForestConfig(n_trees=nt, bits=4, key_bits=448,
                                    leaf_size=32, seed=0),
                store_points=False,
            )
            t0 = time.time()
            built[nt] = (HilbertIndex.build(data_j, cfg), time.time() - t0)
        idx, tb = built[nt]
        params = SearchParams(k1=k1, k2=k2, h=h, k=30)
        t0 = time.time()
        ids, _ = idx.search(queries_j, params)
        ids.block_until_ready()
        ts = time.time() - t0
        rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
        print(f"{nt},{k1},{k2},{h},{rec:.3f},{1000*ts/Q:.2f},{tb:.1f}")
        out.append((nt, k1, k2, h, rec, ts))
    # paper band: the upper rows must clear recall@30 > 0.7
    assert max(r[4] for r in out) > 0.7
    return out


if __name__ == "__main__":
    main()
