"""Sharded-search benchmark: per-stage breakdown + gather-vs-tree merge A/B.

Quantifies the sharded search path so the scaling trajectory is
machine-readable:

* **latency** — p50/p99 per-batch wall time for (a) the single-device
  fused path over the full corpus, (b) a standalone single-shard index
  over n/S rows (the per-device work in isolation), (c) the IN-SITU shard
  core — ``search_local()``, the identical shard_map dispatch stopped
  before any collective — and (d) the mesh-wide merged path, all after
  jit warmup and wrapped in obs spans so a trace shows the same split;
* **merge A/B** — the flat ``merge="gather"`` reference vs the butterfly
  ``merge="tree"`` reduction (± distance-bound pruning): p50/p99, the
  per-variant dispatch/recompile accounting delta (a recompile on a
  warmed variant would invalidate its timings), and an analytic
  bytes-over-interconnect model per variant — the quantity the tree
  exists to shrink, which wall time on a single-host CPU harness cannot
  see (see the ``machine`` note in the artifact);
* **merge-tax guard** — asserts merged p50 <= 2.5x the in-situ shard-core
  p50: the reduction must stay a tax, never the dominant cost.  Runs on
  every CI pass of this bench (the sharded-parity job);
* **dispatches per chunk** — structural: the WHOLE sharded pipeline
  (per-shard fused searches + deflation + reduction) stays exactly ONE
  XLA dispatch per query chunk (asserted, not assumed);
* **resident bytes** — total vs per-device residency of the sharded
  layout.

Results land in ``BENCH_sharded.json`` (cwd).  ``--smoke`` shrinks to CI
scale; also runnable via ``python -m benchmarks.run sharded``.

The measurement runs in a re-exec'd subprocess with
``--xla_force_host_platform_device_count=8`` so it works from any parent
process (``benchmarks.run`` has usually initialized jax single-device
already); on a host that already has multiple real devices the flag is
harmless — it only affects the CPU platform.
"""

import json
import os
import subprocess
import sys

_WORKER_ENV = "_SHARDED_BENCH_WORKER"

# The reduction must stay a tax on the shard core, never the dominant
# cost: merged p50 <= this multiple of the in-situ shard-core p50.
MERGE_TAX_LIMIT = 2.5


def main(smoke: bool = False) -> dict:
    if os.environ.get(_WORKER_ENV) != "1":
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p
        )
        cmd = [sys.executable, "-m", "benchmarks.sharded_search"]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, cwd=os.getcwd())
        if r.returncode != 0:
            raise SystemExit(f"sharded bench worker failed ({r.returncode})")
        with open("BENCH_sharded.json") as f:
            return json.load(f)
    return _worker(smoke)


def _worker(smoke: bool) -> dict:
    import math
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import ann_datasets
    from repro.index import (
        ForestConfig,
        HilbertIndex,
        IndexConfig,
        SearchParams,
        ShardedHilbertIndex,
    )
    from repro.launch.mesh import data_mesh
    from repro.obs import accounting_delta, accounting_snapshot, span

    n_shards = min(8, jax.device_count())
    if smoke:
        n, d, q, reps = 8192, 48, 128, 5
        fcfg = ForestConfig(n_trees=4, bits=4, key_bits=192, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=2, k=10)
    else:
        n, d, q, reps = 65536, 192, 512, 20
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=384, leaf_size=32)
        params = SearchParams(k1=48, k2=192, h=2, k=30)
    cfg = IndexConfig(forest=fcfg, store_points=False)
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        n, q, d, n_clusters=32, seed=0
    )
    queries = jnp.asarray(queries)

    def timed(search, label):
        search()  # warm the jit cache
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            with span(f"bench.sharded.{label}", rows=q):
                out_arrays = search()
                jax.block_until_ready(out_arrays)
            out.append(time.perf_counter() - t0)
        s = np.sort(np.asarray(out))
        return {
            "p50_ms": 1000 * float(s[int(0.50 * (len(s) - 1))]),
            "p99_ms": 1000 * float(s[int(0.99 * (len(s) - 1))]),
            "qps": q / float(s[int(0.50 * (len(s) - 1))]),
        }

    single = HilbertIndex.build(jnp.asarray(data), cfg)
    lat_single = timed(lambda: single.search(queries, params), "single_full")

    local_n = -(-n // n_shards)
    shard_standalone = HilbertIndex.build(jnp.asarray(data[:local_n]), cfg)
    lat_standalone = timed(
        lambda: shard_standalone.search(queries, params), "shard_standalone"
    )

    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), cfg, mesh=data_mesh(n_shards)
    )

    # Per-stage breakdown, measured IN SITU: search_local() is the same
    # shard_map dispatch as search() minus the cross-shard reduction, so
    # merged - local is the reduction stage on the real dispatch shape.
    # (On this CPU harness the 8 virtual devices share the host's cores,
    # so the shard core includes their serialization — which is exactly
    # why the standalone single-shard number above is NOT the right guard
    # denominator.)
    lat_core = timed(
        lambda: sharded.search_local(queries, params), "shard_core_in_situ"
    )

    variants = {
        "gather": dict(merge="gather"),
        "tree": dict(merge="tree"),
        "tree_prune": dict(merge="tree", prune=True),
    }
    merge_ab = {}
    for name, kw in variants.items():
        sharded.search(queries, params, **kw)  # warm before snapshotting
        acct0 = accounting_snapshot()
        merge_ab[name] = timed(
            lambda kw=kw: sharded.search(queries, params, **kw), name
        )
        merge_ab[name]["dispatch_accounting_delta"] = accounting_delta(
            acct0, accounting_snapshot()
        )
        rc = merge_ab[name]["dispatch_accounting_delta"][
            "recompiles_by_site"
        ].get("sharded.search", 0)
        assert rc == 0, f"variant {name} recompiled {rc}x after warmup"
        merge_ab[name]["reduction_tax_ms"] = round(
            merge_ab[name]["p50_ms"] - lat_core["p50_ms"], 3
        )

    # Analytic interconnect model (per query, both directions summed over
    # devices; 8 bytes = int32 id + fp32 distance per candidate).  The
    # gather path moves every shard's inflated pool everywhere; the tree
    # moves k rows per hop for log2(S) hops (+ one scalar pmin when
    # pruning).  This is the cost that dominates once shards sit on
    # separate hosts — wall time on one CPU cannot show it.
    k_local = sharded._k_local(params)
    hops = int(math.log2(n_shards))
    bytes_model = {
        "per_candidate_bytes": 8,
        "k_inflated": k_local,
        "gather_bytes_per_query": 8 * n_shards * (n_shards - 1) * k_local,
        "tree_bytes_per_query": 8 * n_shards * hops * params.k,
        "tree_prune_extra_bytes_per_query": 8 * n_shards * hops,
        "tree_hops": hops,
    }
    bytes_model["gather_over_tree"] = round(
        bytes_model["gather_bytes_per_query"]
        / bytes_model["tree_bytes_per_query"], 2
    )

    lat_merged = merge_ab["tree" if sharded.config.merge != "gather"
                          else "gather"]
    lat_merged = {key: lat_merged[key] for key in ("p50_ms", "p99_ms", "qps")}
    sharded.search(queries, params)
    assert sharded.last_dispatch_count == 1  # whole pipeline, one dispatch

    # Merge-tax guard: the cross-shard reduction must stay a bounded tax
    # on the in-situ shard core.  CI runs this bench in the
    # sharded-parity job, so a regression fails the build.
    tax = lat_merged["p50_ms"] / lat_core["p50_ms"]
    assert tax <= MERGE_TAX_LIMIT, (
        f"merged p50 {lat_merged['p50_ms']:.1f}ms is {tax:.2f}x the in-situ "
        f"shard-core p50 {lat_core['p50_ms']:.1f}ms (limit {MERGE_TAX_LIMIT}x)"
    )

    rep = sharded.memory_report()
    result = {
        "n": n,
        "d": d,
        "q": q,
        "n_shards": n_shards,
        "n_trees": fcfg.n_trees,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        "machine": {
            "platform": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "devices": jax.device_count(),
            "note": (
                "virtual CPU devices share the host cores: the in-situ "
                "shard core serializes S per-shard searches, and collective "
                "cost is memory traffic, not interconnect — see "
                "bytes_per_hop_model for the multi-host quantity"
            ),
        },
        "latency": {
            "single_device_full": lat_single,
            "single_shard_standalone": lat_standalone,
            "shard_local_core": lat_core,
            "sharded_merged": lat_merged,
        },
        "merge_ab": merge_ab,
        "bytes_per_hop_model": bytes_model,
        "merge_tax_guard": {
            "merged_p50_over_shard_core_p50": round(tax, 3),
            "limit": MERGE_TAX_LIMIT,
        },
        "dispatches_per_chunk": {
            "single_device_fused": 1,
            "sharded_merged": sharded.last_dispatch_count,
        },
        "resident_bytes": {
            "sharded_total": rep["resident_bytes"],
            "per_device": rep["per_device_bytes"][0],
            "replicated": rep["replicated_bytes"],
            "per_device_over_total": (
                rep["per_device_bytes"][0] / rep["resident_bytes"]
            ),
            "single_device_baseline": (
                single.memory_report()["resident_bytes"]
            ),
        },
        "dispatch_accounting": accounting_snapshot(),
    }
    with open("BENCH_sharded.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_sharded.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
