"""Sharded-search benchmark: per-shard vs merged latency, residency split.

Quantifies the PR-4 tentpole so the scaling trajectory is machine-readable:

* **latency** — p50/p99 per-batch wall time for (a) the single-device fused
  path over the full corpus, (b) the shard-local core alone (one fused
  search over a corpus of n/S rows — the per-device work), and (c) the
  mesh-wide sharded path (shard_map fused per-shard search + cross-shard
  ``merge_topk``), all after jit warmup;
* **dispatches per chunk** — structural: both the single fused path and the
  WHOLE sharded pipeline (8 per-shard searches + all_gather + merge) cost
  exactly ONE XLA dispatch per query chunk (asserted, not assumed);
* **resident bytes** — total vs per-device residency of the sharded layout
  (the row-partition is what divides the paper's 16 GB single-box budget
  across the mesh).

Results land in ``BENCH_sharded.json`` (cwd).  ``--smoke`` shrinks to CI
scale; also runnable via ``python -m benchmarks.run sharded``.

The measurement runs in a re-exec'd subprocess with
``--xla_force_host_platform_device_count=8`` so it works from any parent
process (``benchmarks.run`` has usually initialized jax single-device
already); on a host that already has multiple real devices the flag is
harmless — it only affects the CPU platform.
"""

import json
import os
import subprocess
import sys

_WORKER_ENV = "_SHARDED_BENCH_WORKER"


def main(smoke: bool = False) -> dict:
    if os.environ.get(_WORKER_ENV) != "1":
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p
        )
        cmd = [sys.executable, "-m", "benchmarks.sharded_search"]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, cwd=os.getcwd())
        if r.returncode != 0:
            raise SystemExit(f"sharded bench worker failed ({r.returncode})")
        with open("BENCH_sharded.json") as f:
            return json.load(f)
    return _worker(smoke)


def _worker(smoke: bool) -> dict:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import ann_datasets
    from repro.index import (
        ForestConfig,
        HilbertIndex,
        IndexConfig,
        SearchParams,
        ShardedHilbertIndex,
    )
    from repro.launch.mesh import data_mesh
    from repro.obs import accounting_snapshot

    n_shards = min(8, jax.device_count())
    if smoke:
        n, d, q, reps = 8192, 48, 128, 5
        fcfg = ForestConfig(n_trees=4, bits=4, key_bits=192, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=2, k=10)
    else:
        n, d, q, reps = 65536, 192, 512, 20
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=384, leaf_size=32)
        params = SearchParams(k1=48, k2=192, h=2, k=30)
    cfg = IndexConfig(forest=fcfg, store_points=False)
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        n, q, d, n_clusters=32, seed=0
    )
    queries = jnp.asarray(queries)

    def timed(search):
        search()  # warm the jit cache
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            ids, _ = search()
            jnp.asarray(ids).block_until_ready()
            out.append(time.perf_counter() - t0)
        s = np.sort(np.asarray(out))
        return {
            "p50_ms": 1000 * float(s[int(0.50 * (len(s) - 1))]),
            "p99_ms": 1000 * float(s[int(0.99 * (len(s) - 1))]),
            "qps": q / float(s[int(0.50 * (len(s) - 1))]),
        }

    single = HilbertIndex.build(jnp.asarray(data), cfg)
    lat_single = timed(lambda: single.search(queries, params))

    local_n = -(-n // n_shards)
    shard_local = HilbertIndex.build(jnp.asarray(data[:local_n]), cfg)
    lat_local = timed(lambda: shard_local.search(queries, params))

    sharded = ShardedHilbertIndex.build(
        jnp.asarray(data), cfg, mesh=data_mesh(n_shards)
    )
    lat_sharded = timed(lambda: sharded.search(queries, params))
    sharded.search(queries, params)
    assert sharded.last_dispatch_count == 1  # whole pipeline, one dispatch

    rep = sharded.memory_report()
    result = {
        "n": n,
        "d": d,
        "q": q,
        "n_shards": n_shards,
        "n_trees": fcfg.n_trees,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        "latency": {
            "single_device_full": lat_single,
            "shard_local_core": lat_local,
            "sharded_merged": lat_sharded,
        },
        "dispatches_per_chunk": {
            "single_device_fused": 1,
            "sharded_merged": sharded.last_dispatch_count,
        },
        "resident_bytes": {
            "sharded_total": rep["resident_bytes"],
            "per_device": rep["per_device_bytes"][0],
            "replicated": rep["replicated_bytes"],
            "per_device_over_total": (
                rep["per_device_bytes"][0] / rep["resident_bytes"]
            ),
            "single_device_baseline": (
                single.memory_report()["resident_bytes"]
            ),
        },
        "dispatch_accounting": accounting_snapshot(),
    }
    with open("BENCH_sharded.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_sharded.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
