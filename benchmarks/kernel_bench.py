"""Kernel microbenchmarks: Hamming filter + fused 4-bit ADC distance.

On this CPU container the Pallas kernels execute in interpret mode (Python —
not timing-relevant); the numbers that matter here are (a) the jnp-oracle
throughput on CPU as a sanity floor and (b) the ANALYTIC TPU roofline for
the kernel's tiling, derived from bytes/flops per tile (see EXPERIMENTS.md
§Kernels): both kernels are HBM-bandwidth-bound on v5e, so the model is
bytes_touched / 819 GB/s.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.kernels.hamming import hamming_matrix
from repro.kernels.qdist import qdist

HBM_BW = 819e9


def _time(f, *args, iters=5):
    f(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters


def main():
    rng = np.random.default_rng(0)
    print("name,cpu_us_per_call,tpu_roofline_us,bytes_per_call")

    # hamming: Q=512 queries × C=65536 candidates × 384-bit sketches
    q, c, w = 512, 65536, 12
    a = jnp.asarray(rng.integers(0, 2**32, (q, w), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, (c, w), dtype=np.uint32))
    t = _time(lambda x, y: hamming_matrix(x, y), a, b)
    nbytes = (q * w + c * w) * 4 + q * c * 4  # reads + output
    print(f"hamming_{q}x{c}x384b,{1e6*t:.0f},{1e6*nbytes/HBM_BW:.0f},{nbytes}")

    # qdist: Q=512 × C=16384 × d=384, 4-bit codes
    cq, cc, d = 512, 16384, 384
    data = rng.normal(size=(cc, d)).astype(np.float32)
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    queries = jnp.asarray(rng.normal(size=(cq, d)).astype(np.float32))
    t = _time(lambda x: qdist(x, codes, quant.centroids), queries)
    nbytes = cq * d * 4 + cc * d // 2 + cq * cc * 4  # fp32 q + packed codes + out
    print(f"qdist_{cq}x{cc}x{d},{1e6*t:.0f},{1e6*nbytes/HBM_BW:.0f},{nbytes}")

    # interpret-mode correctness spot check (kernels vs oracle) at bench shapes
    got = hamming_matrix(a[:8], b[:256], use_kernel=True, interpret=True)
    ref = hamming_matrix(a[:8], b[:256])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    print("kernel_interpret_check,ok,,")


if __name__ == "__main__":
    main()
