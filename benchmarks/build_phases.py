"""Paper §3.2 preprocessing-time split (scaled).

The paper reports, for 23M×384: forest (160 trees) 38m56s, sketches 5s,
quantization 2m32s, master sort 14s.  The scaled reproduction checks the
ORDERING (forest ≫ quantization > master sort ≳ sketches) and prints the
split for the container-scale problem, using the facade's instrumented
build (``repro.index.build_with_timings``) — the same code path as
``HilbertIndex.build``.
"""

import jax.numpy as jnp

from repro.data import ann_datasets
from repro.index import ForestConfig, IndexConfig, build_with_timings

N, D, TREES = 20000, 384, 16


def main():
    data = jnp.asarray(ann_datasets.lowrank_embeddings(N, D, seed=0))
    cfg = IndexConfig(
        forest=ForestConfig(n_trees=TREES, bits=4, key_bits=448, leaf_size=32)
    )

    _, t = build_with_timings(data, cfg)

    print("phase,seconds")
    print(f"forest({TREES} trees),{t['forest']:.2f}")
    print(f"quantization,{t['quantization']:.2f}")
    print(f"sketches,{t['sketches']:.2f}")
    print(f"master_sort,{t['master_sort']:.2f}")
    # paper ordering: forest dominates; sketches are near-free
    assert t["forest"] > t["quantization"]
    assert t["forest"] > 5 * t["sketches"]
    assert t["forest"] > 5 * t["master_sort"]
    return dict(forest=t["forest"], quant=t["quantization"],
                sketch=t["sketches"], master=t["master_sort"])


if __name__ == "__main__":
    main()
