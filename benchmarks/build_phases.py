"""Paper §3.2 preprocessing-time split (scaled).

The paper reports, for 23M×384: forest (160 trees) 38m56s, sketches 5s,
quantization 2m32s, master sort 14s.  The scaled reproduction checks the
ORDERING (forest ≫ quantization > master sort ≳ sketches) and prints the
split for the container-scale problem.
"""

import time

import jax
import jax.numpy as jnp

from repro.core import forest as forest_lib
from repro.core import hilbert, quantize, sketch
from repro.core.types import ForestConfig
from repro.data import ann_datasets

N, D, TREES = 20000, 384, 16


def main():
    data = jnp.asarray(ann_datasets.lowrank_embeddings(N, D, seed=0))
    cfg = ForestConfig(n_trees=TREES, bits=4, key_bits=448, leaf_size=32)

    t0 = time.time()
    f = forest_lib.build_forest(data, cfg)
    jax.block_until_ready(f.orders)
    t_forest = time.time() - t0

    t0 = time.time()
    quant = quantize.fit(data, bits=4)
    codes = quantize.encode(quant, data)
    jax.block_until_ready(codes)
    t_quant = time.time() - t0

    t0 = time.time()
    sks = sketch.sketches_from_codes(codes)
    jax.block_until_ready(sks)
    t_sketch = time.time() - t0

    t0 = time.time()
    order, _ = hilbert.hilbert_sort(
        data, bits=cfg.bits, key_bits=cfg.key_bits, lo=f.lo, hi=f.hi
    )
    jax.block_until_ready(order)
    t_master = time.time() - t0

    print("phase,seconds")
    print(f"forest({TREES} trees),{t_forest:.2f}")
    print(f"quantization,{t_quant:.2f}")
    print(f"sketches,{t_sketch:.2f}")
    print(f"master_sort,{t_master:.2f}")
    # paper ordering: forest dominates; sketches are near-free
    assert t_forest > t_quant
    assert t_forest > 5 * t_sketch
    assert t_forest > 5 * t_master
    return dict(forest=t_forest, quant=t_quant, sketch=t_sketch, master=t_master)


if __name__ == "__main__":
    main()
