"""Search hot-path benchmark: packed+fused vs unpacked+per-tree-loop.

Quantifies the PR-3 tentpole so the perf trajectory is machine-readable
from here on:

* **latency/QPS** — p50/p99 per-batch wall time and queries/sec for
  (a) the fused single-dispatch packed path (``search()``, the default) and
  (b) the per-tree-loop + unpacked-stage-2 reference (``fused=False``),
  both after jit warmup;
* **dispatches per chunk** — the structural XLA-dispatch count of each
  path: fused is 1 regardless of ``n_trees``; the loop pays
  ``n_trees + 2`` (query sketch + one per tree + stage 2);
* **resident bytes** — actual packed residency vs the unpacked uint8
  baseline layout this PR replaced.

Results land in ``BENCH_search.json`` (cwd).  ``--smoke`` shrinks to CI
scale; also runnable via ``python -m benchmarks.run search``.
"""

import json
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import ForestConfig, HilbertIndex, IndexConfig, SearchParams
from repro.obs import accounting_snapshot


def _time_path(index, queries, params, reps, **kw):
    index.search(queries, params, **kw)  # warm the jit cache
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        ids, _ = index.search(queries, params, **kw)
        jnp.asarray(ids).block_until_ready()
        out.append(time.perf_counter() - t0)
    s = np.sort(np.asarray(out))
    p50 = float(s[int(0.50 * (len(s) - 1))])
    p99 = float(s[int(0.99 * (len(s) - 1))])
    return {
        "p50_ms": 1000 * p50,
        "p99_ms": 1000 * p99,
        "qps": queries.shape[0] / p50,
    }


def main(smoke: bool = False) -> dict:
    if smoke:
        n, d, q, reps = 4000, 64, 64, 5
        fcfg = ForestConfig(n_trees=4, bits=4, key_bits=256, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=2, k=10)
    else:
        n, d, q, reps = 50000, 384, 512, 30
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=448, leaf_size=32)
        params = SearchParams(k1=48, k2=192, h=2, k=30)
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        n, q, d, n_clusters=32, seed=0
    )
    queries = jnp.asarray(queries)
    cfg = IndexConfig(forest=fcfg, store_points=False)
    index = HilbertIndex.build(jnp.asarray(data), cfg)
    rep = index.memory_report()

    fused = _time_path(index, queries, params, reps)
    loop = _time_path(index, queries, params, reps, fused=False)

    # Exactness cross-check: the two paths must agree bit-for-bit on XLA.
    ids_f, d2_f = index.search(queries, params, backend="xla")
    ids_l, d2_l = index.search(queries, params, backend="xla", fused=False)
    assert np.array_equal(np.asarray(ids_f), np.asarray(ids_l))
    assert np.array_equal(np.asarray(d2_f), np.asarray(d2_l))

    result = {
        "n": n,
        "d": d,
        "q": q,
        "n_trees": fcfg.n_trees,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        # one jitted fused_search_chunk call vs sketch + n_trees stage-1
        # calls + stage-2 (the structural dispatch count per query chunk)
        "dispatches_per_chunk": {
            "fused_scan": 1,
            "per_tree_loop": fcfg.n_trees + 2,
        },
        "stage1_dispatches_per_chunk": {
            "fused_scan": 1,
            "per_tree_loop": fcfg.n_trees,
        },
        "latency": {"fused_packed": fused, "per_tree_loop_unpacked": loop},
        "packed_vs_unpacked_p50_speedup": loop["p50_ms"] / fused["p50_ms"],
        "resident_bytes": {
            "packed": rep["resident_bytes"],
            "codes_packed": rep["codes_bytes"],
            "codes_unpacked_baseline": n * d,  # uint8 layout pre-PR-3
            "unpacked_layout_total": rep["resident_bytes"]
            - rep["codes_bytes"] + n * d,
        },
        "bit_identical_paths": True,
        # measured (not structural) dispatch/recompile counters for the
        # whole run, from the obs layer's per-site accounting
        "dispatch_accounting": accounting_snapshot(),
    }
    result["resident_bytes"]["savings_frac"] = 1.0 - (
        result["resident_bytes"]["packed"]
        / result["resident_bytes"]["unpacked_layout_total"]
    )
    with open("BENCH_search.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_search.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
