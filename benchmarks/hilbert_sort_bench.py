"""Hilbert-sort scaling: O(n log n), dimension-independent key cost.

The 2016 fast-Hilbert-sort claim: average O(n log n) independent of
dimensionality.  The TPU formulation pays O(n·d·bits) vectorized key
generation + O(n log n) sort; this bench shows (a) near-linear scaling in n
(log factor invisible at these sizes) and (b) key-gen cost linear in d but
a small fraction of total build at paper-like d.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hilbert
from repro.data import ann_datasets


def main():
    print("n,d,keys_ms,sort_ms,total_ms")
    for n, d in [(10_000, 96), (20_000, 96), (40_000, 96),
                 (20_000, 192), (20_000, 384), (20_000, 768)]:
        pts = jnp.asarray(ann_datasets.lowrank_embeddings(n, d, seed=1))
        lo, hi = jnp.min(pts, 0), jnp.max(pts, 0)
        kb = min(448, d * 4)

        t0 = time.time()
        keys = hilbert.hilbert_keys(pts, bits=4, key_bits=kb, lo=lo, hi=hi)
        keys.block_until_ready()
        tk = time.time() - t0

        t0 = time.time()
        order, _ = hilbert.hilbert_sort(pts, bits=4, key_bits=kb, lo=lo, hi=hi)
        order.block_until_ready()
        tt = time.time() - t0
        print(f"{n},{d},{1000*tk:.0f},{1000*(tt-tk):.0f},{1000*tt:.0f}")


if __name__ == "__main__":
    main()
