"""Sharded churn workload: streaming writes on the row-partitioned index.

The sharded analogue of ``benchmarks.churn`` — quantifies what a
multi-device streaming deployment cares about:

* **recall-vs-rebuild** — after each churn phase, recall@k of the live
  sharded LSM state against exact brute force and against a from-scratch
  ``ShardedHilbertIndex`` build over the same live points, plus the
  rebuild's wall-clock cost the mutable layout avoids paying;
* **one-dispatch invariant** — every streaming search runs in exactly ONE
  jitted dispatch per query chunk regardless of generation count
  (asserted, not assumed);
* **routing locality** — the fraction of streamed inserts whose
  curve-range routing agrees with where a full re-partition would place
  them (how well the frozen bounds track the data);
* **compaction endpoint** — post-compact latency/recall, where search is
  bit-equal to the fresh rebuild (asserted).

Results land in ``BENCH_sharded_churn.json`` (cwd).  ``--smoke`` shrinks
to CI scale; also runnable via ``python -m benchmarks.run sharded_churn``.
Like ``benchmarks.sharded_search``, the measurement re-execs itself in a
subprocess with ``--xla_force_host_platform_device_count=8``.
"""

import json
import os
import subprocess
import sys

_WORKER_ENV = "_SHARDED_CHURN_BENCH_WORKER"


def main(smoke: bool = False) -> dict:
    if os.environ.get(_WORKER_ENV) != "1":
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p
        )
        cmd = [sys.executable, "-m", "benchmarks.sharded_churn"]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, cwd=os.getcwd())
        if r.returncode != 0:
            raise SystemExit(f"sharded churn bench worker failed ({r.returncode})")
        with open("BENCH_sharded_churn.json") as f:
            return json.load(f)
    return _worker(smoke)


def _worker(smoke: bool) -> dict:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import distributed
    from repro.data import ann_datasets
    from repro.index import (
        ForestConfig,
        IndexConfig,
        SearchParams,
        ShardedHilbertIndex,
        ShardedMutableHilbertIndex,
    )
    from repro.launch.mesh import data_mesh
    from repro.obs import accounting_snapshot

    n_shards = min(8, jax.device_count())
    if smoke:
        n0, d, q, batches, batch, reps = 2048, 24, 32, 2, 256, 3
        fcfg = ForestConfig(n_trees=2, bits=4, key_bits=96, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=1, k=10)
        capacity, max_segments = 128, 4
    else:
        n0, d, q, batches, batch, reps = 32768, 96, 256, 5, 4096, 15
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=384, leaf_size=32)
        params = SearchParams(k1=32, k2=192, h=2, k=10)
        capacity, max_segments = 1024, 8
    cfg = IndexConfig(forest=fcfg)
    mesh = data_mesh(n_shards)
    total = n0 + batches * batch
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        total, q, d, n_clusters=32, seed=0
    )
    data = np.asarray(data)
    queries_j = jnp.asarray(queries)
    rng = np.random.default_rng(0)

    def timed(search):
        search()  # warm the jit caches for this LSM shape
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            ids, _ = search()
            jnp.asarray(ids).block_until_ready()
            out.append(1000 * (time.perf_counter() - t0))
        s = np.sort(np.asarray(out))
        return (float(s[int(0.50 * (len(s) - 1))]),
                float(s[int(0.99 * (len(s) - 1))]))

    mut = ShardedMutableHilbertIndex.build(
        jnp.asarray(data[:n0]), cfg, mesh=mesh,
        buffer_capacity=capacity, max_segments=max_segments,
    )
    live_ids = np.arange(n0, dtype=np.int64)
    live_pts = data[:n0]
    route_agree = []

    rows = []
    print("phase,n_live,n_segments,n_buffered,recall_mut,recall_rebuild,"
          "rebuild_s,p50_ms,p99_ms,dispatches")
    for phase in range(batches + 1):
        p50, p99 = timed(lambda: mut.search(queries_j, params))
        mut.search(queries_j, params)
        dispatches = mut.last_dispatch_count
        assert dispatches == -(-q // cfg.query_chunk), dispatches

        gt, _ = ann_datasets.exact_knn(live_pts, np.asarray(queries), params.k)
        hits, _ = mut.search(queries_j, params)
        pos_of = {int(e): i for i, e in enumerate(live_ids)}
        pos = np.vectorize(lambda e: pos_of.get(int(e), -1))(np.asarray(hits))
        rec = ann_datasets.recall_at_k(pos, gt)
        t0 = time.time()
        fresh = ShardedHilbertIndex.build(jnp.asarray(live_pts), cfg, mesh=mesh)
        rebuild_s = time.time() - t0
        frec = ann_datasets.recall_at_k(
            np.asarray(fresh.search(queries_j, params)[0]), gt
        )
        row = {
            "phase": phase, "n_live": mut.n_live,
            "n_segments": mut.n_segments, "n_buffered": mut.n_buffered,
            "recall_mut": float(rec), "recall_rebuild": float(frec),
            "rebuild_s": float(rebuild_s), "p50_ms": p50, "p99_ms": p99,
            "dispatches_per_chunk": int(dispatches),
        }
        rows.append(row)
        print(f"{phase},{mut.n_live},{mut.n_segments},{mut.n_buffered},"
              f"{rec:.3f},{frec:.3f},{rebuild_s:.2f},{p50:.1f},{p99:.1f},"
              f"{dispatches}", flush=True)

        if phase == batches:
            break
        # churn: insert a batch (measuring routing locality), expire ~8%.
        # Locality = how often the FROZEN partition bounds send a new row
        # to the same shard a full re-partition of live+batch would.
        s = n0 + phase * batch
        batch_pts = data[s : s + batch]
        if mut._bounds is not None:
            routed = mut._route(batch_pts)
            union = np.concatenate([live_pts, batch_pts])
            parts = distributed.hilbert_partition(
                jnp.asarray(union), fcfg, mesh=mesh, n_shards=n_shards
            )
            owner = np.zeros((len(union),), np.int32)
            for si, g in enumerate(parts):
                owner[np.asarray(g)] = si
            route_agree.append(float(np.mean(
                routed == owner[len(live_pts):]
            )))
        new = mut.insert(batch_pts)
        drop = rng.choice(live_ids, len(live_ids) // 12, replace=False)
        mut.delete(drop)
        keep = ~np.isin(live_ids, drop)
        live_ids = np.concatenate([live_ids[keep], new])
        live_pts = np.concatenate([live_pts[keep], batch_pts])

    # compacted endpoint: bit-equal to the fresh rebuild
    t0 = time.time()
    mut.compact()
    compact_s = time.time() - t0
    p50c, p99c = timed(lambda: mut.search(queries_j, params))
    order = np.argsort(live_ids, kind="stable")
    live_ids_s, live_pts_s = live_ids[order], live_pts[order]
    fresh = ShardedHilbertIndex.build(jnp.asarray(live_pts_s), cfg, mesh=mesh)
    fi, fd = fresh.search(queries_j, params)
    mi, md = mut.search(queries_j, params)
    exp = np.where(np.asarray(fi) >= 0,
                   live_ids_s[np.clip(np.asarray(fi), 0, None)], -1)
    bit_equal = bool(
        np.array_equal(exp, np.asarray(mi))
        and np.array_equal(np.asarray(fd), np.asarray(md))
    )
    assert bit_equal, "post-compact search must equal the fresh rebuild"
    print(f"compacted,{mut.n_live},{mut.n_segments},0,bit_equal={bit_equal},"
          f",{compact_s:.2f},{p50c:.1f},{p99c:.1f},1", flush=True)

    rep = mut.memory_report()
    result = {
        "n0": n0, "d": d, "q": q, "batch": batch, "batches": batches,
        "n_shards": n_shards, "buffer_capacity": capacity,
        "max_segments": max_segments,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        "phases": rows,
        "routing_agreement_mean": (
            float(np.mean(route_agree)) if route_agree else None
        ),
        "compacted": {
            "compact_s": float(compact_s), "p50_ms": p50c, "p99_ms": p99c,
            "bit_equal_to_fresh_rebuild": bit_equal,
        },
        "memory": {
            "sharded_bytes": rep["sharded_bytes"],
            "replicated_bytes": rep["replicated_bytes"],
            "per_device_bytes": rep["per_device_bytes"][0],
            "buffer_bytes": rep["buffer_bytes"],
        },
        "dispatch_accounting": accounting_snapshot(),
    }
    with open("BENCH_sharded_churn.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_sharded_churn.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
