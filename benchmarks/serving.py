"""Concurrent write+query serving load: the engine's reason to exist.

``BENCH_sharded_churn.json`` showed WHY serving needs an engine: query
p50 degrades ~8x as sealed generations pile up, and a synchronous
``compact()`` stalls the caller for seconds.  This benchmark measures the
fix — the same concurrent write+query load is driven through
:class:`repro.serve.RetrievalEngine` in three phases on the
sharded-mutable layout:

* **baseline** — query stream only, no writes: the latency floor;
* **churn** — a background writer streams inserts/deletes while queries
  run, background maintenance OFF: generations accumulate and tail
  latency creeps (what the seed's serving path would experience);
* **churn_maintained** — same write load with the maintenance thread ON:
  tier compaction runs on a shadow copy off the query path and the
  serving index is atomically swapped, so the generation count stays
  bounded while NO query ever waits on a compaction.

Two latency series are reported per phase:

* **request** — submit -> result wall time (queue + serve-lock wait
  included): what a caller experiences end to end;
* **search** — the search execution itself (the engine's
  ``batch_latency``, timed inside the serve lock): the query path
  proper, which is what the swap protocol keeps off the compaction.

plus the maintained/baseline p99 ratios for both.  The acceptance
target is maintained p99 within 2x of the no-write baseline.  CAVEAT
for this CPU harness: the "device" here IS the host cores, so the
shadow compaction unavoidably contends with serving for the same
silicon and inflates both series while it runs — on a real accelerator
the compact's build executes beside the serving device, which is the
deployment the 2x target describes.  The artifact records both ratios
honestly; track the trend, not the absolute, on CPU.

Results land in ``BENCH_serving.json`` (cwd).  ``--smoke`` shrinks to
CI scale AND drops to the single-device ``MutableHilbertIndex`` layout:
the engine is layout-agnostic (the sharded engine paths are exercised
by ``tests/test_engine.py`` in the same CI job), and sustained
write+compile load over 8 *virtual* CPU devices starves XLA's
collective rendezvous for minutes at a time — a harness artifact, not
a serving property.  The full run uses the 8-shard sharded-mutable
layout and re-execs itself in a subprocess with
``--xla_force_host_platform_device_count=8``.  Also runnable via
``python -m benchmarks.run serving``.
"""

import json
import os
import subprocess
import sys

_WORKER_ENV = "_SERVING_BENCH_WORKER"


def main(smoke: bool = False) -> dict:
    if os.environ.get(_WORKER_ENV) != "1":
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        if not smoke:  # smoke runs the single-device mutable layout
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + " --xla_force_host_platform_device_count=8"
            ).strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p
        )
        cmd = [sys.executable, "-m", "benchmarks.serving"]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, cwd=os.getcwd())
        if r.returncode != 0:
            raise SystemExit(f"serving bench worker failed ({r.returncode})")
        with open("BENCH_serving.json") as f:
            return json.load(f)
    return _worker(smoke)


def _worker(smoke: bool) -> dict:
    import threading
    import time

    import jax
    import numpy as np

    from repro.data import ann_datasets
    from repro.index import (
        ForestConfig,
        IndexConfig,
        MutableHilbertIndex,
        SearchParams,
        ShardedMutableHilbertIndex,
    )
    from repro.launch.mesh import data_mesh
    from repro.serve import MaintenancePolicy, RetrievalEngine
    from repro.serve.metrics import LatencyRecorder, percentiles

    n_shards = 1 if smoke else min(8, jax.device_count())
    if smoke:
        n0, d, requests, q_batch = 4096, 24, 150, 32
        fcfg = ForestConfig(n_trees=2, bits=4, key_bits=96, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=1, k=10)
        capacity, write_batch, warm_swaps, warm_cap_s = 256, 64, 2, 240.0
    else:
        n0, d, requests, q_batch = 32768, 96, 300, 256
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=384, leaf_size=32)
        params = SearchParams(k1=32, k2=192, h=2, k=10)
        capacity, write_batch, warm_swaps, warm_cap_s = 1024, 512, 2, 600.0
    # writer pacing: one batch per ~write_pause — heavy but bounded churn
    # (an unthrottled writer saturates the serve lock and measures lock
    # starvation, not serving)
    write_pause = 0.05
    cfg = IndexConfig(forest=fcfg)
    mesh = None if n_shards == 1 else data_mesh(n_shards)
    # spare rows for the churn writers (they wrap within this region)
    total = n0 + 64 * write_batch
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        total, q_batch, d, n_clusters=32, seed=0
    )
    data, queries = np.asarray(data), np.asarray(queries)
    policy = MaintenancePolicy(
        max_segments=4, max_tombstone_ratio=0.5, poll_interval_s=0.05
    )

    def run_phase(name, *, churn, maintained):
        if mesh is None:
            index = MutableHilbertIndex(
                cfg, buffer_capacity=capacity, max_segments=16
            )
            index.insert(data[:n0])
            index.compact()  # start from one sealed segment
        else:
            index = ShardedMutableHilbertIndex.build(
                data[:n0], cfg, mesh=mesh,
                buffer_capacity=capacity, max_segments=16,
            )
        eng = RetrievalEngine(
            index, params,
            maintenance=policy if maintained else None, start=True,
        )
        stop = threading.Event()
        inserted_ids: list = []

        def writer():
            s = n0
            while not stop.is_set():
                ids = eng.insert(data[s : s + write_batch])
                inserted_ids.append(ids)
                if len(inserted_ids) > 2:
                    old = inserted_ids.pop(0)  # rolling-window expiry
                    eng.delete(old)
                s += write_batch
                if s + write_batch > total:
                    s = n0  # wrap within the spare region
                if stop.wait(write_pause):
                    return

        th = None
        if churn:
            th = threading.Thread(target=writer)
            th.start()
        # Warm-up (unmeasured): a long-running deployment's jit caches
        # hold every recurring LSM shape.  The maintained phase reaches
        # that steady state only after a couple of full maintenance
        # cycles (compact shapes + post-swap buffer buckets), so keep
        # serving unmeasured until `warm_swaps` swaps have landed (time
        # capped); other phases just warm the query-shape dispatch.
        warm_t0 = time.perf_counter()
        warm_requests = 0
        while True:
            eng.search(queries)
            warm_requests += 1
            if not maintained or eng.metrics.counter("swaps") >= warm_swaps:
                break
            if time.perf_counter() - warm_t0 > warm_cap_s:
                break
        # fresh search-exec ring: measure the query path post-warmup only
        eng.metrics.batch_latency = LatencyRecorder()
        warm_swaps_seen = eng.metrics.counter("swaps")
        warm_s = time.perf_counter() - warm_t0
        lat = []
        t0 = time.perf_counter()
        try:
            for r in range(requests):
                ticket = eng.submit(queries)
                ticket.result(timeout=600)
                lat.append(ticket.latency_ms)
        finally:
            if th is not None:
                stop.set()
                th.join()
            eng.stop(drain=True)
        wall_s = time.perf_counter() - t0
        stats = eng.maintenance_stats()
        search_ms = eng.metrics.batch_latency.samples()
        row = {
            "phase": name,
            "requests": requests,
            "warmup_requests": warm_requests,
            "warmup_s": float(warm_s),
            "rows_per_request": q_batch,
            "wall_s": float(wall_s),
            "qps": float(requests / wall_s),
            **percentiles(lat),
            "max_ms": float(np.max(lat)),
            "search": percentiles(search_ms),
            "swaps_in_window": (
                eng.metrics.counter("swaps") - warm_swaps_seen
            ),
            "swaps": eng.metrics.counter("swaps"),
            "maintenance_runs": eng.metrics.counter("maintenance_runs"),
            "inserts": eng.metrics.counter("inserts"),
            "deletes": eng.metrics.counter("deletes"),
            "end_segments": int(stats.get("n_segments", 0)),
            "end_live": int(stats.get("n_live", 0)),
        }
        print(
            f"{name}: p50={row['p50']:.1f}ms p99={row['p99']:.1f}ms "
            f"p999={row['p999']:.1f}ms qps={row['qps']:.1f} "
            f"swaps={row['swaps']} segments={row['end_segments']} "
            f"(inserts={row['inserts']})",
            flush=True,
        )
        return row

    print(f"serving load: {requests} requests x {q_batch} queries, "
          f"{n_shards} shard(s), corpus n0={n0} d={d}", flush=True)
    baseline = run_phase("baseline", churn=False, maintained=False)
    churn = run_phase("churn", churn=True, maintained=False)
    maintained = run_phase("churn_maintained", churn=True, maintained=True)

    ratio_churn = churn["p99"] / max(baseline["p99"], 1e-9)
    ratio_maintained = maintained["p99"] / max(baseline["p99"], 1e-9)
    s_ratio_churn = (churn["search"]["p99"]
                     / max(baseline["search"]["p99"], 1e-9))
    s_ratio_maintained = (maintained["search"]["p99"]
                          / max(baseline["search"]["p99"], 1e-9))
    result = {
        "n0": n0, "d": d, "n_shards": n_shards,
        "layout": "mutable" if mesh is None else "sharded_mutable",
        "requests": requests, "q_batch": q_batch,
        "write_batch": write_batch, "buffer_capacity": capacity,
        "write_pause_s": write_pause,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        "policy": {"max_segments": policy.max_segments,
                   "max_tombstone_ratio": policy.max_tombstone_ratio},
        "phases": [baseline, churn, maintained],
        "p99_ratio_churn_vs_baseline": float(ratio_churn),
        "p99_ratio_maintained_vs_baseline": float(ratio_maintained),
        "search_p99_ratio_churn_vs_baseline": float(s_ratio_churn),
        "search_p99_ratio_maintained_vs_baseline": float(s_ratio_maintained),
        "maintained_within_2x_of_baseline": bool(ratio_maintained <= 2.0),
        "maintained_search_within_2x_of_baseline": bool(
            s_ratio_maintained <= 2.0
        ),
        "cpu_caveat": (
            "host==device on this harness: the shadow compact contends "
            "with serving for the same cores while it runs (see module "
            "docstring); on an accelerator the compact builds beside the "
            "serving device"
        ),
    }
    print(f"\np99 ratios vs baseline: request churn={ratio_churn:.2f}x "
          f"maintained={ratio_maintained:.2f}x | search "
          f"churn={s_ratio_churn:.2f}x maintained={s_ratio_maintained:.2f}x "
          f"(target: maintained <= 2x)", flush=True)
    with open("BENCH_serving.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_serving.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
