"""Concurrent write+query serving load: the engine's reason to exist.

``BENCH_sharded_churn.json`` showed WHY serving needs an engine: query
p50 degrades ~8x as sealed generations pile up, and a synchronous
``compact()`` stalls the caller for seconds.  This benchmark measures the
fix — the same concurrent write+query load is driven through
:class:`repro.serve.RetrievalEngine` in three phases on the
sharded-mutable layout:

* **baseline** — query stream only, no writes: the latency floor;
* **churn** — a background writer streams inserts/deletes while queries
  run, background maintenance OFF: generations accumulate and tail
  latency creeps (what the seed's serving path would experience);
* **churn_maintained** — same write load with the maintenance thread ON:
  tier compaction runs on a shadow copy off the query path and the
  serving index is atomically swapped, so the generation count stays
  bounded while NO query ever waits on a compaction;
* **churn_maintained_subprocess** — the reader-concurrency A/B against
  the previous phase: identical load, but the shadow compacts in a CHILD
  process (``compaction="subprocess"``) and two serve workers execute
  batches concurrently under the shared read side of the engine's
  reader-writer lock.  The in-thread phase is the PR-6 architecture's
  number; this phase is the rw-lock + out-of-process one.  Each
  maintained phase's swap timeline also records per-phase
  ``*_locked`` booleans, from which the artifact asserts the serve lock
  was held exclusively ONLY during snapshot and swap — never during the
  compact or the catch-up replay;
* **baseline_obs** — the baseline load with span tracing toggled per
  request (interleaved A/B within one phase): the traced-vs-untraced
  p50 delta is the tracing/metrics tax, clean of cross-phase drift;
* **baseline_probe** — tracing ON plus a 25% online recall probe: its
  rolling recall is checked against an offline exact evaluation, and its
  p50 delta prices the probe's shadow scorer (which on this CPU harness
  contends with serving for cores).  All of it lands in the artifact's
  ``observability`` block.  Dispatch/recompile accounting is on in every
  phase; each phase reports its post-warmup per-site deltas.

Two latency series are reported per phase:

* **request** — submit -> result wall time (queue + serve-lock wait
  included): what a caller experiences end to end;
* **search** — the search execution itself (the engine's
  ``batch_latency``, timed inside the serve lock): the query path
  proper, which is what the swap protocol keeps off the compaction.

plus the maintained/baseline p99 ratios for both.  The acceptance
target is maintained p99 within 2x of the no-write baseline.  CAVEAT
for this CPU harness: the "device" here IS the host cores, so the
shadow compaction unavoidably contends with serving for the same
silicon and inflates both series while it runs — on a real accelerator
the compact's build executes beside the serving device, which is the
deployment the 2x target describes.  The artifact records both ratios
honestly; track the trend, not the absolute, on CPU.

Results land in ``BENCH_serving.json`` (cwd).  ``--smoke`` shrinks to
CI scale AND drops to the single-device ``MutableHilbertIndex`` layout:
the engine is layout-agnostic (the sharded engine paths are exercised
by ``tests/test_engine.py`` in the same CI job), and sustained
write+compile load over 8 *virtual* CPU devices starves XLA's
collective rendezvous for minutes at a time — a harness artifact, not
a serving property.  The full run uses the 8-shard sharded-mutable
layout and re-execs itself in a subprocess with
``--xla_force_host_platform_device_count=8``.  Also runnable via
``python -m benchmarks.run serving``.
"""

import json
import os
import subprocess
import sys

_WORKER_ENV = "_SERVING_BENCH_WORKER"


def main(smoke: bool = False) -> dict:
    if os.environ.get(_WORKER_ENV) != "1":
        env = dict(os.environ)
        env[_WORKER_ENV] = "1"
        if not smoke:  # smoke runs the single-device mutable layout
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + " --xla_force_host_platform_device_count=8"
            ).strip()
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"),
                        env.get("PYTHONPATH", "")) if p
        )
        cmd = [sys.executable, "-m", "benchmarks.serving"]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, cwd=os.getcwd())
        if r.returncode != 0:
            raise SystemExit(f"serving bench worker failed ({r.returncode})")
        with open("BENCH_serving.json") as f:
            return json.load(f)
    return _worker(smoke)


def _worker(smoke: bool) -> dict:
    import threading
    import time

    import jax
    import numpy as np

    from repro.data import ann_datasets
    from repro.index import (
        ForestConfig,
        IndexConfig,
        MutableHilbertIndex,
        SearchParams,
        ShardedMutableHilbertIndex,
    )
    from repro import obs
    from repro.launch.mesh import data_mesh
    from repro.obs import (
        RecallProbeConfig,
        accounting_snapshot,
        dispatch_counts,
        exact_topk,
        live_points,
        recall_at_k,
        recompile_counts,
    )
    from repro.serve import MaintenancePolicy, RetrievalEngine
    from repro.serve.metrics import LatencyRecorder, percentiles

    n_shards = 1 if smoke else min(8, jax.device_count())
    if smoke:
        n0, d, requests, q_batch = 4096, 24, 150, 32
        fcfg = ForestConfig(n_trees=2, bits=4, key_bits=96, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=1, k=10)
        capacity, write_batch, warm_swaps, warm_cap_s = 256, 64, 2, 240.0
    else:
        n0, d, requests, q_batch = 32768, 96, 300, 256
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=384, leaf_size=32)
        params = SearchParams(k1=32, k2=192, h=2, k=10)
        capacity, write_batch, warm_swaps, warm_cap_s = 1024, 512, 2, 600.0
    # writer pacing: one batch per ~write_pause — heavy but bounded churn
    # (an unthrottled writer saturates the serve lock and measures lock
    # starvation, not serving)
    write_pause = 0.05
    cfg = IndexConfig(forest=fcfg)
    mesh = None if n_shards == 1 else data_mesh(n_shards)
    # spare rows for the churn writers (they wrap within this region)
    total = n0 + 64 * write_batch
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        total, q_batch, d, n_clusters=32, seed=0
    )
    data, queries = np.asarray(data), np.asarray(queries)
    policy = MaintenancePolicy(
        max_segments=4, max_tombstone_ratio=0.5, poll_interval_s=0.05
    )

    def run_phase(name, *, churn, maintained, obs_on=False,
                  obs_ab=False, recall_fraction=None,
                  compaction="thread", serve_threads=1):
        # obs_on: the full observability stack — span tracing, a recall
        # probe sampling served batches — is live for the measured window
        # (the A/B against the identical obs-off phase is the overhead
        # number the artifact reports).  Dispatch/recompile accounting is
        # unconditional (the scopes are always on), so every phase gets
        # post-warmup recompile deltas for free.
        obs.default_tracer().enabled = bool(obs_on)
        if mesh is None:
            index = MutableHilbertIndex(
                cfg, buffer_capacity=capacity, max_segments=16
            )
            index.insert(data[:n0])
            index.compact()  # start from one sealed segment
        else:
            index = ShardedMutableHilbertIndex.build(
                data[:n0], cfg, mesh=mesh,
                buffer_capacity=capacity, max_segments=16,
            )
        eng = RetrievalEngine(
            index, params,
            maintenance=policy if maintained else None,
            recall=(RecallProbeConfig(fraction=recall_fraction, seed=0)
                    if recall_fraction else None),
            compaction=compaction,
            serve_threads=serve_threads,
            start=True,
        )
        stop = threading.Event()
        inserted_ids: list = []

        def writer():
            s = n0
            while not stop.is_set():
                ids = eng.insert(data[s : s + write_batch])
                inserted_ids.append(ids)
                if len(inserted_ids) > 2:
                    old = inserted_ids.pop(0)  # rolling-window expiry
                    eng.delete(old)
                s += write_batch
                if s + write_batch > total:
                    s = n0  # wrap within the spare region
                if stop.wait(write_pause):
                    return

        th = None
        if churn:
            th = threading.Thread(target=writer)
            th.start()
        # Warm-up (unmeasured): a long-running deployment's jit caches
        # hold every recurring LSM shape.  The maintained phase reaches
        # that steady state only after a couple of full maintenance
        # cycles (compact shapes + post-swap buffer buckets), so keep
        # serving unmeasured until `warm_swaps` swaps have landed (time
        # capped); other phases just warm the query-shape dispatch.
        warm_t0 = time.perf_counter()
        warm_requests = 0
        while True:
            eng.search(queries)
            warm_requests += 1
            if not maintained or eng.metrics.counter("swaps") >= warm_swaps:
                break
            if time.perf_counter() - warm_t0 > warm_cap_s:
                break
        # fresh search-exec ring: measure the query path post-warmup only
        eng.metrics.batch_latency = LatencyRecorder()
        warm_swaps_seen = eng.metrics.counter("swaps")
        warm_s = time.perf_counter() - warm_t0
        d_warm, r_warm = dispatch_counts(), recompile_counts()
        lat = []
        lat_ab = {True: [], False: []}  # obs_ab: traced vs untraced
        t0 = time.perf_counter()
        try:
            for r in range(requests):
                if obs_ab:
                    # interleaved A/B: alternate tracing per request so
                    # both series see identical load, cache, and thermal
                    # conditions — phase-to-phase drift on a busy CPU
                    # host dwarfs the tracing tax, an interleave doesn't
                    obs.default_tracer().enabled = (r % 2 == 0)
                ticket = eng.submit(queries)
                ticket.result(timeout=600)
                lat.append(ticket.latency_ms)
                if obs_ab:
                    lat_ab[r % 2 == 0].append(ticket.latency_ms)
        finally:
            if th is not None:
                stop.set()
                th.join()
            eng.stop(drain=True)
        wall_s = time.perf_counter() - t0
        stats = eng.maintenance_stats()
        search_ms = eng.metrics.batch_latency.samples()
        # per-site dispatch/recompile deltas over the measured window:
        # the steady-state invariant says the *search* sites stay at 0
        # recompiles after warmup (seal/compact sites may legitimately
        # compile fresh generation shapes under churn)
        d_end, r_end = dispatch_counts(), recompile_counts()
        dispatches_meas = {
            s: d_end[s] - d_warm.get(s, 0)
            for s in d_end if d_end[s] - d_warm.get(s, 0)
        }
        recompiles_meas = {
            s: r_end[s] - r_warm.get(s, 0)
            for s in r_end if r_end[s] - r_warm.get(s, 0)
        }
        online_recall = offline_recall = None
        if eng.recall_probe is not None:
            # stop(drain=True) above scored the stragglers; compare the
            # rolling online estimate against an offline exact evaluation
            # of the same queries on the final index state
            online_recall = float(eng.recall_probe.recall())
            final = eng.index
            direct_ids, _ = final.search(queries, params)
            truth = live_points(final)
            if truth is not None:
                exact = exact_topk(queries, truth[0], truth[1], params.k)
                offline_recall = float(
                    recall_at_k(np.asarray(direct_ids), exact).mean()
                )
        row = {
            "phase": name,
            "requests": requests,
            "warmup_requests": warm_requests,
            "warmup_s": float(warm_s),
            "rows_per_request": q_batch,
            "wall_s": float(wall_s),
            "qps": float(requests / wall_s),
            **percentiles(lat),
            "max_ms": float(np.max(lat)),
            "search": percentiles(search_ms),
            "swaps_in_window": (
                eng.metrics.counter("swaps") - warm_swaps_seen
            ),
            "swaps": eng.metrics.counter("swaps"),
            "maintenance_runs": eng.metrics.counter("maintenance_runs"),
            "inserts": eng.metrics.counter("inserts"),
            "deletes": eng.metrics.counter("deletes"),
            "end_segments": int(stats.get("n_segments", 0)),
            "end_live": int(stats.get("n_live", 0)),
            "obs_on": bool(obs_on),
            "compaction": compaction,
            "serve_threads": serve_threads,
            "dispatches_measured": dispatches_meas,
            "recompiles_measured": recompiles_meas,
            # rw-lock contention over the whole phase (incl. warmup):
            # how often searches shared the read side, how long writes
            # actually kept them out
            "rwlock": {
                k: float(v) for k, v in eng._serve_lock.stats().items()
                if k in ("read_acquisitions", "write_acquisitions",
                         "read_wait_ms", "write_wait_ms", "write_held_ms")
            },
        }
        if eng.last_swap_timeline is not None:
            tl = eng.last_swap_timeline
            # the lock-exclusivity proof, from recorded maint timings:
            # exclusive at snapshot + swap, shared/free elsewhere
            row["swap_timeline_locks"] = {
                k: tl.get(k) for k in ("snapshot_locked", "compact_locked",
                                       "replay_locked", "swap_locked")
            }
            row["swap_ms"] = tl.get("swap_ms")
            row["snapshot_ms"] = tl.get("snapshot_ms")
            row["compact_ms"] = tl.get("compact_ms")
        if online_recall is not None:
            row["recall_online"] = online_recall
            row["recall_offline"] = offline_recall
        if obs_ab:
            row["p50_obs_on"] = percentiles(lat_ab[True])["p50"]
            row["p50_obs_off"] = percentiles(lat_ab[False])["p50"]
        print(
            f"{name}: p50={row['p50']:.1f}ms p99={row['p99']:.1f}ms "
            f"p999={row['p999']:.1f}ms qps={row['qps']:.1f} "
            f"swaps={row['swaps']} segments={row['end_segments']} "
            f"(inserts={row['inserts']})",
            flush=True,
        )
        return row

    print(f"serving load: {requests} requests x {q_batch} queries, "
          f"{n_shards} shard(s), corpus n0={n0} d={d}", flush=True)
    baseline = run_phase("baseline", churn=False, maintained=False)
    churn = run_phase("churn", churn=True, maintained=False)
    maintained = run_phase("churn_maintained", churn=True, maintained=True)
    # reader-concurrency A/B: identical load, out-of-process compaction
    # + two serve workers sharing the read lock (vs in-thread above)
    maintained_sub = run_phase(
        "churn_maintained_subprocess", churn=True, maintained=True,
        compaction="subprocess", serve_threads=2,
    )
    # A/B for the observability tax: the baseline load with tracing
    # toggled per request (interleaved within ONE phase — see run_phase).
    # The recall probe gets its own phase: its exact shadow scoring runs
    # on a second thread, which on this host==device harness contends
    # with serving for the same cores, so folding it into the overhead
    # A/B would measure core contention, not the tracing/metrics tax (on
    # an accelerator the shadow is pure host work beside the device).
    # Both taxes land in the artifact.
    baseline_obs = run_phase(
        "baseline_obs", churn=False, maintained=False, obs_ab=True,
    )
    baseline_probe = run_phase(
        "baseline_probe", churn=False, maintained=False,
        obs_on=True, recall_fraction=0.25,
    )
    obs.default_tracer().enabled = False

    ratio_churn = churn["p99"] / max(baseline["p99"], 1e-9)
    ratio_maintained = maintained["p99"] / max(baseline["p99"], 1e-9)
    s_ratio_churn = (churn["search"]["p99"]
                     / max(baseline["search"]["p99"], 1e-9))
    s_ratio_maintained = (maintained["search"]["p99"]
                          / max(baseline["search"]["p99"], 1e-9))
    ratio_sub = maintained_sub["p99"] / max(baseline["p99"], 1e-9)
    s_ratio_sub = (maintained_sub["search"]["p99"]
                   / max(baseline["search"]["p99"], 1e-9))
    result = {
        "n0": n0, "d": d, "n_shards": n_shards,
        "layout": "mutable" if mesh is None else "sharded_mutable",
        "requests": requests, "q_batch": q_batch,
        "write_batch": write_batch, "buffer_capacity": capacity,
        "write_pause_s": write_pause,
        "params": {"k1": params.k1, "k2": params.k2, "h": params.h,
                   "k": params.k},
        "policy": {"max_segments": policy.max_segments,
                   "max_tombstone_ratio": policy.max_tombstone_ratio},
        "phases": [baseline, churn, maintained, maintained_sub,
                   baseline_obs, baseline_probe],
        "p99_ratio_churn_vs_baseline": float(ratio_churn),
        "p99_ratio_maintained_vs_baseline": float(ratio_maintained),
        "p99_ratio_maintained_subprocess_vs_baseline": float(ratio_sub),
        "search_p99_ratio_churn_vs_baseline": float(s_ratio_churn),
        "search_p99_ratio_maintained_vs_baseline": float(s_ratio_maintained),
        "search_p99_ratio_maintained_subprocess_vs_baseline": float(
            s_ratio_sub
        ),
        "maintained_within_2x_of_baseline": bool(ratio_maintained <= 2.0),
        "maintained_search_within_2x_of_baseline": bool(
            s_ratio_maintained <= 2.0
        ),
        "cpu_caveat": (
            "host==device on this harness: the shadow compact contends "
            "with serving for the same cores while it runs (see module "
            "docstring); on an accelerator the compact builds beside the "
            "serving device"
        ),
    }
    # Reader-concurrency acceptance block: the in-thread vs
    # out-of-process A/B, and the lock-exclusivity proof read back from
    # the recorded maint timelines (exclusive ONLY at snapshot + swap).
    with_tl = [ph for ph in (maintained, maintained_sub)
               if ph.get("swap_timeline_locks") is not None]
    locks_ok = bool(with_tl) and all(
        ph["swap_timeline_locks"]["snapshot_locked"] is True
        and ph["swap_timeline_locks"]["swap_locked"] is True
        and ph["swap_timeline_locks"]["compact_locked"] is False
        and ph["swap_timeline_locks"]["replay_locked"] is False
        for ph in with_tl
    )
    result["reader_concurrency"] = {
        "in_thread_search_p99_ms": maintained["search"]["p99"],
        "subprocess_search_p99_ms": maintained_sub["search"]["p99"],
        "subprocess_search_p99_improves": bool(
            maintained_sub["search"]["p99"] <= maintained["search"]["p99"]
        ),
        "in_thread_request_p99_ms": maintained["p99"],
        "subprocess_request_p99_ms": maintained_sub["p99"],
        "subprocess_serve_threads": 2,
        "lock_exclusive_only_at_snapshot_and_swap": locks_ok,
        "exclusive_hold_ms_in_thread": maintained["rwlock"][
            "write_held_ms"
        ],
        "exclusive_hold_ms_subprocess": maintained_sub["rwlock"][
            "write_held_ms"
        ],
        # the exclusive window around the swap itself — the number the
        # rw-lock + subprocess protocol shrinks on ANY host (the child
        # compacts outside the lock and outside the process, so the
        # parent's write side covers only the final tail replay + flip)
        "swap_exclusive_ms_in_thread": maintained.get("swap_ms"),
        "swap_exclusive_ms_subprocess": maintained_sub.get("swap_ms"),
        "cpu_caveat": (
            "the p99 A/B needs >=2 host cores to show the isolation "
            "win: with one core the compactor child pays interpreter + "
            "jax startup per cycle AND timeshares the serving core, so "
            "its longer compact window inflates p99 instead of freeing "
            "it.  The structural guarantee holds regardless (asserted "
            "above): the serve lock is exclusive only at snapshot + "
            "swap, and in both modes the exclusive swap window covers "
            "only the final WAL tail + pointer flip — independent of "
            "how long the compact itself ran, because compaction and "
            "catch-up replay happen outside the lock."
        ),
    }
    # Observability acceptance block: obs tax on the request path,
    # online-vs-offline recall agreement, and the steady-state recompile
    # invariant over every measured window.
    obs_overhead = (
        baseline_obs["p50_obs_on"] / max(baseline_obs["p50_obs_off"], 1e-9)
    ) - 1.0
    probe_overhead = (
        baseline_probe["p50"] / max(baseline["p50"], 1e-9)
    ) - 1.0
    steady_recompiles = {
        f'{ph["phase"]}:{s}': v
        for ph in (baseline, baseline_obs, baseline_probe)
        for s, v in ph["recompiles_measured"].items()
    }
    churn_search_recompiles = {
        f'{ph["phase"]}:{s}': v
        for ph in (churn, maintained)
        for s, v in ph["recompiles_measured"].items()
        if "search" in s or s.endswith(".merge")
    }
    recall_delta = None
    if baseline_probe.get("recall_offline") is not None:
        recall_delta = abs(
            baseline_probe["recall_online"] - baseline_probe["recall_offline"]
        )
    result["observability"] = {
        "request_p50_ms_obs_off": baseline_obs["p50_obs_off"],
        "request_p50_ms_obs_on": baseline_obs["p50_obs_on"],
        "overhead_frac_request_p50": float(obs_overhead),
        "overhead_within_2pct": bool(obs_overhead <= 0.02),
        "request_p50_ms_probe_on": baseline_probe["p50"],
        "probe_overhead_frac_request_p50": float(probe_overhead),
        "recall_online": baseline_probe.get("recall_online"),
        "recall_offline": baseline_probe.get("recall_offline"),
        "recall_online_offline_abs_delta": recall_delta,
        "recall_agrees_within_0p02": (
            None if recall_delta is None else bool(recall_delta <= 0.02)
        ),
        "recall_probe_fraction": 0.25,
        # the query-side pow2-bucket invariant: zero recompiles anywhere
        # in the steady-state (no-write) phases after warmup
        "steady_state_recompiles_post_warmup": steady_recompiles,
        "steady_state_recompile_free": not steady_recompiles,
        # under churn, a compacted/sealed generation with a NOVEL row
        # count recompiles its per-segment search once — data-side shape
        # instability, the open "shape-stable sealed generations"
        # ROADMAP item; the gauge now measures it live
        "churn_search_recompiles_post_warmup": churn_search_recompiles,
        "dispatch_accounting": accounting_snapshot(),
        "noise_caveat": (
            "the tracing A/B interleaves traced/untraced requests within "
            "one phase (phase-to-phase drift on a shared-core CPU host "
            "dwarfs the tracing tax); the structural obs cost per "
            "request is one disabled-tracer check, two counter bumps "
            "per dispatch scope, and one RNG draw for the probe.  The "
            "probe phase's extra tax vs baseline is cross-phase (noisy) "
            "and includes its exact shadow scorer contending for the "
            "same host cores (accelerator deployments run it beside "
            "the device)."
        ),
    }
    print(f"\np99 ratios vs baseline: request churn={ratio_churn:.2f}x "
          f"maintained={ratio_maintained:.2f}x subprocess={ratio_sub:.2f}x "
          f"| search churn={s_ratio_churn:.2f}x "
          f"maintained={s_ratio_maintained:.2f}x "
          f"subprocess={s_ratio_sub:.2f}x "
          f"(target: maintained <= 2x)", flush=True)
    rc = result["reader_concurrency"]
    print(f"reader concurrency: search p99 in-thread="
          f"{rc['in_thread_search_p99_ms']:.1f}ms subprocess="
          f"{rc['subprocess_search_p99_ms']:.1f}ms "
          f"(improves={rc['subprocess_search_p99_improves']}), lock "
          f"exclusive only at snapshot+swap="
          f"{rc['lock_exclusive_only_at_snapshot_and_swap']}", flush=True)
    ob = result["observability"]
    print(f"obs: p50 {ob['request_p50_ms_obs_off']:.1f}ms -> "
          f"{ob['request_p50_ms_obs_on']:.1f}ms "
          f"({100 * ob['overhead_frac_request_p50']:+.1f}%; probe phase "
          f"{ob['request_p50_ms_probe_on']:.1f}ms), "
          f"recall online={ob['recall_online']} "
          f"offline={ob['recall_offline']}, steady-state recompiles="
          f"{ob['steady_state_recompiles_post_warmup'] or 0}",
          flush=True)
    with open("BENCH_serving.json", "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print("\nwrote BENCH_serving.json", flush=True)
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
