"""Paper Table 2: Task-2 k-NN-graph construction time vs recall.

GOOAQ (3M×384) scaled to container size (N=12k, d=384).  Reproduces the
table's structure: construction time grows ~linearly with n (orders) while
recall climbs; the recall@15 > 0.8 band is reachable; memory stays constant
in n (orders are streamed — the paper's Task-2 headline property).
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import ForestConfig, GraphParams, HilbertIndex, IndexConfig

N, D = 12000, 384


def main(rows=None):
    data = ann_datasets.lowrank_embeddings(N, D, n_clusters=48, seed=3)
    gt = ann_datasets.exact_knn_graph(data, 15)
    data_j = jnp.asarray(data)
    # One build amortized over the whole grid: every row reuses the index's
    # fitted quantizer/sketches (n_trees=1 — Task 2 streams its own orders).
    index = HilbertIndex.build(
        data_j, IndexConfig(forest=ForestConfig(n_trees=1, bits=4, key_bits=448))
    )

    grid = rows or [
        # (n_orders, k1, k2) — scaled analogue of Table 2's 5 rows
        (6, 32, 48),
        (10, 40, 64),
        (16, 48, 96),
        (24, 56, 128),
        (32, 64, 160),
    ]
    print("n,k1,k2,recall@15,time_s")
    out = []
    for (no, k1, k2) in grid:
        params = GraphParams(n_orders=no, k1=k1, k2=k2, k=15, seed=0)
        t0 = time.time()
        ids, _ = index.knn_graph(params)
        ids.block_until_ready()
        dt = time.time() - t0
        rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
        print(f"{no},{k1},{k2},{rec:.3f},{dt:.1f}")
        out.append((no, k1, k2, rec, dt))
    assert max(r[3] for r in out) > 0.8
    # time ~linear in n: top row >= 3x bottom row's per-order cost sanity
    return out


if __name__ == "__main__":
    main()
