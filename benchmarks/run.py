"""Benchmark orchestrator: one module per paper table/figure.

All table/phase benchmarks run on the unified ``repro.index.HilbertIndex``
API (build once → search / knn_graph off the same artifact).

  table1   — Task-1 recall/time grid (paper Table 1)
  table2   — Task-2 graph build time/recall (paper Table 2)
  phases   — preprocessing time split (paper §3.2)
  kernels  — hamming/qdist microbench + TPU roofline model
  hsort    — Hilbert-sort scaling (2016 algorithm claim)
  churn    — streaming insert/delete/search on the mutable index
  search   — fused packed search path vs per-tree-loop reference
             (emits BENCH_search.json)
  sharded  — row-partitioned shard_map search vs single-device
             (emits BENCH_sharded.json; re-execs itself with 8
             simulated devices)
  sharded_churn — streaming insert/delete/compact on the sharded-mutable
             index: recall-vs-rebuild, one-dispatch invariant, routing
             locality (emits BENCH_sharded_churn.json; re-execs itself
             with 8 simulated devices)
  serving  — concurrent write+query load through the RetrievalEngine:
             per-request p50/p99/p999 with and without background
             maintenance (emits BENCH_serving.json; re-execs itself
             with 8 simulated devices)
  durability — WAL ack-latency overhead vs sync_every and recovery
             time vs replay-tail length; asserts the default group
             commit stays <10% p50 on sustained ingest (emits
             BENCH_durability.json)

``python -m benchmarks.run [names...]`` (default: all).
"""

import sys
import time


def main() -> None:
    names = sys.argv[1:] or ["kernels", "hsort", "phases", "table2", "table1",
                             "churn", "search", "sharded", "sharded_churn",
                             "serving", "durability"]
    t00 = time.time()
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        if name == "table1":
            from benchmarks import task1_table1 as m
        elif name == "table2":
            from benchmarks import task2_table2 as m
        elif name == "phases":
            from benchmarks import build_phases as m
        elif name == "kernels":
            from benchmarks import kernel_bench as m
        elif name == "hsort":
            from benchmarks import hilbert_sort_bench as m
        elif name == "churn":
            from benchmarks import churn as m
        elif name == "search":
            from benchmarks import search_path as m
        elif name == "sharded":
            from benchmarks import sharded_search as m
        elif name == "sharded_churn":
            from benchmarks import sharded_churn as m
        elif name == "serving":
            from benchmarks import serving as m
        elif name == "durability":
            from benchmarks import durability as m
        else:
            raise SystemExit(f"unknown benchmark {name!r}")
        m.main()
        print(f"[{name} done in {time.time()-t0:.0f}s]", flush=True)
    print(f"\nALL BENCHMARKS DONE in {time.time()-t00:.0f}s")


if __name__ == "__main__":
    main()
