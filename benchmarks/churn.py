"""Churn workload: interleaved insert/delete/search on the mutable index.

Measures what a streaming deployment cares about:

* **recall-vs-rebuild** — after each churn phase, recall@k of the live
  LSM state against (a) exact brute force and (b) a from-scratch
  ``HilbertIndex.build`` over the same live points, plus the rebuild's
  wall-clock cost the mutable index avoids paying.
* **segment-count vs latency** — p50/p99 single-batch search latency as the
  number of sealed segments varies (the LSM read-amplification curve),
  including the fully compacted state.

``python -m benchmarks.churn [--smoke]`` — smoke mode shrinks everything to
CI scale (also runnable via ``python -m benchmarks.run churn``).
"""

import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    MutableHilbertIndex,
    SearchParams,
)


def _percentiles(samples_ms):
    s = np.sort(np.asarray(samples_ms))
    return s[int(0.50 * (len(s) - 1))], s[int(0.99 * (len(s) - 1))]


def _time_search(mut, queries, params, reps):
    mut.search(queries, params)  # warm the jit caches for this LSM shape
    out = []
    for _ in range(reps):
        t0 = time.perf_counter()
        ids, _ = mut.search(queries, params)
        jnp.asarray(ids).block_until_ready()
        out.append(1000 * (time.perf_counter() - t0))
    return out


def main(smoke: bool = False) -> dict:
    if smoke:
        n0, d, q, batches, batch, reps = 2000, 32, 32, 3, 400, 5
        fcfg = ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16)
        params = SearchParams(k1=16, k2=64, h=1, k=10)
        capacity, max_segments = 512, 6
    else:
        n0, d, q, batches, batch, reps = 20000, 128, 200, 6, 4000, 30
        fcfg = ForestConfig(n_trees=8, bits=4, key_bits=448, leaf_size=32)
        params = SearchParams(k1=32, k2=192, h=2, k=10)
        capacity, max_segments = 4096, 8
    # pow2-padded seals: flush/merge builds land on power-of-two shapes so
    # steady-state churn re-uses compiled kernels (asserted at the end)
    cfg = IndexConfig(forest=fcfg, seal_pow2=True)
    total = n0 + batches * batch
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        total, q, d, n_clusters=32, seed=0
    )
    data = np.asarray(data)
    queries_j = jnp.asarray(queries)
    rng = np.random.default_rng(0)

    mut = MutableHilbertIndex(cfg, buffer_capacity=capacity,
                              max_segments=max_segments)
    ids = mut.bulk_load(data[:n0])
    live_ids, live_pts = ids, data[:n0]

    rows = []
    print("phase,n_live,n_segments,recall_mut,recall_rebuild,"
          "rebuild_s,p50_ms,p99_ms")
    for phase in range(batches + 1):
        # -- latency at the current segment count --------------------------
        p50, p99 = _percentiles(_time_search(mut, queries_j, params, reps))

        # -- recall vs exact + vs a from-scratch rebuild -------------------
        gt, _ = ann_datasets.exact_knn(live_pts, np.asarray(queries), params.k)
        hits, _ = mut.search(queries_j, params)
        pos_of = {int(e): i for i, e in enumerate(live_ids)}
        pos = np.vectorize(lambda e: pos_of.get(int(e), -1))(np.asarray(hits))
        rec = ann_datasets.recall_at_k(pos, gt)
        t0 = time.time()
        fresh = HilbertIndex.build(jnp.asarray(live_pts), cfg)
        rebuild_s = time.time() - t0
        frec = ann_datasets.recall_at_k(
            np.asarray(fresh.search(queries_j, params)[0]), gt
        )
        rows.append((phase, mut.n_live, mut.n_segments, rec, frec,
                     rebuild_s, p50, p99))
        print(f"{phase},{mut.n_live},{mut.n_segments},{rec:.3f},{frec:.3f},"
              f"{rebuild_s:.2f},{p50:.1f},{p99:.1f}", flush=True)

        if phase == batches:
            break
        # -- churn: insert a batch, expire ~8% of current live points ------
        s = n0 + phase * batch
        new = mut.insert(data[s : s + batch])
        drop = rng.choice(live_ids, len(live_ids) // 12, replace=False)
        mut.delete(drop)
        keep = ~np.isin(live_ids, drop)
        live_ids = np.concatenate([live_ids[keep], new])
        live_pts = np.concatenate([live_pts[keep], data[s : s + batch]])

    # -- compacted endpoint ------------------------------------------------
    t0 = time.time()
    mut.compact()
    compact_s = time.time() - t0
    p50c, p99c = _percentiles(_time_search(mut, queries_j, params, reps))
    gt, _ = ann_datasets.exact_knn(live_pts, np.asarray(queries), params.k)
    hits, _ = mut.search(queries_j, params)
    pos_of = {int(e): i for i, e in enumerate(live_ids)}
    pos = np.vectorize(lambda e: pos_of.get(int(e), -1))(np.asarray(hits))
    rec_c = ann_datasets.recall_at_k(pos, gt)
    print(f"compacted,{mut.n_live},{mut.n_segments},{rec_c:.3f},,"
          f"{compact_s:.2f},{p50c:.1f},{p99c:.1f}", flush=True)

    # sanity: churn never falls meaningfully behind a full rebuild, and the
    # compacted endpoint matches the final rebuild (it IS one, incrementally).
    worst_gap = max(fr - r for _, _, _, r, fr, _, _, _ in rows)
    assert worst_gap <= 0.02, f"mutable recall fell {worst_gap:.3f} behind rebuild"
    final_frec = rows[-1][4]
    assert rec_c >= final_frec - 0.02, (rec_c, final_frec)

    # -- shape stability: steady-state churn must not recompile -----------
    # With seal_pow2, a rolling-window churn round (insert one buffer's
    # worth, expire the previous round's rows) only produces already-seen
    # padded build/search shapes.  Two rounds warm whatever this compacted
    # state hasn't dispatched yet; the third must be compile-free.
    from repro.obs.dispatch import recompile_counts

    prev_round: list = []

    def churn_round():
        extra = rng.normal(size=(capacity, d)).astype(np.float32)
        new = mut.insert(extra)       # exactly one flush (buffer was empty)
        if prev_round:
            mut.delete(prev_round.pop())
        prev_round.append(new)
        mut.search(queries_j, params)

    churn_round()                     # warm-up rounds
    churn_round()
    before = recompile_counts()
    churn_round()                     # asserted round
    delta = {k: v - before.get(k, 0)
             for k, v in recompile_counts().items() if v != before.get(k, 0)}
    print(f"steady-state churn recompiles: {delta or 'none'}", flush=True)
    assert not delta, (
        f"pow2-padded steady-state churn still recompiled: {delta}"
    )
    return {"rows": rows, "compacted": (mut.n_segments, rec_c, p50c, p99c),
            "steady_state_recompiles": 0}


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
