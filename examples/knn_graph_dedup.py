"""Task-2 as a data-curation tool: near-duplicate detection via the
approximate k-NN graph (SemDeDup-style).

    PYTHONPATH=src python examples/knn_graph_dedup.py

A corpus with planted near-duplicates is embedded (stub: the low-rank
generator plays the embedding model); the paper's Algorithm-2 graph is
built and edges under a distance threshold mark duplicate pairs.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import ForestConfig, GraphParams, HilbertIndex, IndexConfig

N, D, DUPS = 8000, 384, 400

# corpus + planted near-duplicates (tiny perturbations of random rows)
base = ann_datasets.lowrank_embeddings(N - DUPS, D, n_clusters=32, seed=0)
rng = np.random.default_rng(1)
src = rng.integers(0, len(base), DUPS)
dup = base[src] + 0.01 * rng.normal(size=(DUPS, D)).astype(np.float32)
dup /= np.linalg.norm(dup, axis=1, keepdims=True)
corpus = np.concatenate([base, dup])
true_pairs = {(int(N - DUPS + i), int(src[i])) for i in range(DUPS)}

params = GraphParams(n_orders=16, k1=48, k2=96, k=15, seed=0)
t0 = time.time()
# One index serves both tasks: knn_graph() reuses its fitted quantizer and
# sketches (n_trees=1 — Task 2 streams its own randomized orders instead).
index = HilbertIndex.build(
    jnp.asarray(corpus),
    IndexConfig(forest=ForestConfig(n_trees=1, bits=4, key_bits=448)),
)
ids, d2 = index.knn_graph(params)
print(f"kNN graph over {N:,} embeddings in {time.time()-t0:.1f}s")

ids_n, d2_n = np.asarray(ids), np.asarray(d2)
# per-dim noise 0.01 in d=384 -> dup distance d² ≈ 384·1e-4 ≈ 0.04;
# regular NN distances sit near 0.7 — threshold 0.1 separates cleanly.
thresh = 0.1
found = set()
for i in range(N):
    for j, dd in zip(ids_n[i], d2_n[i]):
        if dd < thresh:
            found.add((max(i, int(j)), min(i, int(j))))
hits = sum((a, b) in found or (b, a) in found for a, b in true_pairs)
print(f"planted near-dup pairs recovered: {hits}/{DUPS} "
      f"({100*hits/DUPS:.1f}%); {len(found)} candidate pairs flagged")
assert hits / DUPS > 0.9
