"""Quickstart: build a Hilbert-forest index and run approximate k-NN search.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import search
from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets

# 1. A corpus of embedding-like vectors + held-out queries.
data, queries = ann_datasets.lowrank_dataset_with_queries(
    n=10_000, q=100, d=384, n_clusters=48, seed=0
)

# 2. Build the index: Hilbert forest + shared-MSB 4-bit codes + sketches.
cfg = ForestConfig(n_trees=16, bits=4, key_bits=448, leaf_size=32, seed=0)
t0 = time.time()
index = search.build_index(jnp.asarray(data), cfg)
print(f"built {cfg.n_trees}-tree forest over {len(data):,}x{data.shape[1]} "
      f"in {time.time()-t0:.1f}s")
for k, v in index.memory_report().items():
    print(f"  {k:>24}: {v/1e6:8.2f} MB")

# 3. Search (Algorithm 1: forest -> sketches -> ±h expansion -> ADC top-k).
params = SearchParams(k1=48, k2=384, h=2, k=30)
t0 = time.time()
ids, dists = search.search(index, jnp.asarray(queries), params, cfg)
print(f"searched {len(queries)} queries in {time.time()-t0:.2f}s")

# 4. Verify against brute force.
gt, _ = ann_datasets.exact_knn(data, queries, 30)
rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
print(f"recall@30 = {rec:.3f}  (paper Task-1 band: > 0.7)")
assert rec > 0.7
