"""Quickstart: build a self-describing Hilbert-forest index, search, persist.

    PYTHONPATH=src python examples/quickstart.py

One object — ``HilbertIndex`` — covers the whole lifecycle: it carries its
build config, so search takes no config argument, and ``save``/``load``
round-trips the index bit-exactly (build once, serve from many workers).
"""

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import ForestConfig, HilbertIndex, IndexConfig, SearchParams

# 1. A corpus of embedding-like vectors + held-out queries.
data, queries = ann_datasets.lowrank_dataset_with_queries(
    n=10_000, q=100, d=384, n_clusters=48, seed=0
)

# 2. Build the index: Hilbert forest + shared-MSB 4-bit codes + sketches.
cfg = IndexConfig(
    forest=ForestConfig(n_trees=16, bits=4, key_bits=448, leaf_size=32, seed=0)
)
t0 = time.time()
index = HilbertIndex.build(jnp.asarray(data), cfg)
print(f"built {cfg.forest.n_trees}-tree forest over {len(data):,}x{data.shape[1]} "
      f"in {time.time()-t0:.1f}s")
for k, v in index.memory_report().items():
    print(f"  {k:>24}: {v/1e6:8.2f} MB")

# 3. Search (Algorithm 1: forest -> sketches -> ±h expansion -> ADC top-k).
#    No config to re-supply — the index is self-describing.
params = SearchParams(k1=48, k2=384, h=2, k=30)
t0 = time.time()
ids, dists = index.search(jnp.asarray(queries), params)
print(f"searched {len(queries)} queries in {time.time()-t0:.2f}s")

# 4. Verify against brute force.
gt, _ = ann_datasets.exact_knn(data, queries, 30)
rec = ann_datasets.recall_at_k(np.asarray(ids), gt)
print(f"recall@30 = {rec:.3f}  (paper Task-1 band: > 0.7)")
assert rec > 0.7

# 5. Persist and reload: the loaded index reproduces search bit-exactly.
with tempfile.TemporaryDirectory() as td:
    index.save(td + "/index")
    ids2, dists2 = HilbertIndex.load(td + "/index").search(
        jnp.asarray(queries), params
    )
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert np.array_equal(np.asarray(dists), np.asarray(dists2))
    print("save/load round-trip: bit-identical search results")
