"""Streaming churn demo: a Hilbert-forest index that grows while serving.

    PYTHONPATH=src python examples/streaming_churn.py

Simulates a live deployment absorbing a document stream: batches of new
points arrive, stale points are deleted, and searches run continuously —
no offline rebuild.  Shows the LSM lifecycle (buffer fills -> sealed
segments -> tiered merges -> full compaction) and that recall tracks a
from-scratch rebuild the whole way.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    MutableHilbertIndex,
    SearchParams,
)

D, K = 64, 10
cfg = IndexConfig(
    forest=ForestConfig(n_trees=8, bits=4, key_bits=256, leaf_size=32, seed=0)
)
params = SearchParams(k1=32, k2=128, h=2, k=K)

# A stream of 8k points; 100 held-out queries.
stream, queries = ann_datasets.lowrank_dataset_with_queries(
    n=8_000, q=100, d=D, n_clusters=24, seed=0
)
stream = np.asarray(stream)
queries_j = jnp.asarray(queries)

mut = MutableHilbertIndex(cfg, buffer_capacity=1024, max_segments=4)
ext_ids = np.zeros((0,), np.int32)
ext_pts = np.zeros((0, D), np.float32)
rng = np.random.default_rng(0)

print("phase           | live  segs buf   | recall@10 vs rebuild | search ms")
for step in range(8):
    batch = stream[step * 1000 : (step + 1) * 1000]
    ids = mut.insert(batch)
    ext_ids = np.concatenate([ext_ids, ids])
    ext_pts = np.concatenate([ext_pts, batch])
    # churn: ~10% of the oldest half expires
    if step:
        candidates = ext_ids[: len(ext_ids) // 2]
        drop = rng.choice(candidates, len(candidates) // 10, replace=False)
        mut.delete(drop)
        keep = ~np.isin(ext_ids, drop)
        ext_ids, ext_pts = ext_ids[keep], ext_pts[keep]

    t0 = time.time()
    hits, _ = mut.search(queries_j, params)
    dt = 1000 * (time.time() - t0)

    # ground truth + a from-scratch rebuild over exactly the live points
    gt, _ = ann_datasets.exact_knn(ext_pts, np.asarray(queries), K)
    pos_of = {int(e): i for i, e in enumerate(ext_ids)}
    pos = np.vectorize(lambda e: pos_of.get(int(e), -1))(np.asarray(hits))
    rec = ann_datasets.recall_at_k(pos, gt)
    fresh = HilbertIndex.build(jnp.asarray(ext_pts), cfg)
    frec = ann_datasets.recall_at_k(np.asarray(fresh.search(queries_j, params)[0]), gt)
    print(f"stream batch {step}  | {mut.n_live:5d} {mut.n_segments:4d} "
          f"{mut.n_buffered:4d}  | {rec:.3f} vs {frec:.3f}        | {dt:7.1f}")
    assert rec >= frec - 0.02, "streaming recall fell behind a full rebuild"

print(mut)
t0 = time.time()
mut.compact()
print(f"compact() -> {mut.n_segments} segment in {time.time()-t0:.2f}s "
      f"(tombstones dropped: index holds exactly {mut.n_live} live points)")
for k, v in mut.memory_report().items():
    if k.endswith("_bytes"):
        print(f"  {k:>18}: {v/1e6:8.2f} MB")

hits, _ = mut.search(queries_j, params)
pos = np.vectorize(lambda e: pos_of.get(int(e), -1))(np.asarray(hits))
gt, _ = ann_datasets.exact_knn(ext_pts, np.asarray(queries), K)
print(f"post-compact recall@{K}: {ann_datasets.recall_at_k(pos, gt):.3f}")
print("done.")
