"""End-to-end LM training driver with checkpoint/restart.

CPU demo (runs in minutes):
    PYTHONPATH=src python examples/train_lm.py --preset cpu-demo
100M-param config (for real accelerators; lowers/runs the same code):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Demonstrates: config-driven model zoo, microbatch accumulation, AdamW with
warmup-cosine, async atomic checkpoints, bit-exact resume, loss decreasing
on the synthetic Zipf+motif stream.
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.config import LayerSpec, ModelConfig
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules
from repro.train.train_loop import (
    TrainConfig, abstract_train_state, init_train_state, make_train_step,
)

PRESETS = {
    # ~3M params: tens of seconds on this CPU container
    "cpu-demo": ModelConfig(
        name="demo-3m", family="dense", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
        pattern=(LayerSpec(),), act="silu", norm="rmsnorm",
        tie_embeddings=True, compute_dtype="float32",
    ),
    # ~100M params: the brief's end-to-end target for real hardware
    "100m": ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
        pattern=(LayerSpec(),), act="silu", norm="rmsnorm",
        tie_embeddings=True,
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-demo", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"[{cfg.name}] {cfg.param_count():,} params")
    rules = ShardingRules()
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        lr=3e-3, warmup_steps=10, total_steps=args.steps))
    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq))
    step_fn = jax.jit(make_train_step(cfg, tcfg, rules))

    start = 0
    if (ls := latest_step(args.ckpt)) is not None:
        state, _ = restore(args.ckpt, ls, abstract_train_state(cfg, tcfg))
        start = ls
        print(f"[resume] from step {ls}")
    else:
        state = init_train_state(cfg, tcfg, jax.random.key(0))

    ck = AsyncCheckpointer(args.ckpt)
    first = last = None
    for s in range(start, args.steps):
        t0 = time.time()
        state, m = step_fn(state, pipe.jax_batch(s))
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if s % 10 == 0:
            print(f"step {s:4d}  loss {loss:.4f}  ({time.time()-t0:.2f}s)")
        if (s + 1) % 40 == 0:
            ck.save(s + 1, state)
    ck.save(args.steps, state)
    ck.wait()
    print(f"[done] loss {first:.3f} -> {last:.3f}; checkpoints in {args.ckpt}")
    assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
