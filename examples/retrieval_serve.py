"""Serve a small LM with batched requests + Hilbert-forest retrieval.

    PYTHONPATH=src python examples/retrieval_serve.py

Trains the cpu-demo LM briefly, builds a kNN-LM datastore of (hidden state
-> next token) from the training stream, then decodes a batch of prompts
with and without retrieval mixing — demonstrating the paper's index as a
first-class serving feature (Algorithm 1 is the lookup path).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ForestConfig, SearchParams
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.index import IndexConfig
from repro.models import model
from repro.optim import OptimizerConfig
from repro.serve.retrieval import RetrievalStore, knn_lm_mix
from repro.sharding import ShardingRules
from repro.train.train_loop import TrainConfig, init_train_state, make_train_step

try:
    from examples.train_lm import PRESETS  # noqa: E402 (repo root on path)
except ModuleNotFoundError as e:
    if e.name not in ("examples", "examples.train_lm"):
        raise  # a real missing dependency, not the path-layout difference
    from train_lm import PRESETS  # noqa: E402 (script-dir invocation)

cfg, rules = PRESETS["cpu-demo"], ShardingRules()
tcfg = TrainConfig(optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5,
                                             total_steps=40))
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, global_batch=8,
                                seq_len=64))
state = init_train_state(cfg, tcfg, jax.random.key(0))
step_fn = jax.jit(make_train_step(cfg, tcfg, rules))
for s in range(40):
    state, m = step_fn(state, pipe.jax_batch(s))
print(f"[train] 40 steps, final loss {float(m['loss']):.3f}")
params = state["params"]

# --- datastore: hidden states over held-out stream batches ---
keys_l, vals_l = [], []
for s in range(100, 104):
    b = pipe.jax_batch(s)
    hid, _, _ = model.forward(cfg, params, b["tokens"], rules, return_hidden=True)
    keys_l.append(np.asarray(hid[:, :-1].reshape(-1, cfg.d_model), np.float32))
    vals_l.append(np.asarray(b["tokens"][:, 1:].reshape(-1)))
keys = jnp.asarray(np.concatenate(keys_l))
vals = jnp.asarray(np.concatenate(vals_l))
fc = IndexConfig(forest=ForestConfig(n_trees=8, bits=4, key_bits=256,
                                     leaf_size=32),
                 store_points=False)
t0 = time.time()
store = RetrievalStore.build(keys, vals, fc)
print(f"[datastore] {keys.shape[0]:,} entries indexed in {time.time()-t0:.1f}s")

# --- batched decode with/without retrieval ---
b = pipe.jax_batch(200)
prompts = b["tokens"][:, :32]
targets = np.asarray(b["tokens"][:, 32:40])
sp = SearchParams(k1=32, k2=64, h=1, k=8)
decode = jax.jit(lambda p, t, i, c: model.decode_step(cfg, p, t, i, c, rules,
                                                      with_hidden=True))

for use_retrieval in (False, True):
    logits, caches = model.prefill(cfg, params, prompts, rules)
    caches = model.pad_caches(cfg, caches, 40)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    correct = total = 0
    for t in range(32, 40):
        logits_t, caches, hid = decode(params, tok, jnp.int32(t), caches)
        if use_retrieval:
            logp = knn_lm_mix(logits_t.astype(jnp.float32),
                              hid.astype(jnp.float32), store, sp, lam=0.3)
        else:
            logp = logits_t.astype(jnp.float32)
        tok = jnp.argmax(logp, -1)[:, None].astype(jnp.int32)
        # teacher-forced accuracy vs the stream's true next tokens
        correct += int((np.asarray(tok)[:, 0] == targets[:, t - 32]).sum())
        total += targets.shape[0]
        tok = jnp.asarray(targets[:, t - 32][:, None])  # teacher forcing
    tag = "kNN-LM " if use_retrieval else "model  "
    print(f"[{tag}] next-token acc over 8 steps: {correct}/{total}")

# --- the datastore grows WHILE serving (no rebuild): stream one more batch ---
b = pipe.jax_batch(300)
hid, _, _ = model.forward(cfg, params, b["tokens"], rules, return_hidden=True)
new_keys = jnp.asarray(hid[:, :-1].reshape(-1, cfg.d_model), jnp.float32)
new_vals = jnp.asarray(b["tokens"][:, 1:].reshape(-1))
t0 = time.time()
new_ids = store.append(new_keys, new_vals)
print(f"[append ] +{len(new_ids):,} entries in {time.time()-t0:.2f}s -> "
      f"{store.index.n_live:,} live ({store.index.n_segments} segments, "
      f"{store.index.n_buffered} buffered)")
store.delete(new_ids[: len(new_ids) // 2])   # and shrinks: TTL-style eviction
print(f"[delete ] evicted {len(new_ids)//2:,} -> {store.index.n_live:,} live")
print("done.")
