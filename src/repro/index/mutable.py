"""MutableHilbertIndex: LSM-style streaming mutation on top of HilbertIndex.

The paper's headline Task-2 result — Hilbert sort makes forest construction
the *fastest* entry — is exactly the property that makes merge-based dynamic
maintenance cheap: re-sorting a few hundred thousand points is milliseconds,
so segments can be rebuilt wholesale instead of patched in place.  This
module layers classic LSM machinery over the immutable facade:

* **Write buffer** — a fixed-capacity in-RAM array of freshly inserted
  points, searched exactly (:func:`repro.core.search.brute_force_topk`).
  Fixed capacity keeps the jitted brute-force stage's shapes stable.
* **Sealed segments** — when the buffer fills (or :meth:`flush` is called)
  its live rows become an ordinary immutable :class:`HilbertIndex` built via
  the existing fast path, plus an id-remap array giving each local row its
  stable external id.
* **Tombstones** — deletes only flip a bit in a dense ``alive`` mask; search
  masks dead candidates during the cross-segment merge, and each segment's
  per-query ``k`` is inflated by its dead count so tombstones cannot eat
  result slots.
* **Tiered compaction** — when segments pile up, the smallest two are merged
  by concatenating their stored points, dropping tombstoned rows for good,
  re-sorting (one cheap Hilbert-forest build), and remapping ids.
  :meth:`compact` merges everything into one segment, after which search is
  equivalent to a from-scratch :class:`HilbertIndex.build` over the
  surviving points (segments keep rows in external-id order, i.e. insertion
  order, so the rebuild sees the same point sequence).

Search fans out over buffer + segments and merges per-source top-k into one
exact top-k (the same associative merge argument as ``core/knn_graph.py``:
the global top-k of a union is the top-k of per-source top-k's).  External
ids are stable for the life of the index — they survive flushes and
compactions — and rows never move between sources except through them.

Persistence is a multi-bundle checkpoint: one ``repro.checkpoint`` bundle
per segment, one for the buffer/tombstone/value state, committed by an
atomically renamed top-level manifest (see
:func:`repro.checkpoint.atomic_write_json`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.checkpoint import wal as wal_lib
from repro.core import search as search_lib
from repro.core.types import SearchParams
from repro.index.config import IndexConfig
from repro.obs.dispatch import dispatch_scope
from repro.obs.trace import span
from repro.testing.faults import fault_point
from repro.index.facade import (
    HilbertIndex,
    load_index_bundle,
    save_index_bundle,
)

__all__ = [
    "LsmIdSpace",
    "MutableHilbertIndex",
    "Segment",
    "WalFacade",
    "dense_values_at",
    "load_mutable_bundle",
    "replay_wal_records",
    "save_mutable_bundle",
]


def dense_values_at(values: np.ndarray, ids, fill=0) -> jax.Array:
    """Gather rows of a dense by-id ``values`` array for search-result ids.

    The one -1-slot masking gather both serving layouts share: ``ids`` may
    contain ``-1`` padding (fewer than k hits), which surfaces as ``fill``;
    other ids are clipped into range.  Broadcasting handles values of any
    trailing shape (scalar tokens or vector payloads).
    """
    idn = np.asarray(jax.device_get(ids))
    safe = np.clip(idn, 0, values.shape[0] - 1)
    out = values[safe]
    mask = (idn >= 0).reshape(idn.shape + (1,) * (out.ndim - idn.ndim))
    return jnp.asarray(np.where(mask, out, fill))

_MANIFEST = "mutable_manifest.json"
_SEGMENT_KIND = "mutable_segment"
_DEFAULT_KIND = "mutable_hilbert_index"
_MAX_IDS = 2**31 - 1  # external ids are int32


def _pow2_ceil(x: int) -> int:
    """Smallest power of two >= x (>= 1)."""
    return 1 << max(0, int(x) - 1).bit_length()


class LsmIdSpace:
    """External-id allocation, tombstones, and per-point values — the LSM
    bookkeeping shared by every mutable facade.

    Extracted from :class:`MutableHilbertIndex` so the sharded streaming
    index (:class:`repro.index.ShardedMutableHilbertIndex`) reuses identical
    semantics: ids are dense int32 assigned at insert and stable for the
    life of the index, ``alive`` is a dense by-id tombstone mask, and
    ``values`` (optional) is a dense by-id payload array whose tracking mode
    is pinned by the first insert.  ``delete_epoch`` bumps on every
    effective delete so owners can cache per-segment dead counts.
    """

    def __init__(self):
        self.next_id = 0
        self.alive = np.zeros((0,), np.bool_)  # dense by external id
        self.values: Optional[np.ndarray] = None  # dense by external id
        self.track_values: Optional[bool] = None
        self.delete_epoch = 0  # bumps on delete; invalidates dead caches

    @property
    def n_live(self) -> int:
        return int(np.count_nonzero(self.alive))

    @property
    def n_deleted(self) -> int:
        return int(self.next_id - self.n_live)

    def prepare(
        self, points, values, dim: Optional[int]
    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Normalize + fully validate an insert WITHOUT mutating anything.

        The shared preamble of both mutable facades' ``insert``: device_get
        and promote points to (m, d) fp32, run :meth:`validate`, and check
        against the owner's pinned ``dim`` (``None`` = not pinned yet).
        Returns host ``(points, values)``; a raise here leaves the index
        unchanged.  Callers then pin dim / allocate buffers and call
        :meth:`register`.
        """
        pts = np.asarray(jax.device_get(points), np.float32)
        if pts.ndim == 1:
            pts = pts[None, :]
        if pts.ndim != 2:
            raise ValueError(f"points must be (m, d), got shape {pts.shape}")
        if pts.shape[0] == 0:
            return pts, None
        vals = self.validate(pts.shape[0], values)
        if dim is not None and pts.shape[1] != dim:
            raise ValueError(
                f"dim mismatch: index is {dim}, got {pts.shape[1]}"
            )
        return pts, vals

    def validate(self, m: int, values) -> Optional[np.ndarray]:
        """Pre-mutation checks for an m-row insert; returns host values.

        Raises without touching any state (a failed insert must leave the
        index unchanged — including NOT pinning the values mode).
        """
        if self.track_values is not None and (
            (values is not None) != self.track_values
        ):
            raise ValueError(
                "inconsistent values tracking: every insert must carry values "
                "or none may (first insert decides)"
            )
        vals = None
        if values is not None:
            vals = np.asarray(jax.device_get(values))
            if vals.shape[:1] != (m,):
                raise ValueError(f"values must be (m, ...) with m={m}")
        if self.next_id + m > _MAX_IDS:
            raise OverflowError("external id space (int32) exhausted")
        return vals

    def register(self, m: int, vals: Optional[np.ndarray]) -> np.ndarray:
        """Allocate m external ids; extend alive/values. Call validate first."""
        if self.track_values is None:
            self.track_values = vals is not None
        ids = np.arange(self.next_id, self.next_id + m, dtype=np.int32)
        self.next_id += m
        self.alive = np.concatenate([self.alive, np.ones((m,), np.bool_)])
        if vals is not None:
            self.values = (
                vals.copy()
                if self.values is None
                else np.concatenate([self.values, vals])
            )
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids; returns the newly-dead count. KeyError on unknown."""
        idn = np.atleast_1d(np.asarray(jax.device_get(ids))).astype(np.int64)
        if idn.size == 0:
            return 0
        if (idn < 0).any() or (idn >= self.next_id).any():
            bad = idn[(idn < 0) | (idn >= self.next_id)]
            raise KeyError(f"unknown external ids: {bad[:8].tolist()}")
        uniq = np.unique(idn)
        newly = int(np.count_nonzero(self.alive[uniq]))
        self.alive[uniq] = False
        if newly:
            self.delete_epoch += 1
        return newly

    def values_at(self, ids, fill=0) -> jax.Array:
        if self.values is None:
            raise ValueError("this index tracks no values (insert them)")
        return dense_values_at(self.values, ids, fill=fill)

    def values_dense(self) -> jax.Array:
        if self.values is None:
            raise ValueError("this index tracks no values (insert them)")
        return jnp.asarray(self.values)

    def clone(self) -> "LsmIdSpace":
        """Deep copy of the host bookkeeping (the snapshot/swap hook).

        The arrays are small relative to sealed segments (1 byte/id + the
        values payload), so cloning is cheap enough to run under a serving
        engine's write lock.
        """
        c = LsmIdSpace()
        c.next_id = self.next_id
        c.alive = self.alive.copy()
        c.values = None if self.values is None else self.values.copy()
        c.track_values = self.track_values
        c.delete_epoch = self.delete_epoch
        return c


@dataclasses.dataclass(eq=False)  # identity equality: segments hold arrays
class Segment:
    """One sealed immutable segment: an index plus its external-id remap.

    ``ids[row] = external id`` of the row-th point handed to the segment's
    build (ascending, because flush/compaction keep insertion order), so a
    local search result maps to stable ids with one gather.
    """

    index: HilbertIndex
    ids: np.ndarray  # (n,) int32, ascending external ids
    gen: int  # monotone generation tag (stable on-disk segment name)
    # With IndexConfig.seal_pow2, seal builds cyclically repeat real rows
    # up to a power-of-two count for shape-stable jitted search; rows past
    # ``n_valid`` are duplicates of earlier ones (same external id, so the
    # cross-source merge dedups them).  -1 = unpadded (n_valid == n_points).
    n_valid: int = -1
    # dead-count cache: recomputed only when the owner's delete epoch moves.
    dead_cache: int = dataclasses.field(default=-1, repr=False)
    dead_epoch: int = dataclasses.field(default=-1, repr=False)

    @property
    def n_points(self) -> int:
        return int(self.ids.shape[0])

    @property
    def n_real(self) -> int:
        """Rows that are NOT pow2 padding duplicates (a prefix of ids)."""
        return self.n_valid if self.n_valid >= 0 else self.n_points

    @property
    def n_pad(self) -> int:
        return self.n_points - self.n_real

    def memory_bytes(self) -> int:
        return self.index.memory_report()["resident_bytes"] + self.ids.nbytes

    def content_uid(self) -> str:
        """Content address for on-disk dedup: hashes ids + quantized codes.

        Two segments with equal uids hold the same points under the same
        external ids, so a save may safely skip rewriting a bundle that
        already carries this uid — even if it was written by a different
        index instance reusing the same checkpoint path.  Codes are hashed
        in their resident nibble-packed layout, so bundles written by the
        old unpacked-uint8 format never collide with packed ones and are
        rewritten on the first save after an upgrade.
        """
        h = hashlib.sha1()
        h.update(np.int64(self.gen).tobytes())
        h.update(np.asarray(self.ids.shape + self.index.codes_master.shape,
                            np.int64).tobytes())
        h.update(self.ids.tobytes())
        h.update(np.asarray(self.index.codes_master).tobytes())
        return h.hexdigest()


class WalFacade:
    """WAL attachment + log-then-apply hooks shared by both mutable facades.

    Host classes provide ``self._lsm`` (an :class:`LsmIdSpace`),
    ``self._dim``, and initialise ``self._wal = None``.  Mutating methods
    call :meth:`_wal_log_insert` / :meth:`_wal_log_delete` BEFORE touching
    any state: the record is durable (or the append raised) by the time the
    op applies, so an acknowledged mutation can never be lost to a crash.
    """

    _wal: Optional[wal_lib.WriteAheadLog]

    @property
    def wal(self) -> Optional[wal_lib.WriteAheadLog]:
        return self._wal

    def enable_wal(
        self, path: str, config: Optional[wal_lib.WalConfig] = None
    ) -> wal_lib.WriteAheadLog:
        """Attach a write-ahead log at ``<path>/wal.log``.

        ``path`` is the checkpoint directory this index saves to:
        ``save(path)`` truncates the log at its commit point, and
        ``load(path)`` replays + re-attaches it automatically.  The file
        must be fresh (no unreplayed records) — recovering an existing
        log is ``load()``'s job, not this method's.
        """
        if self._wal is not None:
            raise ValueError("a WAL is already attached to this index")
        os.makedirs(path, exist_ok=True)
        self._wal = wal_lib.WriteAheadLog(wal_lib.wal_path(path), config)
        return self._wal

    def detach_wal(self) -> Optional[wal_lib.WriteAheadLog]:
        """Detach (without closing) and return the WAL, if any."""
        w, self._wal = self._wal, None
        return w

    def _wal_log_insert(self, op: str, points, values) -> None:
        if self._wal is None:
            return
        # prepare() validates without mutating, so nothing is logged for
        # an insert that would raise — and a WAL failure below leaves the
        # index untouched (the op is then applied by nobody).
        pts, vals = self._lsm.prepare(points, values, self._dim)
        if pts.shape[0] == 0:
            return
        arrays = {"points": pts}
        if vals is not None:
            arrays["values"] = vals
        self._wal.append(op, arrays, {"next_id": int(self._lsm.next_id)})

    def _wal_log_delete(self, ids) -> None:
        if self._wal is None:
            return
        idn = np.atleast_1d(np.asarray(jax.device_get(ids))).astype(np.int64)
        if idn.size == 0:
            return
        if (idn < 0).any() or (idn >= self._lsm.next_id).any():
            bad = idn[(idn < 0) | (idn >= self._lsm.next_id)]
            raise KeyError(f"unknown external ids: {bad[:8].tolist()}")
        self._wal.append(
            "delete", {"ids": idn.astype(np.int32)},
            {"next_id": int(self._lsm.next_id)},
        )


class MutableHilbertIndex(WalFacade):
    """Streaming insert/delete/search over an LSM of Hilbert-forest segments.

    Typical lifecycle::

        mut = MutableHilbertIndex(IndexConfig(), buffer_capacity=4096)
        ids = mut.insert(points)          # stable external ids
        mut.delete(ids[:10])              # tombstoned, invisible to search
        hits, d2 = mut.search(queries, SearchParams(k=30))
        mut.compact()                     # one segment, tombstones dropped
        mut.save(path); mut = MutableHilbertIndex.load(path)

    ``insert`` may carry per-point ``values`` (e.g. kNN-LM next tokens);
    retrieve them for search hits with :meth:`values_at`.
    """

    def __init__(
        self,
        config: Optional[IndexConfig] = None,
        *,
        buffer_capacity: int = 4096,
        max_segments: int = 8,
    ):
        if buffer_capacity < 1:
            raise ValueError(f"buffer_capacity must be >= 1, got {buffer_capacity}")
        if max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {max_segments}")
        self.config = IndexConfig() if config is None else config
        self.buffer_capacity = int(buffer_capacity)
        self.max_segments = int(max_segments)
        self.segments: List[Segment] = []
        self._dim: Optional[int] = None
        self._buf_points: Optional[np.ndarray] = None  # (capacity, d) f32
        self._buf_ids: Optional[np.ndarray] = None  # (capacity,) int32
        self._buf_count = 0
        self._lsm = LsmIdSpace()  # external ids / tombstones / values
        self._gen = 0
        self._wal: Optional[wal_lib.WriteAheadLog] = None

    # -- LsmIdSpace shims (the historical attribute names, kept so segment
    # bookkeeping below and external pokes keep reading naturally) ----------

    @property
    def _alive(self) -> np.ndarray:
        return self._lsm.alive

    @_alive.setter
    def _alive(self, v) -> None:
        self._lsm.alive = v

    @property
    def _next_id(self) -> int:
        return self._lsm.next_id

    @_next_id.setter
    def _next_id(self, v) -> None:
        self._lsm.next_id = v

    @property
    def _values(self) -> Optional[np.ndarray]:
        return self._lsm.values

    @_values.setter
    def _values(self, v) -> None:
        self._lsm.values = v

    @property
    def _track_values(self) -> Optional[bool]:
        return self._lsm.track_values

    @_track_values.setter
    def _track_values(self, v) -> None:
        self._lsm.track_values = v

    @property
    def _delete_epoch(self) -> int:
        return self._lsm.delete_epoch

    # -- introspection -------------------------------------------------------

    @property
    def dim(self) -> Optional[int]:
        return self._dim

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def n_live(self) -> int:
        """Points visible to search (inserted, not deleted)."""
        return int(np.count_nonzero(self._alive))

    @property
    def n_deleted(self) -> int:
        return int(self._next_id - self.n_live)

    @property
    def n_buffered(self) -> int:
        """Live points still in the write buffer (not yet in a segment)."""
        if self._buf_count == 0:
            return 0
        return int(np.count_nonzero(self._alive[self._buf_ids[: self._buf_count]]))

    def memory_report(self) -> Dict[str, Any]:
        """Bytes for ALL resident state: segments, buffer, values, tombstones."""
        per_segment = [seg.memory_bytes() for seg in self.segments]
        buffer_bytes = 0
        if self._buf_points is not None:
            buffer_bytes = self._buf_points.nbytes + self._buf_ids.nbytes
        rep: Dict[str, Any] = {
            "segments_bytes": int(sum(per_segment)),
            "buffer_bytes": int(buffer_bytes),
            "values_bytes": 0 if self._values is None else int(self._values.nbytes),
            "tombstone_bytes": int(self._alive.nbytes),
            "per_segment": [int(b) for b in per_segment],
            "n_segments": self.n_segments,
            "n_live": self.n_live,
            "n_deleted": self.n_deleted,
            "n_buffered": self.n_buffered,
        }
        rep["total_bytes"] = (
            rep["segments_bytes"]
            + rep["buffer_bytes"]
            + rep["values_bytes"]
            + rep["tombstone_bytes"]
        )
        return rep

    def __repr__(self) -> str:
        mb = self.memory_report()["total_bytes"] / 1e6
        return (
            f"MutableHilbertIndex(n_live={self.n_live}, "
            f"n_segments={self.n_segments}, "
            f"buffered={self.n_buffered}/{self.buffer_capacity}, "
            f"deleted={self.n_deleted}, dim={self._dim}, {mb:.2f} MB)"
        )

    # -- mutation ------------------------------------------------------------

    def _register(
        self, points, values
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Shared insert bookkeeping: dims, values mode, ids, alive mask.

        ``prepare`` validates EVERYTHING before any state mutation
        (including pinning the values mode): a failed insert must leave
        the index unchanged.
        """
        pts, vals = self._lsm.prepare(points, values, self._dim)
        if pts.shape[0] == 0:
            return pts, np.zeros((0,), np.int32)
        if self._dim is None:
            self._dim = int(pts.shape[1])
            self._buf_points = np.zeros(
                (self.buffer_capacity, self._dim), np.float32
            )
            self._buf_ids = np.full((self.buffer_capacity,), -1, np.int32)
        return pts, self._lsm.register(pts.shape[0], vals)

    def insert(
        self, points: jax.Array, values: Optional[jax.Array] = None
    ) -> np.ndarray:
        """Insert points; each sealed segment later rides the paper's fast
        Hilbert-sort build (Algorithm 1 preprocessing) — what makes
        merge-based maintenance cheap.

        Args:
          points: (m, d) fp32 rows (a single (d,) row is promoted).
          values: optional (m, ...) per-point payloads; the first insert
            pins whether the index tracks values.

        Returns:
          (m,) int32 stable external ids.

        Points land in the write buffer (searchable immediately, exactly);
        each buffer fill seals a segment, and tier merging keeps the segment
        count at most ``max_segments``.  ``values`` attaches one payload per
        point — either every insert carries values or none does.

        With a WAL attached the insert is logged BEFORE any state changes
        (log-then-apply): a crash at any later instant replays it, and a
        failed log (:class:`repro.checkpoint.WalWriteError`) leaves the
        index untouched — the insert was never acknowledged.
        """
        self._wal_log_insert("insert", points, values)
        pts, ids = self._register(points, values)
        m = pts.shape[0]
        if m == 0:
            return ids

        done = 0
        while done < m:
            take = min(self.buffer_capacity - self._buf_count, m - done)
            sl = slice(self._buf_count, self._buf_count + take)
            self._buf_points[sl] = pts[done : done + take]
            self._buf_ids[sl] = ids[done : done + take]
            self._buf_count += take
            done += take
            if self._buf_count >= self.buffer_capacity:
                self.flush()
        self._maybe_merge_tiers()
        return ids

    def bulk_load(
        self, points: jax.Array, values: Optional[jax.Array] = None
    ) -> np.ndarray:
        """Seal a whole corpus as ONE segment, bypassing the write buffer.

        The LSM bulk-load path: the initial corpus of a store should be one
        large segment (search latency/recall identical to a static
        ``HilbertIndex``), not ``n/buffer_capacity`` small ones.  Returns
        external ids like :meth:`insert`.
        """
        self._wal_log_insert("bulk_load", points, values)
        if self._buf_count:
            self.flush()
        pts, ids = self._register(points, values)
        if pts.shape[0] == 0:
            raise ValueError("bulk_load needs a non-empty (m, d) corpus")
        self.segments.append(self._build_segment(pts, ids))
        self._maybe_merge_tiers()
        return ids

    def delete(self, ids) -> int:
        """Tombstone external ids; returns how many were newly deleted.

        Out-of-range ids raise ``KeyError``; already-deleted ids are a no-op
        (idempotent).  Rows are physically dropped at the next flush (buffer
        rows) or compaction touching their segment.
        """
        self._wal_log_delete(ids)
        return self._lsm.delete(ids)

    # -- write-ahead log: wal / enable_wal / detach_wal and the log-then-
    # apply hooks come from WalFacade (shared with the sharded facade) ------

    def _segment_dead(self, seg: Segment) -> int:
        """Tombstone count among a segment's REAL rows, cached between
        deletes (pow2 padding duplicates are accounted separately).

        Safe under the engine's SHARED read lock: deletes (the only thing
        that moves ``_delete_epoch``) hold the write side, so concurrent
        readers can at worst race an identical idempotent fill — and the
        cache value is written BEFORE the epoch stamp, so a reader that
        observes the fresh epoch always reads the fresh count.
        """
        if seg.dead_epoch != self._delete_epoch:
            seg.dead_cache = seg.n_real - int(
                np.count_nonzero(self._alive[seg.ids[: seg.n_real]])
            )
            seg.dead_epoch = self._delete_epoch
        return seg.dead_cache

    def rewrite_pressure(self, params: Optional[SearchParams] = None) -> int:
        """Segments so tombstoned that dead rows can crowd live neighbors
        out of the stage-2 candidate pool under ``params``.

        This is the condition that used to trigger a rewrite INSIDE
        ``search()``.  The serving engine searches with
        ``allow_rewrite=False`` (its read path must not mutate under the
        shared read lock), so the same condition is surfaced here as a
        maintenance trigger instead: a nonzero pressure trips
        :class:`~repro.serve.engine.MaintenancePolicy` and the maintainer
        compacts off the query path.
        """
        if params is None:
            params = SearchParams()
        cap = params.k2 * (2 * params.h + 1)
        n = 0
        for seg in list(self.segments):
            dead = self._segment_dead(seg)
            need = (params.k + dead) * (2 if seg.n_pad else 1)
            if dead > 0 and need > cap and seg.index.points is not None:
                n += 1
        return n

    # -- segment lifecycle ---------------------------------------------------

    def _build_segment(self, pts: np.ndarray, ids: np.ndarray,
                       *, pad: bool = False) -> Segment:
        # config.store_points is honored: True (the default) keeps raw fp32
        # points on each segment so compaction can re-sort them; False saves
        # that RAM for serving-only deployments at the cost of compaction
        # (tier merges skip point-less segments; compact() raises).
        n_valid = int(pts.shape[0])
        if pad and self.config.seal_pow2:
            # Shape-stable seals: cyclically repeat real rows up to the
            # next power of two.  Duplicates share their original's
            # external id, so the merge dedups them; compact() and bulk
            # loads never pad (pad=False) and stay bit-equal to a fresh
            # build over the live rows.
            target = _pow2_ceil(max(n_valid, 1))
            if target > n_valid:
                reps = -(-target // n_valid)
                pts = np.tile(pts, (reps, 1))[:target]
                ids = np.tile(ids, reps)[:target]
        with span("lsm.segment_build", rows=int(pts.shape[0])), \
                dispatch_scope("lsm.segment_build"):
            index = HilbertIndex.build(jnp.asarray(pts), self.config)
        seg = Segment(index=index, ids=np.ascontiguousarray(ids, np.int32),
                      gen=self._gen, n_valid=n_valid)
        self._gen += 1
        return seg

    def flush(self) -> Optional[Segment]:
        """Seal the write buffer's live rows into an immutable segment.

        Dead buffer rows are dropped here for good.  No-op (returns None) on
        an empty or fully tombstoned buffer.
        """
        if self._buf_count == 0:
            return None
        ids = self._buf_ids[: self._buf_count]
        live = self._alive[ids]
        pts = self._buf_points[: self._buf_count][live].copy()
        ids = ids[live].copy()
        self._buf_count = 0
        if ids.size == 0:
            return None
        seg = self._build_segment(pts, ids, pad=True)
        self.segments.append(seg)
        return seg

    def _merge_segments(self, to_merge: Sequence[Segment],
                        *, pad: bool = False) -> Optional[Segment]:
        """Replace ``to_merge`` with one segment; tombstoned rows vanish."""
        for seg in to_merge:
            if seg.index.points is None:
                raise ValueError(
                    "cannot compact a segment built without stored points "
                    "(IndexConfig(store_points=False), or a store_points="
                    "False index adopted via from_index)"
                )
        # Pow2 padding rows (duplicates past n_real) are excluded here, so
        # merges — and in particular compact() — see exactly the real rows.
        pts = np.concatenate(
            [np.asarray(seg.index.points, np.float32)[: seg.n_real]
             for seg in to_merge]
        )
        ids = np.concatenate([seg.ids[: seg.n_real] for seg in to_merge])
        live = self._alive[ids]
        pts, ids = pts[live], ids[live]
        # External-id order == insertion order: a full compaction therefore
        # feeds the rebuild the same point sequence a fresh build would see.
        order = np.argsort(ids, kind="stable")
        pts, ids = pts[order], ids[order]
        self.segments = [s for s in self.segments if s not in to_merge]
        if ids.size == 0:
            return None
        seg = self._build_segment(pts, ids, pad=pad)
        self.segments.append(seg)
        return seg

    def _maybe_merge_tiers(self) -> None:
        while len(self.segments) > self.max_segments:
            # Only segments holding raw points can be re-sorted; without
            # store_points the segment count is unbounded by design.
            mergeable = [s for s in self.segments if s.index.points is not None]
            if len(mergeable) < 2:
                return
            smallest = sorted(mergeable, key=lambda s: s.n_points)[:2]
            self._merge_segments(smallest, pad=True)

    def compact(self) -> "MutableHilbertIndex":
        """Full compaction: flush, then merge ALL segments into one.

        Afterwards the index holds at most one segment containing exactly
        the live points in insertion order, and every tombstoned row has
        been physically dropped.  Returns self (chainable).
        """
        with span("lsm.compact", segments=len(self.segments)):
            self.flush()
            if self.segments:
                self._merge_segments(list(self.segments))
        return self

    # -- serving-engine hooks ------------------------------------------------

    def snapshot(self) -> "MutableHilbertIndex":
        """Cheap shared-buffer copy for off-path maintenance (double-buffer).

        Sealed segments are immutable, so the snapshot SHARES their arrays
        (zero copy — the dominant state) under fresh :class:`Segment`
        wrappers (per-segment dead-count caches must not race between the
        serving copy and the shadow); only the write buffer and the LSM
        bookkeeping (alive mask, values, id cursor) are deep-copied.  The
        snapshot is a fully independent index: a serving engine hands it to
        a maintenance thread, compacts it off the query path, replays the
        writes that arrived meanwhile, and swaps it in (see
        :mod:`repro.serve.engine`).
        """
        snap = MutableHilbertIndex(
            config=self.config,
            buffer_capacity=self.buffer_capacity,
            max_segments=self.max_segments,
        )
        snap._dim = self._dim
        if self._dim is not None:
            snap._buf_points = self._buf_points.copy()
            snap._buf_ids = self._buf_ids.copy()
        snap._buf_count = self._buf_count
        snap._lsm = self._lsm.clone()
        snap._gen = self._gen
        snap.segments = [
            Segment(index=seg.index, ids=seg.ids, gen=seg.gen,
                    n_valid=seg.n_valid)
            for seg in self.segments
        ]
        # Deliberately NOT copied: the WAL.  A snapshot is the engine's
        # shadow — replaying writes onto it must not re-log them; the live
        # index's WAL transfers at swap time (see serve/engine.py).
        return snap

    def maintenance_stats(self) -> Dict[str, Any]:
        """The trigger signals a background maintainer watches (host-only).

        ``tombstone_ratio`` is dead/allocated ids; ``mergeable_segments``
        counts segments that actually hold raw points (the only ones a
        merge or compaction can re-sort).
        """
        next_id = max(self._next_id, 1)
        return {
            "n_segments": self.n_segments,
            "mergeable_segments": sum(
                1 for s in self.segments if s.index.points is not None
            ),
            "n_live": self.n_live,
            "n_deleted": self.n_deleted,
            "n_buffered": self.n_buffered,
            "tombstone_ratio": float(self.n_deleted) / float(next_id),
        }

    # -- search --------------------------------------------------------------

    def search(
        self,
        queries: jax.Array,
        params: Optional[SearchParams] = None,
        *,
        backend: str = "auto",
        query_chunk: Optional[int] = None,
        allow_rewrite: bool = True,
    ) -> Tuple[jax.Array, jax.Array]:
        """Fan-out Algorithm-1 top-k over buffer + segments, merged exactly.

        Args:
          queries: (Q, d) fp32 query batch.
          params: Algorithm-1 hyper-parameters (paper Table 1 names);
            applied per segment, with per-segment ``k`` inflation for
            tombstones (:func:`repro.core.search.inflate_k`).
          backend: kernel routing for the segment searches.
          query_chunk: per-dispatch chunk cap (default
            ``config.query_chunk``).
          allow_rewrite: permit read-triggered compaction (below).  The
            serving engine passes ``False``: its searches run under a
            SHARED read lock, so the read path must not mutate segments —
            the same condition is surfaced via :meth:`rewrite_pressure`
            and handled by the maintainer off the query path instead.

        Returns (ids (Q, k), sq-distances (Q, k)) like ``HilbertIndex.search``
        but with **external** ids; when fewer than k live points exist the
        tail is padded with id -1 / distance +inf.  Segment distances are
        ADC (asymmetric vs 4-bit codes) as in the paper; buffer distances
        are exact fp32 — both approximate the true metric, and the merge
        compares them directly.  Each segment is queried for
        ``k + (its tombstone count)`` so masked rows cannot displace live
        results — up to the stage-2 candidate pool (``k2*(2h+1)``).  A
        segment tombstoned past that bound is rewritten on the spot
        (read-triggered compaction) when it stores raw points; without
        stored points (or with ``allow_rewrite=False``) its recall
        degrades until it is compacted or the ids are reinserted.
        """
        if params is None:
            params = SearchParams()
        q = jnp.asarray(queries)
        qn, k = q.shape[0], params.k
        # stage-2 candidate pool per segment; lax.top_k caps k there.
        cap = params.k2 * (2 * params.h + 1)
        parts_ids: List[np.ndarray] = []
        parts_d: List[np.ndarray] = []
        for seg in list(self.segments):
            dead = self._segment_dead(seg)
            # Pow2 padding duplicates each real row at most twice (pad <
            # n_real by construction), so a padded segment needs 2x the
            # candidate slots to guarantee the same count of DISTINCT live
            # results; unpadded segments keep the historical k + dead.
            need = (k + dead) * (2 if seg.n_pad else 1)
            if (allow_rewrite and dead > 0 and need > cap
                    and seg.index.points is not None):
                # So many tombstones that dead candidates could crowd live
                # neighbors out of the stage-1/2 candidate pools (k can no
                # longer be inflated past the pool size).  Read-triggered
                # compaction: rewrite just this segment, dropping its dead
                # rows for good, then search the clean replacement.
                seg = self._merge_segments([seg], pad=True)
                if seg is None:  # segment was fully tombstoned
                    continue
                dead = 0
                need = k * (2 if seg.n_pad else 1)
            k_seg = search_lib.inflate_k(k, need - k, cap)
            sids, sd2 = seg.index.search(
                q, dataclasses.replace(params, k=k_seg),
                backend=backend, query_chunk=query_chunk,
            )
            sids = np.clip(np.asarray(sids), 0, seg.n_points - 1)
            parts_ids.append(seg.ids[sids])
            parts_d.append(np.asarray(sd2, np.float32))
        if self.n_buffered:
            valid = np.zeros((self.buffer_capacity,), np.bool_)
            bids = self._buf_ids[: self._buf_count]
            valid[: self._buf_count] = self._alive[bids]
            with dispatch_scope("lsm.buffer_search"):
                idx, bd2 = search_lib.brute_force_topk(
                    q, jnp.asarray(self._buf_points), jnp.asarray(valid),
                    k=min(k, self.buffer_capacity),
                )
            parts_ids.append(self._buf_ids[np.asarray(idx)])
            parts_d.append(np.asarray(bd2, np.float32))
        if not parts_ids:
            return (
                jnp.full((qn, k), -1, jnp.int32),
                jnp.full((qn, k), jnp.inf, jnp.float32),
            )
        ids = np.concatenate(parts_ids, axis=1)
        d2 = np.concatenate(parts_d, axis=1)
        # Tombstone masking stays host-side (the dense alive mask is numpy);
        # the dedup + rank + pad tail is the shared associative merge — the
        # same `merge_topk` the sharded index uses across shards.
        dead = ~self._alive[np.clip(ids, 0, max(self._next_id - 1, 0))]
        d2 = np.where(dead, np.inf, d2)
        with dispatch_scope("lsm.merge"):
            return search_lib.merge_topk(
                jnp.asarray(ids, jnp.int32), jnp.asarray(d2, jnp.float32), k=k
            )

    # -- values --------------------------------------------------------------

    def values_at(self, ids, fill=0) -> jax.Array:
        """Gather per-point values for search-result ids; -1 slots get fill."""
        return self._lsm.values_at(ids, fill=fill)

    def values_dense(self) -> jax.Array:
        """The dense by-external-id values array (stale rows where deleted)."""
        return self._lsm.values_dense()

    # -- adoption ------------------------------------------------------------

    @classmethod
    def from_index(
        cls,
        index: HilbertIndex,
        *,
        values: Optional[jax.Array] = None,
        buffer_capacity: int = 4096,
        max_segments: int = 8,
    ) -> "MutableHilbertIndex":
        """Adopt a prebuilt immutable index as segment 0 (ids = 0..n-1).

        If the index was built with ``store_points=False`` it can serve and
        absorb inserts/deletes, but compactions touching segment 0 raise
        (no raw points to re-sort).
        """
        self = cls(
            config=index.config,
            buffer_capacity=buffer_capacity,
            max_segments=max_segments,
        )
        n = index.n_points
        self._dim = index.dim
        self._buf_points = np.zeros((self.buffer_capacity, self._dim), np.float32)
        self._buf_ids = np.full((self.buffer_capacity,), -1, np.int32)
        self._next_id = n
        self._alive = np.ones((n,), np.bool_)
        if values is not None:
            vals = np.asarray(jax.device_get(values))
            if vals.shape[:1] != (n,):
                raise ValueError(f"values must be ({n}, ...)")
            self._values = vals.copy()
        # Pin the values mode now: a later insert(..., values=...) on a
        # valueless adoption would misalign the dense values array with the
        # already-assigned external ids 0..n-1.
        self._track_values = values is not None
        self.segments = [
            Segment(index=index, ids=np.arange(n, dtype=np.int32), gen=0)
        ]
        self._gen = 1
        return self

    # -- persistence ---------------------------------------------------------

    def save(self, path: str, *, kind: str = _DEFAULT_KIND,
             extra_meta: Optional[Dict] = None) -> str:
        return save_mutable_bundle(self, path, kind=kind, extra_meta=extra_meta)

    @classmethod
    def load(cls, path: str, *, kind: str = _DEFAULT_KIND
             ) -> "MutableHilbertIndex":
        index, _ = load_mutable_bundle(path, kind=kind)
        return index


def save_mutable_bundle(
    index: MutableHilbertIndex,
    path: str,
    *,
    kind: str = _DEFAULT_KIND,
    extra_meta: Optional[Dict] = None,
) -> str:
    """Persist a mutable index as segment bundles + state bundle + manifest.

    Each piece is an atomic ``repro.checkpoint`` bundle and NOTHING a
    previous manifest references is ever rewritten in place: segments are
    immutable and keyed by generation (an existing bundle with a matching
    uid is skipped, so repeated saves only write what changed) and the
    mutable buffer/tombstone state goes to a FRESH step each save, with the
    step recorded in the manifest.  The top-level JSON manifest is renamed
    into place LAST, so a crash mid-save — or a concurrent load in another
    worker — always observes a complete, mutually consistent
    (manifest, bundles) pair.

    After the manifest commits, bundles referenced by neither the new nor
    the immediately-previous manifest are pruned (writers are assumed
    single; readers get one manifest generation of grace), so repeated
    saves to one path occupy bounded disk.
    """
    os.makedirs(path, exist_ok=True)
    prev_manifest = {}
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            prev_manifest = json.load(f)
    except (OSError, ValueError):
        pass
    seg_names = []
    for seg in index.segments:
        name = f"seg_{seg.gen:06d}"
        seg_dir = os.path.join(path, "segments", name)
        # Content-addressed dedup: only skip the write when the bundle on
        # disk holds exactly this segment's ids+codes (a different index
        # saved to the same path therefore can never leave stale data).
        uid = seg.content_uid()
        if _segment_bundle_uid(seg_dir) != uid:
            save_index_bundle(
                seg.index,
                seg_dir,
                kind=_SEGMENT_KIND,
                extra_arrays={"ids": jnp.asarray(seg.ids)},
                extra_meta={"segment_uid": uid, "n_valid": seg.n_real},
            )
        seg_names.append(name)
    # Buffer state: the raw occupied slice, tombstoned rows included.
    # Keeping dead rows makes load() reconstruct the in-memory state
    # EXACTLY (same buffer occupancy, so later flush boundaries fall at
    # the same ops) — the invariant WAL recovery's bit-equality rests on.
    # Dead rows still drop for good at the next flush, as before.
    d = index._dim if index._dim is not None else 0
    bids = (index._buf_ids[: index._buf_count].copy()
            if index._buf_count else np.zeros((0,), np.int32))
    bpts = (index._buf_points[: index._buf_count].copy()
            if index._buf_count else np.zeros((0, d), np.float32))
    state: Dict[str, np.ndarray] = {
        "alive": index._alive,
        "buffer_points": bpts,
        "buffer_ids": bids,
    }
    if index._values is not None:
        state["values"] = index._values
    state_dir = os.path.join(path, "state")
    state_step = (checkpoint.latest_step(state_dir) or 0) + 1
    checkpoint.save(state_dir, step=state_step, tree=state, extra={})
    manifest = {
        "state_step": state_step,
        "kind": kind,
        "format_version": 1,
        "config": index.config.to_dict(),
        "buffer_capacity": index.buffer_capacity,
        "max_segments": index.max_segments,
        "next_id": int(index._next_id),
        "gen": int(index._gen),
        "dim": index._dim,
        "track_values": index._track_values,
        "segments": seg_names,
        "extra_meta": extra_meta or {},
    }
    fault_point("mutable.save.pre_manifest", path=os.path.join(path, _MANIFEST))
    checkpoint.atomic_write_json(os.path.join(path, _MANIFEST), manifest)
    _prune_unreferenced(path, manifest, prev_manifest)
    # The manifest now covers every acknowledged write: the WAL's records
    # are redundant and the log restarts empty.  A crash BETWEEN the
    # commit and this truncate only means records replay onto state that
    # already contains them — their next_id watermarks make that a no-op.
    if index._wal is not None:
        index._wal.truncate()
    return path


def _prune_unreferenced(path: str, manifest: Dict, prev_manifest: Dict) -> None:
    """Drop bundles neither the new nor the previous manifest references."""
    keep_segs = set(manifest["segments"]) | set(prev_manifest.get("segments", []))
    seg_root = os.path.join(path, "segments")
    if os.path.isdir(seg_root):
        for name in os.listdir(seg_root):
            if name.startswith("seg_") and name not in keep_segs:
                shutil.rmtree(os.path.join(seg_root, name), ignore_errors=True)
    checkpoint.prune_steps(
        os.path.join(path, "state"),
        {manifest["state_step"], prev_manifest.get("state_step")},
    )


def _segment_bundle_uid(seg_dir: str) -> Optional[str]:
    """uid of an already-saved segment bundle, or None if absent/unreadable."""
    step = checkpoint.latest_step(seg_dir)
    if step is None:
        return None
    try:
        with open(os.path.join(seg_dir, f"step_{step:08d}",
                               "manifest.json")) as f:
            return json.load(f).get("extra", {}).get("segment_uid")
    except (OSError, ValueError):
        return None


def _restore_state_bundle(path: str, step: Optional[int]
                          ) -> Dict[str, np.ndarray]:
    """Load every leaf of a checkpoint bundle with manifest-declared dtypes."""
    if step is None:  # pre-state_step manifests: newest available
        step = checkpoint.latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no state bundle under {path!r}")
    with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
        manifest = json.load(f)
    abstract = {}
    for key, (_, dtype_str) in manifest["leaves"].items():
        abstract[key[2:-2]] = jax.ShapeDtypeStruct((0,), np.dtype(dtype_str))
    arrays, _ = checkpoint.restore(path, step, abstract)
    # np.array (not asarray): device_get hands back read-only views, and
    # this state is mutated in place by post-restore deletes/WAL replay
    return {k: np.array(jax.device_get(v)) for k, v in arrays.items()}


def load_mutable_bundle(
    path: str, *, kind: str = _DEFAULT_KIND
) -> Tuple[MutableHilbertIndex, Dict]:
    """Inverse of :func:`save_mutable_bundle`; returns (index, extra_meta)."""
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"no mutable-index manifest under {path!r}")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("kind") != kind:
        raise ValueError(
            f"{path!r} is not a mutable-index checkpoint of kind {kind!r} "
            f"(kind={manifest.get('kind')!r})"
        )
    index = MutableHilbertIndex(
        config=IndexConfig.from_dict(manifest["config"]),
        buffer_capacity=int(manifest["buffer_capacity"]),
        max_segments=int(manifest["max_segments"]),
    )
    for name in manifest["segments"]:
        seg_index, extras, seg_meta = load_index_bundle(
            os.path.join(path, "segments", name), kind=_SEGMENT_KIND
        )
        index.segments.append(
            Segment(
                index=seg_index,
                ids=np.asarray(jax.device_get(extras["ids"]), np.int32),
                gen=int(name.split("_")[1]),
                n_valid=int(seg_meta.get("n_valid", -1)),
            )
        )
    state = _restore_state_bundle(
        os.path.join(path, "state"), manifest.get("state_step")
    )
    index._alive = np.asarray(state["alive"], np.bool_)
    index._next_id = int(manifest["next_id"])
    index._gen = int(manifest["gen"])
    index._track_values = manifest.get("track_values")
    if "values" in state:
        index._values = state["values"]
    dim = manifest.get("dim")
    if dim is not None:
        index._dim = int(dim)
        index._buf_points = np.zeros((index.buffer_capacity, index._dim),
                                     np.float32)
        index._buf_ids = np.full((index.buffer_capacity,), -1, np.int32)
        bpts, bids = state["buffer_points"], state["buffer_ids"]
        m = int(bids.shape[0])
        if m:
            index._buf_points[:m] = bpts
            index._buf_ids[:m] = bids
        index._buf_count = m
    _recover_wal(index, path)
    return index, manifest.get("extra_meta", {})


def _recover_wal(index: MutableHilbertIndex, path: str) -> None:
    """Replay + re-attach ``<path>/wal.log`` if the index was WAL-enabled.

    Replays the acknowledged tail (everything since the manifest last
    truncated the log) in original order on top of the restored state,
    then re-attaches the log so durability stays on.  Records whose
    ``next_id`` watermark the restored state already covers are skipped —
    the crash-between-commit-and-truncate window.
    """
    wfile = wal_lib.wal_path(path)
    if not os.path.exists(wfile):
        return
    records, wal = wal_lib.open_and_recover(wfile)
    replay_wal_records(index, records)
    index._wal = wal


def replay_wal_records(index, records) -> int:
    """Apply WAL records to a WAL-less index; returns ops applied.

    Shared by both mutable facades (they expose the same insert/
    bulk_load/delete and ``_lsm``).  The caller must not have a WAL
    attached yet, or the replay would re-log itself.
    """
    if getattr(index, "_wal", None) is not None:
        raise ValueError("detach the WAL before replaying records into it")
    applied = 0
    for rec in records:
        if rec.op in ("insert", "bulk_load"):
            wm = rec.meta.get("next_id")
            if wm is not None and wm < index._lsm.next_id:
                continue  # the restored checkpoint already contains it
            vals = rec.arrays.get("values")
            if rec.op == "bulk_load":
                index.bulk_load(rec.arrays["points"], vals)
            else:
                index.insert(rec.arrays["points"], vals)
        elif rec.op == "delete":
            # Idempotent: re-deleting checkpoint-covered ids is a no-op.
            index.delete(rec.arrays["ids"])
        else:
            raise wal_lib.WalError(f"unknown WAL op {rec.op!r}")
        applied += 1
    return applied
