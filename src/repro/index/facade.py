"""HilbertIndex: the unified, self-describing Hilbert-forest index.

One artifact, three uses (paper: SISAP 2025 Tasks 1/2 + serving):

* ``HilbertIndex.build(points, cfg)`` — Task-1 preprocessing (quantizer,
  sketches, forest, master order) behind one call.
* ``.search(queries, params)`` — Algorithm-1 ANN search.  The index carries
  its build-time :class:`IndexConfig`, so no config argument exists to
  mismatch (the legacy API's silent-corruption footgun).
* ``.knn_graph(params)`` — Algorithm-2 graph construction **reusing** the
  already-fit quantizer/codes/sketches instead of re-fitting.
* ``.save(path)`` / ``HilbertIndex.load(path)`` — atomic persistence on the
  ``repro.checkpoint`` machinery; build once, load in many serving workers.

The class is a registered JAX pytree (arrays are children, the config is
static aux data), so an index can be passed through ``jax.jit``/``tree_map``
or device_put like any array bundle.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core import forest as forest_lib
from repro.core import knn_graph as knn_graph_lib
from repro.core import quantize, sketch
from repro.core import search as search_lib
from repro.core.types import GraphParams, SearchParams
from repro.index.config import IndexConfig
from repro.obs.dispatch import dispatch_scope
from repro.obs.trace import span

__all__ = [
    "HilbertIndex",
    "BoundedJitCache",
    "build_with_timings",
    "resolve_backend",
    "save_index_bundle",
    "load_index_bundle",
]

_INF = jnp.int32(2**30)

BACKENDS = ("auto", "xla", "pallas")

# Leaf dtypes of the serialized array bundle (manifest-independent, so load
# never trusts dtypes from disk beyond a cast to these).  ``codes_master``
# is nibble-packed uint32 since format_version 2; v1 bundles stored it
# unpacked uint8 and are repacked transparently on load.
_FORMAT_VERSION = 2
_LEAF_DTYPES = {
    "forest.perms": jnp.int32,
    "forest.flips": jnp.bool_,
    "forest.orders": jnp.int32,
    "forest.directories": jnp.uint32,
    "forest.lo": jnp.float32,
    "forest.hi": jnp.float32,
    "quant.boundaries": jnp.float32,
    "quant.centroids": jnp.float32,
    "codes_master": jnp.uint32,
    "sketches_master": jnp.uint32,
    "master_order": jnp.int32,
    "master_rank": jnp.int32,
    "points": jnp.float32,
}


def _pow2_bucket(m: int, cap: int) -> int:
    """Smallest power of two >= m, capped at ``cap`` (the chunk size)."""
    b = 1
    while b < m and b < cap:
        b <<= 1
    return min(b, cap)


class BoundedJitCache:
    """LRU-bounded cache of compiled per-shape dispatch closures.

    The sharded facades key one jitted shard_map executable per
    (bucket, k, merge-knob, ...) tuple.  Keys recycle by construction in
    steady state (pow2 query buckets, pow2-padded seals), but a
    long-lived process that changes params or churns through segment
    layouts would otherwise accumulate one executable per *historical*
    shape forever.  Both ``ShardedHilbertIndex`` and
    ``ShardedMutableHilbertIndex`` share this bound: least-recently-used
    eviction at ``max_entries``, where a ``get`` hit refreshes recency.
    Eviction drops our reference to the closure; XLA frees the
    executable when the last reference dies.

    Thread-safe: the serving engine runs searches under a SHARED
    reader-writer lock, so concurrent readers hit this cache together.
    ``get``/``put`` are atomic under an internal mutex (a ``get`` hit
    mutates LRU recency — the one read-path mutation the facades keep,
    made safe here rather than pushed onto every caller).  Two racing
    misses may both compile; both closures are equivalent and the loser
    is simply dropped by ``put``'s overwrite.
    """

    def __init__(self, max_entries: int = 32):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, object]" = OrderedDict()

    def get(self, key):
        with self._lock:
            fn = self._entries.get(key)
            if fn is not None:
                self._entries.move_to_end(key)
            return fn

    def put(self, key, fn) -> None:
        with self._lock:
            while len(self._entries) >= self.max_entries:
                self._entries.popitem(last=False)
            self._entries[key] = fn

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def keys(self) -> tuple:
        """Key-set snapshot (purity tests fingerprint THIS, not recency
        order — LRU refresh on a hit is deliberate and benign)."""
        with self._lock:
            return tuple(self._entries.keys())

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries


def resolve_backend(backend: str) -> str:
    """Kernel-routing policy: 'auto' → Pallas on TPU, XLA elsewhere."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HilbertIndex:
    """Self-describing Hilbert-forest index (config travels with the arrays)."""

    config: IndexConfig
    forest: forest_lib.HilbertForest
    quant: quantize.Quantizer
    codes_master: jax.Array  # (n, ceil(d/8)) uint32, nibble-PACKED, master order
    sketches_master: jax.Array  # (n, Ws) uint32, master-order layout
    master_order: jax.Array  # (n,) int32: position -> point id
    master_rank: jax.Array  # (n,) int32: point id -> position
    points: Optional[jax.Array] = None  # (n, d) fp32 iff config.store_points

    # -- pytree protocol (config is static; arrays are children) ------------

    def tree_flatten(self):
        children = (
            self.forest,
            self.quant,
            self.codes_master,
            self.sketches_master,
            self.master_order,
            self.master_rank,
            self.points,
        )
        return children, self.config

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, *children)

    # -- introspection -------------------------------------------------------

    @property
    def n_points(self) -> int:
        return self.master_order.shape[0]

    @property
    def dim(self) -> int:
        # codes_master is packed, so its width is ceil(d/8); the quantizer
        # grid keeps the true dimensionality.
        return self.quant.boundaries.shape[0]

    def memory_report(self) -> Dict[str, int]:
        """Bytes by component: the paper's RAM-budget model plus actuals.

        The model fields (``quantized_bytes``/``combined_stage2_bytes``/…)
        come from :func:`repro.core.search.paper_memory_model` — the single
        shared accounting.  Since codes are RESIDENT nibble-packed, the
        model's ``quantized_bytes`` equals the actual ``codes_bytes``.
        ``codes_bytes``/``order_bytes``/``quant_bytes`` are the arrays
        actually resident, and ``resident_bytes``/``total_bytes`` sum every
        pytree leaf so segment lists and serving deployments can budget
        real RAM.
        """
        d = self.dim
        resident = sum(
            int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            for leaf in jax.tree_util.tree_leaves(self)
        )
        rep = search_lib.paper_memory_model(
            self.n_points,
            d,
            int(np.prod(self.sketches_master.shape)) * 4,
            self.forest.memory_bytes(),
        )
        rep.update(
            {
                "points_bytes": 0 if self.points is None else self.n_points * d * 4,
                "codes_bytes": int(np.prod(self.codes_master.shape)) * 4,  # u32
                "order_bytes": self.master_order.nbytes + self.master_rank.nbytes,
                "quant_bytes": self.quant.boundaries.nbytes
                + self.quant.centroids.nbytes,
                "resident_bytes": resident,
                "total_bytes": resident,
            }
        )
        return rep

    def __repr__(self) -> str:
        mb = self.memory_report()["resident_bytes"] / 1e6
        return (
            f"HilbertIndex(n_points={self.n_points}, dim={self.dim}, "
            f"n_trees={self.forest.n_trees}, "
            f"store_points={self.points is not None}, "
            f"backend={jax.default_backend()}, {mb:.2f} MB)"
        )

    # -- build ---------------------------------------------------------------

    @classmethod
    def build(cls, points: jax.Array, config: Optional[IndexConfig] = None
              ) -> "HilbertIndex":
        """Full Task-1 preprocessing: quantize, sketch, forest, master order.

        The paper's §3.1 pipeline behind one call: fit the 4-bit shared-MSB
        quantizer, derive binary sketches, build ``n_trees`` randomized
        Hilbert trees, and store codes/sketches rearranged into the
        un-permuted master Hilbert order (the layout Algorithm 1's stage-2
        window expansion reads contiguously).

        Args:
          points: (n, d) fp32 corpus to index.
          config: build configuration; ``None`` means ``IndexConfig()`` (a
            ``None`` sentinel, not a default-argument instance, so no
            config object is ever shared between calls).

        Returns:
          A self-describing index; its search never takes a config again.
        """
        index, _ = build_with_timings(points, config)
        return index

    # -- Task 1: Algorithm-1 search -----------------------------------------

    def search(
        self,
        queries: jax.Array,
        params: SearchParams = SearchParams(),
        *,
        backend: str = "auto",
        query_chunk: Optional[int] = None,
        fused: bool = True,
    ) -> Tuple[jax.Array, jax.Array]:
        """Batched search — the paper's Algorithm 1 (forest candidates →
        sketch Hamming filter → ±h master-order expansion → ADC → top-k).

        Args:
          queries: (Q, d) fp32 query batch.
          params: Algorithm-1 hyper-parameters (``k1``/``k2``/``h``/``k``,
            paper Table 1 names).
          backend: kernel routing, one of ``BACKENDS``.
          query_chunk: per-dispatch chunk cap (default
            ``config.query_chunk``).
          fused: take the single-dispatch fused path (default) or the
            bit-identical per-tree reference loop.

        Returns:
          ``(ids (Q, k) int32, sq_distances (Q, k) float32)``, distances
          ascending; with fewer than ``k`` points the tail is id ``-1`` /
          ``+inf``.

        No config argument: the forest/quantizer settings used at build time
        travel on ``self.config``.  ``backend`` routes the kernel stages
        (stage-1 Hamming filter + packed stage-2 ADC): ``"pallas"`` uses the
        Mosaic kernels (interpret-mode on CPU), ``"xla"`` the jnp oracles,
        ``"auto"`` picks Pallas only on TPU.

        ``query_chunk`` (default ``config.query_chunk``) caps the chunk
        size; every chunk is padded up to a power-of-two bucket (≤ the cap)
        and trimmed after, so a serving process sees at most
        ``log2(query_chunk)+1`` jit traces no matter how batch sizes vary —
        previously every distinct batch size below the chunk size triggered
        a fresh trace.

        ``fused=True`` (the hot path) runs one XLA dispatch per chunk via
        :func:`repro.core.search.fused_search_chunk`; ``fused=False`` keeps
        the per-tree dispatch loop + unpacked stage 2 as a bit-identical
        reference for parity tests and benchmarks.
        """
        use_kernels = resolve_backend(backend) == "pallas"
        if query_chunk is None:
            query_chunk = self.config.query_chunk
        qn = queries.shape[0]
        if qn == 0:  # idle decode step: no chunks, well-typed empty result
            return (
                jnp.zeros((0, params.k), jnp.int32),
                jnp.zeros((0, params.k), jnp.float32),
            )
        # Reference path: unpack the codes ONCE per search, not per chunk.
        codes_u8 = (
            None if fused
            else quantize.unpack_codes(self.codes_master, self.dim)
        )
        outs_i, outs_d = [], []
        for s in range(0, qn, query_chunk):
            q = queries[s : s + query_chunk]
            m = q.shape[0]
            bucket = _pow2_bucket(m, query_chunk)
            if bucket > m:
                q = jnp.pad(q, ((0, bucket - m), (0, 0)))
            with dispatch_scope("hilbert.search"):
                ids, dists = self._search_chunk(q, params, use_kernels,
                                                fused, codes_u8)
            if bucket > m:
                ids, dists = ids[:m], dists[:m]
            outs_i.append(ids)
            outs_d.append(dists)
        return jnp.concatenate(outs_i), jnp.concatenate(outs_d)

    def _search_chunk(self, queries, params: SearchParams, use_kernels: bool,
                      fused: bool = True, codes_u8=None):
        fcfg = self.config.forest
        f = self.forest
        if fused:
            return search_lib.fused_search_chunk(
                queries, f.orders, f.directories, f.lo, f.hi, f.perms, f.flips,
                self.master_rank, self.sketches_master, self.codes_master,
                self.master_order, self.quant,
                bits=fcfg.bits, key_bits=fcfg.key_bits,
                leaf_size=fcfg.leaf_size, k1=params.k1, k2=params.k2,
                h=params.h, k=params.k, use_kernels=use_kernels,
            )
        # Reference path: one dispatch per tree + stage 2 on codes unpacked
        # back to (n, d) uint8.  Bit-identical to the fused path on XLA;
        # kept for parity tests and the search_path benchmark baseline.
        qn = queries.shape[0]
        qsk = sketch.make_sketches(self.quant, queries)
        best_pos = jnp.full((qn, params.k2), -1, jnp.int32)
        best_dist = jnp.full((qn, params.k2), _INF, jnp.int32)
        for t in range(f.n_trees):
            best_pos, best_dist = search_lib.stage1_tree_merge(
                queries, qsk, best_pos, best_dist,
                f.orders[t], f.directories[t], f.lo, f.hi, f.perms[t], f.flips[t],
                self.master_rank, self.sketches_master,
                bits=fcfg.bits, key_bits=fcfg.key_bits,
                leaf_size=fcfg.leaf_size, k1=params.k1, k2=params.k2,
                use_kernels=use_kernels,
            )
        if codes_u8 is None:
            codes_u8 = quantize.unpack_codes(self.codes_master, self.dim)
        return search_lib.stage2_expand_rank(
            queries, best_pos, codes_u8, self.master_order, self.quant,
            h=params.h, k=params.k,
        )

    # -- Task 2: Algorithm-2 graph construction ------------------------------

    def knn_graph(
        self,
        params: GraphParams = GraphParams(),
        *,
        chunk: int = 1 << 16,
    ) -> Tuple[jax.Array, jax.Array]:
        """Approximate k-NN graph over the indexed points — the paper's
        Algorithm 2 (Task 2): repeated randomized Hilbert orders, ±k1
        neighbor windows, sketch-filtered running top-k2, exact re-rank.

        Args:
          params: Algorithm-2 hyper-parameters (``n_orders``/``k1``/
            ``k2``/``k``, paper Table 2 names).
          chunk: rows per jitted window pass (memory/speed knob only).

        Returns:
          ``(ids (n, k) int32, sq_distances (n, k) float32)`` — each
          indexed point's approximate k nearest neighbors, self excluded.

        Reuses the index's fitted quantizer → sketches and bounds instead of
        re-fitting from scratch (what the legacy ``build_knn_graph`` did).
        Requires ``config.store_points=True`` (default): the final exact
        re-ranking step needs the fp32 points.
        """
        if self.points is None:
            raise ValueError(
                "knn_graph() needs the raw points for exact re-ranking; this "
                "index was built with IndexConfig(store_points=False)"
            )
        # Sketches in point-id order, recovered from the master-order copy:
        # sketches_master[master_rank[i]] is point i's sketch.
        sketches_ids = self.sketches_master[self.master_rank]
        fcfg = self.config.forest
        return knn_graph_lib.knn_graph_from_sketches(
            self.points, sketches_ids, params,
            bits=fcfg.bits, key_bits=fcfg.key_bits,
            lo=self.forest.lo, hi=self.forest.hi, chunk=chunk,
        )

    # -- persistence ---------------------------------------------------------

    def _array_bundle(self) -> Dict[str, jax.Array]:
        d = {
            "forest.perms": self.forest.perms,
            "forest.flips": self.forest.flips,
            "forest.orders": self.forest.orders,
            "forest.directories": self.forest.directories,
            "forest.lo": self.forest.lo,
            "forest.hi": self.forest.hi,
            "quant.boundaries": self.quant.boundaries,
            "quant.centroids": self.quant.centroids,
            "codes_master": self.codes_master,
            "sketches_master": self.sketches_master,
            "master_order": self.master_order,
            "master_rank": self.master_rank,
        }
        if self.points is not None:
            d["points"] = self.points
        return d

    def save(self, path: str) -> str:
        """Atomically persist index arrays + config under ``path``.

        Uses the ``repro.checkpoint`` machinery (tmp-dir + fsync + rename),
        so a crash mid-save can never corrupt a previously saved index and
        many serving workers can load concurrently.  Returns the final
        checkpoint directory.
        """
        return save_index_bundle(self, path)

    @classmethod
    def load(cls, path: str) -> "HilbertIndex":
        """Load an index saved with :meth:`save`; fully self-describing."""
        index, _, _ = load_index_bundle(path)
        return index


def save_index_bundle(
    index: HilbertIndex,
    path: str,
    *,
    kind: str = "hilbert_index",
    extra_arrays: Optional[Dict[str, jax.Array]] = None,
    extra_meta: Optional[Dict] = None,
) -> str:
    """Persist an index plus optional sidecar arrays as ONE atomic bundle.

    Wrappers that pair an index with companion data (e.g. the serving
    ``RetrievalStore``'s values array) use this so a crash or concurrent
    load can never observe the index and its sidecars out of sync.
    """
    bundle = dict(index._array_bundle())
    for k, v in (extra_arrays or {}).items():
        if k in _LEAF_DTYPES:
            raise ValueError(f"extra array name {k!r} collides with an index leaf")
        bundle[k] = v
    extra = {
        "kind": kind,
        "format_version": _FORMAT_VERSION,
        "config": index.config.to_dict(),
        "has_points": index.points is not None,
        "n_points": int(index.n_points),
        "dim": int(index.dim),
        "extra_arrays": sorted((extra_arrays or {}).keys()),
    }
    for k in extra_meta or {}:
        if k in extra:
            raise ValueError(f"extra_meta key {k!r} collides with a reserved key")
    extra.update(extra_meta or {})
    # Fresh step per save with one generation of grace: if the newest
    # bundle is later found rotted (digest mismatch) it is quarantined
    # and loads fall back to the previous, still-verifiable step.
    prev = checkpoint.latest_step(path)
    step = 0 if prev is None else prev + 1
    final = checkpoint.save(path, step=step, tree=bundle, extra=extra)
    checkpoint.prune_steps(path, {step, prev})
    return final


def load_index_bundle(
    path: str, *, kind: str = "hilbert_index"
) -> Tuple[HilbertIndex, Dict[str, jax.Array], Dict]:
    """Inverse of :func:`save_index_bundle`.

    Returns ``(index, extra_arrays, manifest_extra)``; sidecar array dtypes
    come from the manifest, index leaf dtypes from the static schema.

    Resolution is corruption-aware: if the newest step fails digest
    verification mid-restore it is quarantined (``*.quarantine/``) and
    the next-newest step is tried, so a bit-flipped bundle degrades to
    the previous verifiable save instead of a crash or — worse — a
    silently wrong index.
    """
    last_err: Optional[checkpoint.CorruptBundleError] = None
    while True:
        step = checkpoint.latest_step(path)
        if step is None:
            if last_err is not None:
                raise last_err
            raise FileNotFoundError(f"no HilbertIndex checkpoint under {path!r}")
        try:
            return _load_index_bundle_step(path, step, kind=kind)
        except checkpoint.CorruptBundleError as e:
            # restore() has quarantined the step; retry resolves older.
            last_err = e


def _load_index_bundle_step(
    path: str, step: int, *, kind: str
) -> Tuple[HilbertIndex, Dict[str, jax.Array], Dict]:
    try:
        with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
            manifest = json.load(f)
    except ValueError as e:
        quarantined = checkpoint.quarantine_step(path, step)
        raise checkpoint.CorruptBundleError(
            path, step, [f"manifest unparseable: {e}"], quarantined
        ) from e
    extra = manifest.get("extra", {})
    if extra.get("kind") != kind:
        raise ValueError(
            f"{path!r} is not a HilbertIndex checkpoint of kind {kind!r} "
            f"(kind={extra.get('kind')!r})"
        )
    config = IndexConfig.from_dict(extra["config"])
    fmt = int(extra.get("format_version", 1))
    names = list(_LEAF_DTYPES)
    if not extra.get("has_points", False):
        names.remove("points")
    abstract = {k: jax.ShapeDtypeStruct((0,), _LEAF_DTYPES[k]) for k in names}
    if fmt < 2:
        # v1 bundles stored codes unpacked (n, d) uint8; restore them in
        # that dtype and repack below (transparent layout upgrade).
        abstract["codes_master"] = jax.ShapeDtypeStruct((0,), jnp.uint8)
    extra_names = extra.get("extra_arrays", [])
    for k in extra_names:
        # manifest leaves are keyed by jax keystr: "['<name>']"
        _, dtype_str = manifest["leaves"][f"['{k}']"]
        abstract[k] = jax.ShapeDtypeStruct((0,), np.dtype(dtype_str))
    arrays, _ = checkpoint.restore(path, step, abstract)
    if fmt < 2:
        arrays["codes_master"] = quantize.pack_codes(arrays["codes_master"])
    index = HilbertIndex(
        config=config,
        forest=forest_lib.HilbertForest(
            perms=arrays["forest.perms"],
            flips=arrays["forest.flips"],
            orders=arrays["forest.orders"],
            directories=arrays["forest.directories"],
            lo=arrays["forest.lo"],
            hi=arrays["forest.hi"],
        ),
        quant=quantize.Quantizer(
            boundaries=arrays["quant.boundaries"],
            centroids=arrays["quant.centroids"],
        ),
        codes_master=arrays["codes_master"],
        sketches_master=arrays["sketches_master"],
        master_order=arrays["master_order"],
        master_rank=arrays["master_rank"],
        points=arrays.get("points"),
    )
    return index, {k: arrays[k] for k in extra_names}, extra


def build_with_timings(
    points: jax.Array, config: Optional[IndexConfig] = None,
    *, quant: Optional[quantize.Quantizer] = None,
) -> Tuple[HilbertIndex, Dict[str, float]]:
    """Build an index and return per-phase wall times (paper §3.2 split).

    Phases: ``quantization`` (fit+encode), ``sketches``, ``forest`` (the
    dominant cost — n_trees Hilbert sorts), ``master_sort``.

    ``quant`` may supply a pre-fit quantizer instead of fitting one from
    ``points``.  The sharded facade builds every shard with ONE globally
    fit quantizer this way: per-shard ADC distances then dequantize against
    the same centroids, so distances merged across shards are mutually
    comparable and equal to what a single-device index over the union
    would compute.
    """
    if config is None:
        config = IndexConfig()
    n, _ = points.shape
    qcfg, fcfg = config.quantizer, config.forest
    timings: Dict[str, float] = {}

    t0 = time.time()
    with span("build.quantization", rows=int(n)), dispatch_scope(
        "build.quantization"
    ):
        if quant is None:
            quant = quantize.fit(
                points, bits=qcfg.bits, sample_limit=qcfg.sample_limit
            )
        codes = quantize.encode(quant, points)
        jax.block_until_ready(codes)
    timings["quantization"] = time.time() - t0

    t0 = time.time()
    with span("build.sketches"), dispatch_scope("build.sketches"):
        sketches = sketch.sketches_from_codes(codes, bits=qcfg.bits)
        jax.block_until_ready(sketches)
    timings["sketches"] = time.time() - t0

    t0 = time.time()
    with span("build.forest", n_trees=fcfg.n_trees), dispatch_scope(
        "build.forest"
    ):
        f = forest_lib.build_forest(points, fcfg)
        jax.block_until_ready(f.orders)
    timings["forest"] = time.time() - t0

    # Master order: an un-permuted Hilbert sort; vectors/sketches rearranged.
    t0 = time.time()
    with span("build.master_sort"), dispatch_scope("build.master_sort"):
        master_order, _ = search_lib.hilbert_master_sort(
            points, fcfg, f.lo, f.hi
        )
        master_rank = jnp.zeros((n,), jnp.int32).at[master_order].set(
            jnp.arange(n, dtype=jnp.int32)
        )
        jax.block_until_ready(master_order)
    timings["master_sort"] = time.time() - t0

    index = HilbertIndex(
        config=config,
        forest=f,
        quant=quant,
        # Resident layout is nibble-packed (paper: 0.5 B/dim); pack AFTER
        # the master reorder so window reads stay contiguous.
        codes_master=quantize.pack_codes(codes[master_order]),
        sketches_master=sketches[master_order],
        master_order=master_order,
        master_rank=master_rank,
        points=points if config.store_points else None,
    )
    return index, timings
