"""IndexConfig: the single build-time configuration for :class:`HilbertIndex`.

Composes the core ``ForestConfig`` / ``QuantizerConfig`` dataclasses into one
frozen (hashable — usable as jit static aux data) object that the index
carries for its whole life, including across ``save()``/``load()``.  The
dict round-trip below is what lands in the checkpoint manifest, so a loaded
index is self-describing: no caller ever re-supplies the build config.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.types import ForestConfig, QuantizerConfig

__all__ = ["IndexConfig"]


def _filter_fields(cls, d: Dict[str, Any]) -> Dict[str, Any]:
    """Keep only known dataclass fields (forward-compatible manifests)."""
    names = {f.name for f in dataclasses.fields(cls)}
    return {k: v for k, v in d.items() if k in names}


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Everything needed to (re)build or interpret a :class:`HilbertIndex`.

    Attributes:
      forest: Hilbert-forest shape (trees, curve bits, key width, leaf size).
      quantizer: 4-bit shared-MSB quantizer settings.
      store_points: keep the raw fp32 points on the index.  Required for
        ``knn_graph()`` (Task-2 exact re-ranking); turn off for serving
        deployments where only Algorithm-1 search runs and RAM matters.
      query_chunk: default search chunk cap.  Chunks are padded to
        power-of-two buckets up to this cap, so a serving process compiles
        at most ``log2(query_chunk)+1`` traces across all batch sizes.
        Travels with the index so every serving worker shares the same
        trace-bucket policy; overridable per call via
        ``search(query_chunk=...)``.
      shards: row-partition count for the sharded facade.  ``None`` (the
        default) means "auto": :func:`repro.index.build_auto` picks one
        shard per device on the mesh's ``data`` axis when more than one
        device is visible, else a plain single-device index.  ``1`` forces
        single-device even on a multi-device host.
      mutable: ask :func:`repro.index.build_auto` for the streaming (LSM)
        facade instead of the immutable one — a
        :class:`repro.index.MutableHilbertIndex` on one shard, a
        :class:`repro.index.ShardedMutableHilbertIndex` on several — so one
        config describes a deployment that must absorb inserts/deletes
        while serving.  Build-time only: it changes which facade wraps the
        arrays, never the arrays themselves.
      seal_pow2: pad LSM *seal* builds (flushes and tier merges, never
        ``compact()`` or bulk loads) up to power-of-two row counts by
        cyclically repeating real rows.  Steady-state churn then recycles
        a handful of segment shapes instead of minting a new one per
        seal, so the jitted search stops recompiling once warm — the
        recompile gauge assert in ``benchmarks/churn.py``.  Costs a
        bounded amount of redundant rows (< 2x) and a matching top-k
        inflation; results stay exact w.r.t. the live rows.
      merge: cross-shard top-k merge strategy for the sharded facades.
        ``"gather"`` is the flat reference path (one ``all_gather`` of
        every shard's inflated candidate pool, one ``merge_topk``);
        ``"tree"`` is the butterfly reduction (log2(S) ``ppermute`` hops
        exchanging exactly k rows per query per hop — see
        :func:`repro.core.distributed.cross_shard_merge_topk`), which
        requires a power-of-two shard count; ``"auto"`` (the default)
        picks ``"tree"`` when the shard count is a power of two and
        falls back to ``"gather"`` otherwise.  The two paths return the
        same results (sorted distances bit-equal; ids equal up to
        distance ties).  Overridable per call via ``search(merge=...)``.
      merge_prune: with the tree merge, additionally exchange each
        shard's local kth-best distance (one ``pmin``) before the first
        hop and mask candidates that provably cannot enter the global
        top-k.  Exact — pruned entries are strictly worse than the
        global kth-best, so even tie order is unchanged — but one more
        collective; off by default.  Overridable via
        ``search(prune=...)``.
    """

    forest: ForestConfig = ForestConfig()
    quantizer: QuantizerConfig = QuantizerConfig()
    store_points: bool = True
    query_chunk: int = 2048
    shards: Optional[int] = None
    mutable: bool = False
    seal_pow2: bool = False
    merge: str = "auto"
    merge_prune: bool = False

    def to_dict(self) -> Dict[str, Any]:
        """Manifest form of the config (the checkpoint round-trip).

        Returns:
          A plain-JSON dict with one key per field; nested configs become
          nested dicts.  ``from_dict(to_dict(cfg)) == cfg`` exactly.
        """
        return {
            "forest": dataclasses.asdict(self.forest),
            "quantizer": dataclasses.asdict(self.quantizer),
            "store_points": self.store_points,
            "query_chunk": self.query_chunk,
            "shards": self.shards,
            "mutable": self.mutable,
            "seal_pow2": self.seal_pow2,
            "merge": self.merge,
            "merge_prune": self.merge_prune,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "IndexConfig":
        """Inverse of :meth:`to_dict`; tolerant of older/newer manifests.

        Unknown keys are dropped and missing keys take the field defaults,
        so manifests written by earlier format versions (which e.g. lack
        ``mutable``) and later ones (which may add fields) both load.
        """
        shards = d.get("shards")
        return cls(
            forest=ForestConfig(**_filter_fields(ForestConfig, d.get("forest", {}))),
            quantizer=QuantizerConfig(
                **_filter_fields(QuantizerConfig, d.get("quantizer", {}))
            ),
            store_points=bool(d.get("store_points", True)),
            query_chunk=int(d.get("query_chunk", 2048)),
            shards=None if shards is None else int(shards),
            mutable=bool(d.get("mutable", False)),
            seal_pow2=bool(d.get("seal_pow2", False)),
            merge=str(d.get("merge", "auto")),
            merge_prune=bool(d.get("merge_prune", False)),
        )
