"""ShardedHilbertIndex: the row-partitioned Hilbert forest, end to end.

One host's RAM stops being the index capacity ceiling here: the corpus is
row-partitioned across the mesh's ``data`` axis, each device holds ONE
shard's complete index state (forest arrays, sketches, nibble-packed
codes — a full per-shard :class:`HilbertIndex` worth of arrays), and
search / checkpointing / serving all understand the partitioned layout.

Layout
  The partition is **contiguous runs of the master Hilbert order**
  (:func:`repro.core.distributed.hilbert_partition`, the sample sort at
  multi-device scale): shard ``s`` owns the ``s``-th stretch of the global
  curve walk, so its rows are a locality-tight curve segment — the
  hyperorthogonal well-folded curve argument for why a per-shard top-k
  merge loses little recall.  Every shard is padded to equal length with
  cyclic copies of its own rows (fully-empty shards with copies of global
  row 0); padding rows keep their REAL global ids, so they surface as
  duplicate ids and the cross-shard merge's dedup collapses them — no
  special sentinel rows exist anywhere in the hot path.

Search
  ONE jitted dispatch per query chunk: inside ``shard_map`` (queries
  replicated, rows sharded) each device runs PR 3's
  :func:`repro.core.search.fused_search_chunk` over its shard, maps local
  hits to global ids, **deflates** its inflated candidate pool to a true
  local top-k, and the shards reduce via
  :func:`repro.core.distributed.cross_shard_merge_topk`: by default a
  butterfly tree reduction of the associative
  :func:`repro.core.search.merge_topk` — log2(S) ``ppermute`` hops, each
  exchanging exactly k rows per query (``merge="tree"``, auto-selected on
  power-of-two shard counts), optionally preceded by a ``pmin``
  distance-bound prune (``merge_prune``).  The flat
  ``all_gather``-everything + one ``merge_topk`` path survives bit-exact
  as ``merge="gather"`` — the parity reference and the non-pow2
  fallback.  Every shard is searched for ``k + pad_max`` results
  (``pad_max`` = the largest padding count among non-empty shards, a
  static build-time int) so duplicate padding rows can never crowd a
  distinct neighbor out of the merge.

  All shards share ONE globally fit quantizer, so per-shard ADC distances
  dequantize against the same centroids: distances merged across shards
  are mutually comparable and equal to the single-device values for the
  same (query, point) pairs.  A 1-shard index skips the shard_map
  entirely and delegates to ``HilbertIndex.search(fused=True)`` —
  bit-identical to the single-device fused path by construction.

Checkpoints (format_version 3)
  ``save()`` writes one atomic per-shard bundle (an ordinary
  :func:`repro.index.facade.save_index_bundle`, so each shard is a valid
  v2 index checkpoint on its own) plus a top-level JSON manifest renamed
  into place last.  ``load()`` re-assembles the stacks when the target
  mesh matches the on-disk shard count, **reshards** (gathers points +
  ids, rebuilds at the new count with the SAME quantizer) when it does
  not, and adopts plain v2 single-index bundles the same way — changing
  the device count never invalidates a checkpoint.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import checkpoint
from repro.core import distributed as distributed_lib
from repro.core import forest as forest_lib
from repro.core import quantize
from repro.core import search as search_lib
from repro.core.types import SearchParams
from repro.index.config import IndexConfig
from repro.obs.dispatch import dispatch_scope
from repro.index.facade import (
    BoundedJitCache,
    HilbertIndex,
    _pow2_bucket,
    build_with_timings,
    load_index_bundle,
    resolve_backend,
    save_index_bundle,
)

__all__ = [
    "ShardedHilbertIndex",
    "ShardStack",
    "build_auto",
    "shard_index_from_stack",
    "stack_shard_indexes",
]

_SHARDED_MANIFEST = "sharded_manifest.json"
_SHARD_KIND = "sharded_index_shard"
_DEFAULT_KIND = "sharded_hilbert_index"
_FORMAT_VERSION = 3


def _data_mesh(n: Optional[int] = None) -> Mesh:
    from repro.launch.mesh import data_mesh

    return data_mesh(n)


class ShardStack(NamedTuple):
    """Per-shard index arrays stacked on a leading shard axis.

    Every leaf is ``(S, ...)`` and device_put with ``P('data')``, so device
    ``s`` physically holds only shard ``s``'s row — the per-device resident
    bytes of the big leaves are ``nbytes / S`` (verified by
    ``memory_report()``).  ``perms``/``flips`` are shared by all shards
    (same forest seed) and the quantizer is global, so those stay
    replicated outside the stack.
    """

    orders: jax.Array        # (S, T, n_pad) int32, per-tree Hilbert orders
    directories: jax.Array   # (S, T, n_dir, W) uint32 rank directories
    lo: jax.Array            # (S, d) float32 per-shard curve bounds
    hi: jax.Array            # (S, d) float32
    sketches: jax.Array      # (S, n_pad, Ws) uint32, master-order layout
    codes: jax.Array         # (S, n_pad, Wc) uint32, nibble-packed, master
    master_order: jax.Array  # (S, n_pad) int32: position -> local row
    master_rank: jax.Array   # (S, n_pad) int32: local row -> position
    id_map: jax.Array        # (S, n_pad) int32: local row -> GLOBAL id


def stack_shard_indexes(
    mesh: Mesh,
    shard_indexes: List[HilbertIndex],
    id_maps: np.ndarray,           # (S, n_pad) int32 local row -> id
    *,
    store_points: bool,
) -> Tuple[ShardStack, Optional[jax.Array]]:
    """Stack per-shard :class:`HilbertIndex` leaves over the mesh.

    Returns ``(stack, points)`` with every leaf ``(S, ...)`` and laid out
    ``P('data')``.  ``id_maps`` may carry either global row ids (the static
    :class:`ShardedHilbertIndex`) or stable external ids (the sharded
    mutable facade's sealed generations) — the stack is agnostic; its
    ``id_map`` is simply what local search hits are gathered through.
    """
    data_sh = NamedSharding(mesh, P("data"))

    def stack_leaf(get):
        return jax.device_put(
            jnp.stack([get(ix) for ix in shard_indexes]), data_sh
        )

    stack = ShardStack(
        orders=stack_leaf(lambda ix: ix.forest.orders),
        directories=stack_leaf(lambda ix: ix.forest.directories),
        lo=stack_leaf(lambda ix: ix.forest.lo),
        hi=stack_leaf(lambda ix: ix.forest.hi),
        sketches=stack_leaf(lambda ix: ix.sketches_master),
        codes=stack_leaf(lambda ix: ix.codes_master),
        master_order=stack_leaf(lambda ix: ix.master_order),
        master_rank=stack_leaf(lambda ix: ix.master_rank),
        id_map=jax.device_put(jnp.asarray(id_maps, jnp.int32), data_sh),
    )
    points = stack_leaf(lambda ix: ix.points) if store_points else None
    return stack, points


def shard_index_from_stack(
    config: IndexConfig,
    stack: ShardStack,
    points: Optional[jax.Array],
    quant: quantize.Quantizer,
    perms: jax.Array,
    flips: jax.Array,
    s: int,
) -> HilbertIndex:
    """Shard ``s``'s slice of a stack as a self-contained v2 HilbertIndex.

    The inverse of :func:`stack_shard_indexes` for one shard — used by both
    sharded checkpoint writers (static v3, mutable v4) so every per-shard
    bundle on disk is an ordinary loadable index checkpoint.
    """
    return HilbertIndex(
        config=dataclasses.replace(config, shards=None),
        forest=forest_lib.HilbertForest(
            perms=perms, flips=flips,
            orders=jnp.asarray(np.asarray(stack.orders[s])),
            directories=jnp.asarray(np.asarray(stack.directories[s])),
            lo=jnp.asarray(np.asarray(stack.lo[s])),
            hi=jnp.asarray(np.asarray(stack.hi[s])),
        ),
        quant=quant,
        codes_master=jnp.asarray(np.asarray(stack.codes[s])),
        sketches_master=jnp.asarray(np.asarray(stack.sketches[s])),
        master_order=jnp.asarray(np.asarray(stack.master_order[s])),
        master_rank=jnp.asarray(np.asarray(stack.master_rank[s])),
        points=(
            None if points is None else jnp.asarray(np.asarray(points[s]))
        ),
    )


@dataclasses.dataclass
class ShardedHilbertIndex:
    """Row-partitioned Hilbert forest over the mesh's ``data`` axis."""

    config: IndexConfig
    mesh: Mesh
    quant: quantize.Quantizer          # global (replicated)
    perms: jax.Array                   # (T, d) shared forest randomization
    flips: jax.Array                   # (T, d)
    stack: Optional[ShardStack]        # None iff n_shards == 1
    points: Optional[jax.Array]        # (S, n_pad, d) iff store_points
    single: Optional[HilbertIndex]     # the 1-shard fast path
    n_points: int
    n_valid: np.ndarray                # (S,) rows actually owned per shard
    pad_max: int                       # largest pad count among non-empty shards

    def __post_init__(self):
        self._chunk_fns = BoundedJitCache()
        self.last_dispatch_count = 0

    # -- introspection -------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape["data"]) if self.single is None else 1

    @property
    def n_pad(self) -> int:
        return (
            self.single.n_points if self.single is not None
            else int(self.stack.id_map.shape[1])
        )

    @property
    def dim(self) -> int:
        return self.quant.boundaries.shape[0]

    def memory_report(self) -> Dict[str, object]:
        """The paper's RAM model plus the partitioned-layout actuals.

        ``per_device_bytes`` is what one device/host must actually hold:
        its slice of every sharded leaf plus a copy of every replicated
        leaf — for the big leaves that is ``total / n_shards``, which is
        the whole point of the partition (the paper's 16 GB single-box
        accounting divided across the mesh, plus the small replicated
        quantizer/randomization overhead).
        """
        if self.single is not None:
            rep = dict(self.single.memory_report())
            rep.update(
                n_shards=1,
                sharded_bytes=0,
                replicated_bytes=rep["resident_bytes"],
                per_device_bytes=[rep["resident_bytes"]],
            )
            return rep
        s = self.n_shards
        sharded_leaves = list(self.stack) + (
            [self.points] if self.points is not None else []
        )
        sharded = sum(int(leaf.nbytes) for leaf in sharded_leaves)
        replicated = sum(
            int(leaf.nbytes)
            for leaf in (self.quant.boundaries, self.quant.centroids,
                         self.perms, self.flips)
        )
        rep = search_lib.paper_memory_model(
            self.n_points,
            self.dim,
            int(self.stack.sketches.nbytes),
            int(self.stack.orders.nbytes + self.stack.directories.nbytes
                + self.perms.nbytes + self.flips.nbytes),
        )
        rep.update(
            n_shards=s,
            points_bytes=0 if self.points is None else int(self.points.nbytes),
            codes_bytes=int(self.stack.codes.nbytes),
            sharded_bytes=sharded,
            replicated_bytes=replicated,
            resident_bytes=sharded + replicated,
            total_bytes=sharded + replicated,
            per_device_bytes=[sharded // s + replicated] * s,
        )
        return rep

    def __repr__(self) -> str:
        rep = self.memory_report()
        return (
            f"ShardedHilbertIndex(n_points={self.n_points}, dim={self.dim}, "
            f"n_shards={self.n_shards}, n_pad={self.n_pad}, "
            f"per_device={rep['per_device_bytes'][0] / 1e6:.2f} MB, "
            f"total={rep['resident_bytes'] / 1e6:.2f} MB)"
        )

    # -- build ---------------------------------------------------------------

    @classmethod
    def build(
        cls,
        points: jax.Array,
        config: Optional[IndexConfig] = None,
        *,
        mesh: Optional[Mesh] = None,
    ) -> "ShardedHilbertIndex":
        """Partition rows over the mesh's ``data`` axis and build every shard.

        Args:
          points: (n, d) fp32 corpus; global row ids are ``0..n-1``.
          config: build configuration (``None`` = ``IndexConfig()``).
          mesh: explicit ``('data',)`` mesh; default derives one from
            ``config.shards`` (else every local device).

        Returns:
          The partitioned index; per-shard Algorithm-1 preprocessing runs
          once per shard over its contiguous master-curve run.

        The shard count is ``config.shards`` if set, else the mesh's
        ``data`` axis size (default mesh: every local device).  The
        quantizer is fit ONCE on the full corpus and shared by all shards.
        """
        if config is None:
            config = IndexConfig()
        pts = np.asarray(jax.device_get(points), np.float32)
        n = pts.shape[0]
        if n == 0:
            raise ValueError("cannot build a sharded index over 0 points")
        if mesh is None:
            mesh = _data_mesh(config.shards)
        n_shards = int(mesh.shape["data"])
        if config.shards is not None and config.shards != n_shards:
            raise ValueError(
                f"config.shards={config.shards} != mesh 'data' axis size "
                f"{n_shards}; pass a matching mesh (launch.mesh.data_mesh)"
            )
        quant = quantize.fit(
            jnp.asarray(pts), bits=config.quantizer.bits,
            sample_limit=config.quantizer.sample_limit,
        )
        return cls._build_impl(pts, config, mesh, quant)

    @classmethod
    def _build_impl(
        cls,
        pts: np.ndarray,
        config: IndexConfig,
        mesh: Mesh,
        quant: quantize.Quantizer,
    ) -> "ShardedHilbertIndex":
        n = pts.shape[0]
        n_shards = int(mesh.shape["data"])
        if n_shards == 1:
            single, _ = build_with_timings(
                jnp.asarray(pts), config, quant=quant
            )
            return cls(
                config=config, mesh=mesh, quant=quant,
                perms=single.forest.perms, flips=single.forest.flips,
                stack=None, points=None, single=single,
                n_points=n, n_valid=np.asarray([n], np.int64), pad_max=0,
            )

        gid_slices = distributed_lib.hilbert_partition(
            jnp.asarray(pts), config.forest, mesh=mesh, n_shards=n_shards
        )
        n_pad = -(-n // n_shards)
        n_valid = np.asarray([len(g) for g in gid_slices], np.int64)
        # pad_max counts only shards that own rows: a fully-empty shard's
        # padding duplicates global row 0 (owned — and merged away — by
        # shard 0), so it can never crowd out a distinct neighbor.
        pad_max = int(max(
            (n_pad - v for v in n_valid if v > 0), default=0
        ))
        shard_indexes: List[HilbertIndex] = []
        id_maps = np.zeros((n_shards, n_pad), np.int32)
        for s, gids in enumerate(gid_slices):
            if len(gids) == 0:
                gids_pad = np.zeros((n_pad,), np.int32)
            else:
                reps = -(-n_pad // len(gids))
                gids_pad = np.tile(np.asarray(gids, np.int32), reps)[:n_pad]
            id_maps[s] = gids_pad
            idx, _ = build_with_timings(
                jnp.asarray(pts[gids_pad]), config, quant=quant
            )
            shard_indexes.append(idx)
        return cls._assemble(
            config, mesh, quant, shard_indexes, id_maps, n, n_valid, pad_max
        )

    @classmethod
    def _assemble(
        cls, config, mesh, quant, shard_indexes, id_maps, n, n_valid, pad_max
    ) -> "ShardedHilbertIndex":
        """Stack per-shard index leaves and lay them out over the mesh."""
        repl = NamedSharding(mesh, P())
        stack, points = stack_shard_indexes(
            mesh, shard_indexes, id_maps, store_points=config.store_points
        )
        return cls(
            config=config, mesh=mesh,
            quant=jax.device_put(quant, repl),
            perms=jax.device_put(shard_indexes[0].forest.perms, repl),
            flips=jax.device_put(shard_indexes[0].forest.flips, repl),
            stack=stack, points=points, single=None,
            n_points=n, n_valid=np.asarray(n_valid, np.int64),
            pad_max=pad_max,
        )

    # -- search --------------------------------------------------------------

    def _resolve_merge(
        self, merge: Optional[str], prune: Optional[bool]
    ) -> Tuple[str, bool]:
        """Per-call knobs default to the config; "auto" resolves by S."""
        merge = distributed_lib.resolve_merge(
            merge if merge is not None else self.config.merge, self.n_shards
        )
        if prune is None:
            prune = self.config.merge_prune
        return merge, bool(prune)

    def search(
        self,
        queries: jax.Array,
        params: SearchParams = SearchParams(),
        *,
        backend: str = "auto",
        query_chunk: Optional[int] = None,
        merge: Optional[str] = None,
        prune: Optional[bool] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Mesh-wide Algorithm-1 search.

        Args:
          queries: (Q, d) fp32 batch, replicated across the mesh.
          params: Algorithm-1 hyper-parameters (paper Table 1 names);
            each shard searches for ``k + pad_max`` candidates.
          backend: kernel routing for the per-shard fused pipeline.
          query_chunk: per-dispatch chunk cap (default
            ``config.query_chunk``).
          merge: cross-shard merge strategy, ``"auto"|"gather"|"tree"``
            (default ``config.merge``); see :class:`IndexConfig`.
          prune: distance-bound early pruning on the tree path (default
            ``config.merge_prune``).

        Returns:
          ``(ids (Q, k) int32, sq_distances (Q, k) float32)`` with GLOBAL
          row ids, distances ascending; shortfalls pad id -1 / +inf.

        One jitted dispatch per query chunk (``last_dispatch_count`` records
        the count for the most recent call): the whole shard_map — per-shard
        fused pipeline, gid mapping, shard-local deflation, cross-shard
        reduction — is one XLA computation.  Chunks are padded to
        power-of-two buckets exactly like ``HilbertIndex.search``.
        """
        merge, prune = self._resolve_merge(merge, prune)
        if self.single is not None:
            chunk = query_chunk or self.config.query_chunk
            self.last_dispatch_count = -(-queries.shape[0] // chunk)
            return self.single.search(
                queries, params, backend=backend, query_chunk=query_chunk,
                fused=True,
            )
        use_kernels = resolve_backend(backend) == "pallas"
        if query_chunk is None:
            query_chunk = self.config.query_chunk
        qn = queries.shape[0]
        self.last_dispatch_count = 0
        if qn == 0:
            return (
                jnp.zeros((0, params.k), jnp.int32),
                jnp.zeros((0, params.k), jnp.float32),
            )
        k_local = self._k_local(params)
        fn = self._chunk_fn(params, k_local, use_kernels, merge, prune)
        outs_i, outs_d = [], []
        for s in range(0, qn, query_chunk):
            q = queries[s : s + query_chunk]
            m = q.shape[0]
            bucket = _pow2_bucket(m, query_chunk)
            if bucket > m:
                q = jnp.pad(q, ((0, bucket - m), (0, 0)))
            with dispatch_scope("sharded.search"):
                ids, dists = fn(
                    q, self.stack, self.perms, self.flips, self.quant
                )
            self.last_dispatch_count += 1
            if bucket > m:
                ids, dists = ids[:m], dists[:m]
            outs_i.append(ids)
            outs_d.append(dists)
        return jnp.concatenate(outs_i), jnp.concatenate(outs_d)

    def search_local(
        self,
        queries: jax.Array,
        params: SearchParams = SearchParams(),
        *,
        backend: str = "auto",
        query_chunk: Optional[int] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Per-shard search WITHOUT the cross-shard reduction.

        Runs the identical shard_map core as :meth:`search` — fused
        per-shard pipeline, gid mapping, shard-local k deflation — but
        stops before any collective and returns the still-sharded
        ``(ids (S, Q, k), sq_distances (S, Q, k))`` stacks.  This is the
        in-situ "shard core" stage of the sharded path: what the
        benchmark's merge-tax guard compares the merged latency against,
        so the reduction cost is measured on the same dispatch shape
        rather than against a standalone single-shard run.
        """
        if self.single is not None:
            ids, d2 = self.single.search(
                queries, params, backend=backend, query_chunk=query_chunk,
                fused=True,
            )
            return ids[None], d2[None]
        use_kernels = resolve_backend(backend) == "pallas"
        if query_chunk is None:
            query_chunk = self.config.query_chunk
        qn = queries.shape[0]
        if qn == 0:
            z = jnp.zeros((self.n_shards, 0, params.k))
            return z.astype(jnp.int32), z.astype(jnp.float32)
        k_local = self._k_local(params)
        fn = self._chunk_fn(params, k_local, use_kernels, "local", False)
        outs_i, outs_d = [], []
        for s in range(0, qn, query_chunk):
            q = queries[s : s + query_chunk]
            m = q.shape[0]
            bucket = _pow2_bucket(m, query_chunk)
            if bucket > m:
                q = jnp.pad(q, ((0, bucket - m), (0, 0)))
            with dispatch_scope("sharded.search_local"):
                ids, dists = fn(
                    q, self.stack, self.perms, self.flips, self.quant
                )
            if bucket > m:
                ids, dists = ids[:, :m], dists[:, :m]
            outs_i.append(ids)
            outs_d.append(dists)
        return jnp.concatenate(outs_i, axis=1), jnp.concatenate(outs_d, axis=1)

    def _k_local(self, params: SearchParams) -> int:
        window = min(2 * params.h + 1, self.n_pad)
        return max(1, min(params.k + self.pad_max, params.k2 * window))

    def _chunk_fn(self, params: SearchParams, k_local: int, use_kernels: bool,
                  merge: str, prune: bool):
        key = (params.k1, params.k2, params.h, params.k, k_local, use_kernels,
               merge, prune)
        fn = self._chunk_fns.get(key)
        if fn is not None:
            return fn
        mesh = self.mesh
        fcfg = self.config.forest
        k1, k2, h, k = params.k1, params.k2, params.h, params.k
        n_shards = self.n_shards

        def shard_fn(q, st, perms, flips, quant):
            # shard_map keeps the sharded leading axis at local size 1.
            ids_l, d2 = search_lib.fused_search_chunk(
                q, st.orders[0], st.directories[0], st.lo[0], st.hi[0],
                perms, flips, st.master_rank[0], st.sketches[0], st.codes[0],
                st.master_order[0], quant,
                bits=fcfg.bits, key_bits=fcfg.key_bits,
                leaf_size=fcfg.leaf_size, k1=k1, k2=k2, h=h, k=k_local,
                use_kernels=use_kernels,
            )
            gids = jnp.where(
                ids_l >= 0, st.id_map[0][jnp.maximum(ids_l, 0)], -1
            )
            d2 = jnp.where(gids >= 0, d2, jnp.inf)
            if merge == "local":
                # search_local: deflate and stop pre-collective, sharded out.
                ids_k, d_k = search_lib.merge_topk(gids, d2, k=k)
                return ids_k[None], d_k[None]
            return distributed_lib.cross_shard_merge_topk(
                gids, d2, k=k, axis="data", axis_size=n_shards,
                merge=merge, prune=prune,
            )
        out_specs = (
            (P("data"), P("data")) if merge == "local"
            else (P(None, None), P(None, None))
        )
        fn = jax.jit(
            shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(P(None, None), P("data"), P(), P(), P()),
                out_specs=out_specs,
                check_rep=False,
            )
        )
        self._chunk_fns.put(key, fn)
        return fn

    # -- persistence ---------------------------------------------------------

    def _shard_index(self, s: int) -> Tuple[HilbertIndex, np.ndarray]:
        """Shard ``s`` as a self-contained v2 HilbertIndex (+ its gid map)."""
        if self.single is not None:
            return self.single, np.arange(self.n_points, dtype=np.int32)
        index = shard_index_from_stack(
            self.config, self.stack, self.points, self.quant,
            self.perms, self.flips, s,
        )
        return index, np.asarray(self.stack.id_map[s], np.int32)

    def save(self, path: str, *, kind: str = _DEFAULT_KIND,
             extra_meta: Optional[Dict] = None) -> str:
        """Persist as per-shard bundles under ONE atomically-renamed manifest.

        Each shard bundle is an ordinary atomic index checkpoint
        (`save_index_bundle`), written BEFORE the top-level manifest
        commits — a crash mid-save leaves any previous manifest (and the
        bundles it references) fully intact, and a concurrent loader never
        observes a half-written shard set.
        """
        os.makedirs(path, exist_ok=True)
        names = []
        for s in range(self.n_shards):
            index, gids = self._shard_index(s)
            name = f"shard_{s:05d}"
            save_index_bundle(
                index,
                os.path.join(path, "shards", name),
                kind=_SHARD_KIND,
                extra_arrays={"shard_gids": jnp.asarray(gids)},
                extra_meta={
                    "shard": s,
                    "n_shards": self.n_shards,
                    "n_valid": int(self.n_valid[s]),
                },
            )
            names.append(name)
        manifest = {
            "kind": kind,
            "format_version": _FORMAT_VERSION,
            "config": self.config.to_dict(),
            "n_shards": self.n_shards,
            "n_points": int(self.n_points),
            "dim": int(self.dim),
            "pad_max": int(self.pad_max),
            "shards": names,
            "extra_meta": extra_meta or {},
        }
        checkpoint.atomic_write_json(
            os.path.join(path, _SHARDED_MANIFEST), manifest
        )
        return path

    @classmethod
    def load(
        cls,
        path: str,
        *,
        mesh: Optional[Mesh] = None,
        kind: str = _DEFAULT_KIND,
    ) -> "ShardedHilbertIndex":
        """Load a v3 sharded checkpoint — or adopt/reshard a v2 single bundle.

        The target shard count is the mesh's ``data`` axis size (default
        mesh: every local device).  When it differs from the checkpoint's
        shard count, the index is RESHARDED on load: points + global ids
        are gathered from the stored shards and the partition is rebuilt at
        the new count with the checkpoint's own quantizer, so distances are
        unchanged.  Resharding needs stored points
        (``IndexConfig(store_points=True)``, the default).
        """
        if mesh is None:
            mesh = _data_mesh()
        target = int(mesh.shape["data"])
        mpath = os.path.join(path, _SHARDED_MANIFEST)
        if not os.path.exists(mpath):
            # v2 single-index bundle: adopt as 1 shard, reshard if needed.
            index, _, _ = load_index_bundle(path)
            config = dataclasses.replace(index.config, shards=None)
            if target == 1:
                return cls(
                    config=config, mesh=mesh, quant=index.quant,
                    perms=index.forest.perms, flips=index.forest.flips,
                    stack=None, points=None, single=index,
                    n_points=index.n_points,
                    n_valid=np.asarray([index.n_points], np.int64), pad_max=0,
                )
            if index.points is None:
                raise ValueError(
                    "cannot reshard a v2 bundle saved with store_points="
                    "False onto a multi-device mesh (no raw points to "
                    "re-partition)"
                )
            return cls._build_impl(
                np.asarray(jax.device_get(index.points), np.float32),
                config, mesh, index.quant,
            )
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("kind") != kind:
            raise ValueError(
                f"{path!r} is not a sharded-index checkpoint of kind "
                f"{kind!r} (kind={manifest.get('kind')!r})"
            )
        config = IndexConfig.from_dict(manifest["config"])
        n = int(manifest["n_points"])
        shard_indexes, id_maps, n_valid = [], [], []
        for name in manifest["shards"]:
            idx, extras, extra = load_index_bundle(
                os.path.join(path, "shards", name), kind=_SHARD_KIND
            )
            shard_indexes.append(idx)
            id_maps.append(np.asarray(jax.device_get(extras["shard_gids"]),
                                      np.int32))
            n_valid.append(int(extra["n_valid"]))
        if target == len(shard_indexes):
            if target == 1:
                return cls(
                    config=config, mesh=mesh, quant=shard_indexes[0].quant,
                    perms=shard_indexes[0].forest.perms,
                    flips=shard_indexes[0].forest.flips,
                    stack=None, points=None, single=shard_indexes[0],
                    n_points=n, n_valid=np.asarray(n_valid, np.int64),
                    pad_max=0,
                )
            return cls._assemble(
                config, mesh, shard_indexes[0].quant, shard_indexes,
                np.stack(id_maps), n, n_valid, int(manifest["pad_max"]),
            )
        # Shard-count change: gather owned rows, rebuild at the new count.
        if any(ix.points is None for ix in shard_indexes):
            raise ValueError(
                f"checkpoint has {len(shard_indexes)} shards but the mesh "
                f"wants {target}; resharding needs stored points "
                "(IndexConfig(store_points=True))"
            )
        pts = np.zeros((n, shard_indexes[0].dim), np.float32)
        for ix, gids, nv in zip(shard_indexes, id_maps, n_valid):
            own = gids[:nv]
            pts[own] = np.asarray(jax.device_get(ix.points))[: len(own)]
        # The checkpoint's config.shards describes the OLD partition; the
        # resharded index follows the mesh (auto), like the v2-adopt path.
        return cls._build_impl(
            pts, dataclasses.replace(config, shards=None), mesh,
            shard_indexes[0].quant,
        )


def build_auto(
    points: jax.Array,
    config: Optional[IndexConfig] = None,
    *,
    mesh: Optional[Mesh] = None,
    mutable: Optional[bool] = None,
    values: Optional[jax.Array] = None,
    buffer_capacity: int = 1024,
    max_segments: int = 8,
):
    """The ``backend="auto"`` of index construction.

    Args:
      points: (n, d) corpus to index.
      config: build configuration; ``None`` means ``IndexConfig()``.
      mesh: explicit ``('data',)`` mesh; default derives one from
        ``config.shards`` (else every local device).
      mutable: build the streaming (LSM) facade; ``None`` defers to
        ``config.mutable``.
      values: optional (n, ...) per-point payloads (mutable facades only).
      buffer_capacity: write-buffer rows (per shard when sharded);
        mutable facades only.
      max_segments: sealed-segment cap before tier merging; mutable only.

    Returns:
      The facade matching the resolved shard count (``config.shards``,
      else the mesh's ``data`` axis, else every local device) and
      mutability: :class:`HilbertIndex`, :class:`ShardedHilbertIndex`,
      :class:`repro.index.MutableHilbertIndex`, or
      :class:`repro.index.ShardedMutableHilbertIndex` — so the same call
      site scales from a laptop to a pod, static or streaming, without
      branching.
    """
    if config is None:
        config = IndexConfig()
    if mutable is None:
        mutable = config.mutable
    if mesh is not None:
        n_shards = int(mesh.shape["data"])
    elif config.shards is not None:
        n_shards = config.shards
    else:
        n_shards = jax.device_count()
    if n_shards > 1:
        if mutable:
            from repro.index.sharded_mutable import ShardedMutableHilbertIndex

            return ShardedMutableHilbertIndex.build(
                points, config, mesh=mesh, values=values,
                buffer_capacity=buffer_capacity, max_segments=max_segments,
            )
        return ShardedHilbertIndex.build(points, config, mesh=mesh)
    config = dataclasses.replace(config, shards=None)
    if mutable:
        from repro.index.mutable import MutableHilbertIndex

        mut = MutableHilbertIndex(
            config, buffer_capacity=buffer_capacity, max_segments=max_segments
        )
        mut.bulk_load(points, values)
        return mut
    return HilbertIndex.build(points, config)
