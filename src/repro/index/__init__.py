"""Public API: the unified, self-describing Hilbert-forest index.

    from repro.index import HilbertIndex, IndexConfig

    index = HilbertIndex.build(points, IndexConfig())
    ids, d2 = index.search(queries, SearchParams(k=30))   # Task 1
    gids, gd2 = index.knn_graph(GraphParams(k=15))        # Task 2
    index.save("ckpt/index"); index = HilbertIndex.load("ckpt/index")

Legacy entry points (``repro.core.search.build_index/search`` and
``repro.core.knn_graph.build_knn_graph``) are deprecation shims over this
package for one release.
"""

from repro.core.types import (  # noqa: F401  (re-exported for one-stop import)
    ForestConfig,
    GraphParams,
    QuantizerConfig,
    SearchParams,
)
from repro.index.config import IndexConfig  # noqa: F401
from repro.index.facade import (  # noqa: F401
    BACKENDS,
    HilbertIndex,
    build_with_timings,
    load_index_bundle,
    resolve_backend,
    save_index_bundle,
)

__all__ = [
    "HilbertIndex",
    "IndexConfig",
    "ForestConfig",
    "QuantizerConfig",
    "SearchParams",
    "GraphParams",
    "BACKENDS",
    "build_with_timings",
    "resolve_backend",
    "save_index_bundle",
    "load_index_bundle",
]
