"""Public API: the unified, self-describing Hilbert-forest index.

    from repro.index import HilbertIndex, IndexConfig

    index = HilbertIndex.build(points, IndexConfig())
    ids, d2 = index.search(queries, SearchParams(k=30))   # Task 1
    gids, gd2 = index.knn_graph(GraphParams(k=15))        # Task 2
    index.save("ckpt/index"); index = HilbertIndex.load("ckpt/index")

For workloads that must absorb inserts and deletions while serving, use the
LSM-style streaming wrapper :class:`repro.index.MutableHilbertIndex`
(:mod:`repro.index.mutable`): a write buffer searched exactly, sealed
immutable :class:`HilbertIndex` segments, tombstoned deletes, and tiered
compaction riding the paper's fast Hilbert-sort build path::

    mut = MutableHilbertIndex(IndexConfig())
    ids = mut.insert(points); mut.delete(ids[:5])
    hits, d2 = mut.search(queries, SearchParams(k=30))
    mut.compact()                       # merge segments, drop tombstones

When the corpus outgrows one device's RAM, the row-partitioned facade
:class:`repro.index.ShardedHilbertIndex` (:mod:`repro.index.sharded`)
spreads the forest over the mesh's ``data`` axis — per-shard fused search
inside ``shard_map`` merged by an associative cross-shard top-k, one
jitted dispatch per query chunk.  And when that sharded deployment must
ALSO absorb churn, :class:`repro.index.ShardedMutableHilbertIndex`
(:mod:`repro.index.sharded_mutable`) composes the two: shard-local write
buffers routed by curve range, cross-shard sealed generations, and a
compaction that re-balances the partition — search still one dispatch per
chunk.  :func:`repro.index.build_auto` picks the right facade for the
host::

    index = build_auto(points, IndexConfig())   # sharded iff >1 device
    ids, d2 = index.search(queries, SearchParams(k=30))
    streaming = build_auto(points, IndexConfig(), mutable=True)

Legacy entry points (``repro.core.search.build_index/search`` and
``repro.core.knn_graph.build_knn_graph``) are deprecation shims over this
package for one release.
"""

from repro.core.types import (  # noqa: F401  (re-exported for one-stop import)
    ForestConfig,
    GraphParams,
    QuantizerConfig,
    SearchParams,
)
from repro.index.config import IndexConfig  # noqa: F401
from repro.index.facade import (  # noqa: F401
    BACKENDS,
    HilbertIndex,
    build_with_timings,
    load_index_bundle,
    resolve_backend,
    save_index_bundle,
)
from repro.index.mutable import (  # noqa: F401
    LsmIdSpace,
    MutableHilbertIndex,
    Segment,
    load_mutable_bundle,
    save_mutable_bundle,
)
from repro.index.sharded import (  # noqa: F401
    ShardedHilbertIndex,
    build_auto,
)
from repro.index.sharded_mutable import (  # noqa: F401
    ShardedMutableHilbertIndex,
    ShardedSegment,
    load_sharded_mutable_as_mutable,
    load_sharded_mutable_bundle,
    save_sharded_mutable_bundle,
)

__all__ = [
    "HilbertIndex",
    "ShardedHilbertIndex",
    "ShardedMutableHilbertIndex",
    "build_auto",
    "LsmIdSpace",
    "MutableHilbertIndex",
    "Segment",
    "ShardedSegment",
    "IndexConfig",
    "ForestConfig",
    "QuantizerConfig",
    "SearchParams",
    "GraphParams",
    "BACKENDS",
    "build_with_timings",
    "resolve_backend",
    "save_index_bundle",
    "load_index_bundle",
    "save_mutable_bundle",
    "load_mutable_bundle",
    "save_sharded_mutable_bundle",
    "load_sharded_mutable_bundle",
]
