"""ShardedMutableHilbertIndex: shard-local LSM writes on the partitioned forest.

PR 2 made the index streaming (write buffer, sealed segments, tombstones,
compaction); PR 4 made it row-partitioned (``shard_map`` fused search with a
cross-shard ``merge_topk``).  This module composes the two so the sharded
layout — the only one that scales past one host — stops being static:

* **Per-shard write buffers** — every shard owns a fixed-capacity buffer
  slice; an insert is *routed* to the shard owning its master-curve range
  (:func:`repro.core.distributed.route_to_shards` against the partition's
  opening keys, frozen at build/compaction time), so freshly written rows
  keep the same curve locality the static partition has.  Before any bounds
  exist (an index born empty) routing falls back to round-robin.
* **Sealed generations** — when any shard's buffer fills (or
  :meth:`flush`), every shard's live buffered rows seal together into ONE
  cross-shard segment *generation*: per-shard :class:`HilbertIndex` builds
  sharing a generation-global quantizer (cross-shard distances within the
  generation are mutually comparable, exactly like the static sharded
  build), stacked ``(S, ...)`` and laid out ``P('data')``.  Shards pad to
  the generation's max row count with cyclic copies keeping REAL external
  ids; a shard with no rows holds copies of the generation's smallest-id
  row — duplicates collapse in the merge, no sentinels in the hot path.
* **Tombstones** — the dense by-external-id ``alive`` mask (the shared
  :class:`repro.index.mutable.LsmIdSpace`), device-resident padded to a
  power-of-two capacity so the search dispatch masks dead candidates
  in-computation (capacity growth retraces only log-many times).
* **Search** — ONE jitted dispatch per query chunk: inside ``shard_map``
  each device brute-forces its buffer slice and runs the PR 3 fused
  pipeline over every sealed generation — each generation's ``k`` inflated
  by its padding count plus a power-of-two bucket of its worst per-shard
  tombstone count (:func:`repro.core.search.inflate_k`), so dead or
  duplicate rows can never crowd a live neighbor out of the pool — maps
  local rows to external ids, masks tombstones, deflates the inflated
  pool to a local top-k and reduces across shards via
  :func:`repro.core.distributed.cross_shard_merge_topk` (butterfly tree
  by default, flat ``all_gather`` as the ``merge="gather"`` reference).
* **Compaction** — :meth:`compact` gathers the survivors in external-id
  (= insertion) order and literally calls
  :class:`repro.index.ShardedHilbertIndex`.build over them: the global
  Hilbert partition re-runs and rows RE-BALANCE across shards, so
  post-compact search is **bit-equal** to a fresh sharded build on the
  surviving rows (asserted under 8 virtual devices in
  ``tests/test_sharded_mutable.py``).  Tier merges between compactions stay
  shard-local: each shard re-sorts only its own rows, no cross-shard moves.

Checkpoints are **format_version 4** (see ``docs/CHECKPOINTS.md``): one
ordinary v2-valid bundle per (generation, shard) plus a buffer/tombstone
sidecar bundle, committed by a single atomically-renamed manifest.  v3
static-sharded checkpoints are adopted on load, and a mesh whose shard
count differs from the checkpoint's triggers a compact-on-load reshard.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import checkpoint
from repro.core import distributed as distributed_lib
from repro.core import quantize
from repro.core import search as search_lib
from repro.core.types import SearchParams
from repro.index.config import IndexConfig
from repro.obs.dispatch import dispatch_scope
from repro.obs.trace import span
from repro.index.facade import (
    BoundedJitCache,
    _pow2_bucket,
    build_with_timings,
    load_index_bundle,
    resolve_backend,
    save_index_bundle,
)
from repro.checkpoint import wal as wal_lib
from repro.index.mutable import (
    LsmIdSpace,
    WalFacade,
    _recover_wal,
    _restore_state_bundle,
)
from repro.testing.faults import fault_point
from repro.index.sharded import (
    ShardedHilbertIndex,
    ShardStack,
    shard_index_from_stack,
    stack_shard_indexes,
)

__all__ = [
    "ShardedMutableHilbertIndex",
    "ShardedSegment",
    "load_sharded_mutable_as_mutable",
    "load_sharded_mutable_bundle",
    "save_sharded_mutable_bundle",
]

_MANIFEST = "sharded_mutable_manifest.json"
_STATIC_MANIFEST = "sharded_manifest.json"  # v3 adoption
_SEG_SHARD_KIND = "sharded_mutable_segment_shard"
_DEFAULT_KIND = "sharded_mutable_hilbert_index"
_FORMAT_VERSION = 4
# Compiled search dispatches kept per index.  Keys change whenever the LSM
# shape does (generation sealed/merged, alive capacity doubled, tombstone
# bucket moved), so a long-lived streaming server would otherwise pin one
# shard_map executable per historical shape forever; the shared
# ``repro.index.facade.BoundedJitCache`` (LRU at this bound) caps that
# while keeping every shape the CURRENT state cycles through.
_CHUNK_FN_CACHE_MAX = 32


def _pow2_ceil(x: int) -> int:
    """0 for x<=0, else the smallest power of two >= x."""
    return 0 if x <= 0 else 1 << (int(x) - 1).bit_length()


@dataclasses.dataclass(eq=False)  # identity equality: segments hold arrays
class ShardedSegment:
    """One sealed cross-shard generation: stacked per-shard indexes + id map.

    ``stack.id_map`` (and its host copy ``ids_host``) maps each shard-local
    row — including cyclic padding rows — to its stable EXTERNAL id, so a
    local search hit resolves to a global result with one gather and
    duplicate padding rows collapse in the cross-shard merge.
    """

    stack: ShardStack            # (S, ...) leaves, P('data'); id_map = ext ids
    points: Optional[jax.Array]  # (S, n_pad, d) fp32, P('data'); None when
    #                              built with store_points=False (segment
    #                              serves but cannot merge/re-partition)
    quant: quantize.Quantizer    # generation-global, replicated
    gen: int                     # monotone generation tag (on-disk name)
    n_valid: np.ndarray          # (S,) owned-row counts (pre-padding)
    pad_max: int                 # max padding among shards that own rows
    ids_host: np.ndarray         # (S, n_pad) int32 ext ids incl. padding
    # worst-per-shard dead-count cache, keyed by the owner's delete epoch
    dead_cache: int = dataclasses.field(default=-1, repr=False)
    dead_epoch: int = dataclasses.field(default=-1, repr=False)

    @property
    def n_pad(self) -> int:
        return int(self.ids_host.shape[1])

    @property
    def n_owned(self) -> int:
        return int(self.n_valid.sum())


class ShardedMutableHilbertIndex(WalFacade):
    """Streaming insert/delete/search over a row-partitioned Hilbert forest.

    Typical lifecycle (requires a multi-device ``data`` mesh; on one device
    use :class:`repro.index.MutableHilbertIndex`)::

        idx = ShardedMutableHilbertIndex.build(points, IndexConfig(),
                                               mesh=data_mesh(8))
        ids = idx.insert(fresh)            # routed to curve-owning shards
        idx.delete(ids[:10])               # tombstoned, invisible to search
        hits, d2 = idx.search(queries, SearchParams(k=30))   # ONE dispatch
        idx.compact()                      # re-balance == fresh sharded build
        idx.save(path); idx = ShardedMutableHilbertIndex.load(path)

    ``insert`` may carry per-point ``values`` (e.g. kNN-LM next tokens);
    gather them for search hits with :meth:`values_at`.  External ids are
    stable for the life of the index, across flushes, compactions, and
    save/load.
    """

    def __init__(
        self,
        config: Optional[IndexConfig] = None,
        *,
        mesh: Optional[Mesh] = None,
        buffer_capacity: int = 1024,
        max_segments: int = 8,
    ):
        if buffer_capacity < 1:
            raise ValueError(f"buffer_capacity must be >= 1, got {buffer_capacity}")
        if max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {max_segments}")
        # config.store_points is honored like the single-device mutable
        # index: True (the default) keeps raw fp32 points on every
        # generation so tier merges and the re-balancing compaction can
        # re-sort them; False reclaims that RAM for serving-only
        # deployments at the cost of maintenance (point-less generations
        # never merge; compact() raises).
        self.config = IndexConfig() if config is None else config
        if mesh is None:
            from repro.launch.mesh import data_mesh

            mesh = data_mesh(self.config.shards)
        self.mesh = mesh
        if self.n_shards < 2:
            raise ValueError(
                "ShardedMutableHilbertIndex needs a multi-device 'data' mesh; "
                "on one device use MutableHilbertIndex"
            )
        self.buffer_capacity = int(buffer_capacity)
        self.max_segments = int(max_segments)
        self.segments: List[ShardedSegment] = []
        self._lsm = LsmIdSpace()
        self._dim: Optional[int] = None
        self._buf_pts: Optional[np.ndarray] = None   # (S, B, d) fp32 host
        self._buf_ids: Optional[np.ndarray] = None   # (S, B) int32 host
        self._buf_count: Optional[np.ndarray] = None  # (S,) int
        self._dev_buf = None                         # device mirror, lazy
        self._perms: Optional[jax.Array] = None      # shared forest seed
        self._flips: Optional[jax.Array] = None
        self._bounds: Optional[np.ndarray] = None    # (S-1, W) curve keys
        self._route_lo: Optional[np.ndarray] = None  # (d,) partition box
        self._route_hi: Optional[np.ndarray] = None
        self._rr = 0                                 # round-robin cursor
        self._gen = 0
        self._alive_key = None
        self._alive_dev = None
        self._chunk_fns = BoundedJitCache(_CHUNK_FN_CACHE_MAX)
        self.last_dispatch_count = 0
        self._wal: Optional[wal_lib.WriteAheadLog] = None

    # -- introspection -------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape["data"])

    @property
    def dim(self) -> Optional[int]:
        return self._dim

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def n_live(self) -> int:
        """Points visible to search (inserted, not deleted)."""
        return self._lsm.n_live

    @property
    def n_deleted(self) -> int:
        return self._lsm.n_deleted

    @property
    def n_buffered(self) -> int:
        """Live points still in the per-shard write buffers."""
        if self._buf_count is None:
            return 0
        total = 0
        for s in range(self.n_shards):
            c = int(self._buf_count[s])
            if c:
                total += int(np.count_nonzero(
                    self._lsm.alive[self._buf_ids[s, :c]]
                ))
        return total

    def memory_report(self) -> Dict[str, object]:
        """Bytes for ALL resident state, split sharded vs replicated.

        ``per_device_bytes`` ≈ ``sharded_bytes / n_shards +
        replicated_bytes`` — the number to compare against a per-device RAM
        budget, now including buffer slices and segment stacks on top of
        the static layout's accounting.
        """
        s = self.n_shards
        per_segment, sharded, replicated = [], 0, 0
        for seg in self.segments:
            leaves = list(seg.stack) + (
                [seg.points] if seg.points is not None else []
            )
            b = sum(int(leaf.nbytes) for leaf in leaves)
            per_segment.append(b)
            sharded += b
            replicated += sum(
                int(a.nbytes)
                for a in (seg.quant.boundaries, seg.quant.centroids)
            )
        if self._perms is not None:
            replicated += int(self._perms.nbytes) + int(self._flips.nbytes)
        # the device-resident tombstone mask is replicated on every device
        # at its pow2-padded search capacity (1 byte per slot)
        alive_dev_bytes = max(1024, _pow2_ceil(self._lsm.next_id))
        replicated += alive_dev_bytes
        buffer_bytes = 0
        if self._buf_pts is not None:
            buffer_bytes = self._buf_pts.nbytes + self._buf_ids.nbytes
        sharded += buffer_bytes
        rep: Dict[str, object] = {
            "n_shards": s,
            "segments_bytes": int(sum(per_segment)),
            "per_segment": [int(b) for b in per_segment],
            "buffer_bytes": int(buffer_bytes),
            "values_bytes": (
                0 if self._lsm.values is None else int(self._lsm.values.nbytes)
            ),
            "tombstone_bytes": int(self._lsm.alive.nbytes),
            "sharded_bytes": int(sharded),
            "replicated_bytes": int(replicated),
            "n_segments": self.n_segments,
            "n_live": self.n_live,
            "n_deleted": self.n_deleted,
            "n_buffered": self.n_buffered,
        }
        rep["total_bytes"] = (
            rep["sharded_bytes"] + rep["replicated_bytes"]
            + rep["values_bytes"] + rep["tombstone_bytes"]
        )
        rep["per_device_bytes"] = [sharded // s + replicated] * s
        return rep

    def __repr__(self) -> str:
        mb = self.memory_report()["total_bytes"] / 1e6
        return (
            f"ShardedMutableHilbertIndex(n_live={self.n_live}, "
            f"n_shards={self.n_shards}, n_segments={self.n_segments}, "
            f"buffered={self.n_buffered}/{self.n_shards}x"
            f"{self.buffer_capacity}, deleted={self.n_deleted}, "
            f"dim={self._dim}, {mb:.2f} MB)"
        )

    # -- construction --------------------------------------------------------

    @classmethod
    def build(
        cls,
        points: jax.Array,
        config: Optional[IndexConfig] = None,
        *,
        mesh: Optional[Mesh] = None,
        values: Optional[jax.Array] = None,
        buffer_capacity: int = 1024,
        max_segments: int = 8,
    ) -> "ShardedMutableHilbertIndex":
        """Build from an initial corpus: one balanced partitioned base.

        Args:
          points: (n, d) fp32 corpus; rows get external ids ``0..n-1``.
          config: build config.  ``store_points=True`` (the default) keeps
            raw points so tier merges and the re-balancing compaction can
            re-sort them; ``False`` serves RAM-lean but inserts route
            round-robin and maintenance raises.
          mesh: ``('data',)`` mesh; defaults to ``config.shards`` devices
            (else every local device).
          values: optional (n, ...) per-point payloads.
          buffer_capacity: write-buffer rows PER SHARD.
          max_segments: sealed-generation cap before tier merging.

        Returns:
          The streaming index; its initial search results are bit-equal to
          a static :class:`ShardedHilbertIndex` built from the same call.
        """
        base = ShardedHilbertIndex.build(points, config, mesh=mesh)
        return cls.from_sharded(
            base, values=values, buffer_capacity=buffer_capacity,
            max_segments=max_segments,
        )

    @classmethod
    def from_sharded(
        cls,
        base: ShardedHilbertIndex,
        *,
        values: Optional[jax.Array] = None,
        buffer_capacity: int = 1024,
        max_segments: int = 8,
    ) -> "ShardedMutableHilbertIndex":
        """Adopt a prebuilt static sharded index (external ids ``0..n-1``).

        The v3-checkpoint upgrade path: the static index's stack becomes
        generation 0 unchanged (its global row ids ARE the external ids),
        and the partition's opening keys are recovered from the stored
        points so future inserts route to the curve-owning shards.  A base
        built with ``store_points=False`` (the old static serving layout)
        still adopts: it serves and absorbs inserts/deletes, but inserts
        route round-robin (no points to recover bounds from) and
        maintenance touching generation 0 raises — matching
        :meth:`MutableHilbertIndex.from_index` semantics.
        """
        if base.single is not None:
            raise ValueError(
                "from_sharded needs a multi-shard index; wrap a 1-shard "
                "index with MutableHilbertIndex.from_index instead"
            )
        self = cls(
            config=base.config, mesh=base.mesh,
            buffer_capacity=buffer_capacity, max_segments=max_segments,
        )
        n = base.n_points
        vals = self._lsm.validate(n, values)
        self._dim = int(base.dim)
        self._alloc_buffers()
        self._lsm.register(n, vals)
        self._adopt_base(base, np.arange(n, dtype=np.int32))
        return self

    def _alloc_buffers(self) -> None:
        s = self.n_shards
        self._buf_pts = np.zeros(
            (s, self.buffer_capacity, self._dim), np.float32
        )
        self._buf_ids = np.full((s, self.buffer_capacity), -1, np.int32)
        self._buf_count = np.zeros((s,), np.int64)

    def _adopt_base(
        self, base: ShardedHilbertIndex, gids: np.ndarray
    ) -> None:
        """Wrap a fresh static build as a sealed generation + routing bounds.

        ``gids[row] = external id`` of the base corpus's row-th point.  The
        stack is reused as-is when the mapping is the identity (build/
        adopt); after a compaction it is the sorted live-id list.
        """
        id_host = np.asarray(jax.device_get(base.stack.id_map))
        ext_host = np.asarray(gids, np.int32)[id_host]
        stack = base.stack
        if not np.array_equal(ext_host, id_host):
            stack = stack._replace(id_map=jax.device_put(
                jnp.asarray(ext_host), NamedSharding(self.mesh, P("data"))
            ))
        self.segments.append(ShardedSegment(
            stack=stack, points=base.points, quant=base.quant,
            gen=self._gen, n_valid=np.asarray(base.n_valid, np.int64),
            pad_max=int(base.pad_max), ids_host=ext_host,
        ))
        self._gen += 1
        self._perms, self._flips = base.perms, base.flips
        if base.points is None:
            # No stored points to recover the partition's opening keys
            # from: inserts route round-robin until the next full build.
            self._bounds = None
            return
        # Recover the partition's opening keys for insert routing: shard
        # s's first owned row is its lowest point on the master curve.
        pts_host = np.asarray(jax.device_get(base.points))
        nv = [int(v) for v in base.n_valid]
        own = np.concatenate(
            [pts_host[s, : nv[s]] for s in range(self.n_shards) if nv[s]]
        )
        lo, hi = own.min(axis=0), own.max(axis=0)
        firsts = [
            pts_host[s, 0] if nv[s] else None for s in range(self.n_shards)
        ]
        self._bounds = distributed_lib.curve_partition_bounds(
            firsts, self.config.forest, lo, hi
        )
        self._route_lo, self._route_hi = lo, hi

    # -- mutation ------------------------------------------------------------

    def _register(self, points, values) -> Tuple[np.ndarray, np.ndarray]:
        """Shared insert bookkeeping (same contract as the mutable facade:
        ``prepare`` validates everything before any state mutates)."""
        pts, vals = self._lsm.prepare(points, values, self._dim)
        if pts.shape[0] == 0:
            return pts, np.zeros((0,), np.int32)
        if self._dim is None:
            self._dim = int(pts.shape[1])
            self._alloc_buffers()
        return pts, self._lsm.register(pts.shape[0], vals)

    def _route(self, pts: np.ndarray) -> np.ndarray:
        """Owning shard per row: curve bounds when known, else round-robin."""
        if self._bounds is None:
            out = (np.arange(pts.shape[0]) + self._rr) % self.n_shards
            self._rr = int((self._rr + pts.shape[0]) % self.n_shards)
            return out.astype(np.int32)
        return distributed_lib.route_to_shards(
            pts, self.config.forest, self._route_lo, self._route_hi,
            self._bounds,
        )

    def insert(
        self, points: jax.Array, values: Optional[jax.Array] = None
    ) -> np.ndarray:
        """Insert points (m, d); returns their stable external ids (m,).

        Each row lands in the write buffer of the shard owning its
        master-curve range (searchable immediately, exactly); whenever any
        shard's buffer fills, ALL shards' buffered rows seal into one
        cross-shard generation, and tier merging keeps the generation count
        at most ``max_segments``.  ``values`` attaches one payload per
        point — either every insert carries values or none does.
        """
        self._wal_log_insert("insert", points, values)
        pts, ids = self._register(points, values)
        m = pts.shape[0]
        if m == 0:
            return ids
        routes = self._route(pts)
        todo = np.ones((m,), np.bool_)
        while todo.any():
            for s in range(self.n_shards):
                idx = np.nonzero(todo & (routes == s))[0]
                if idx.size == 0:
                    continue
                c = int(self._buf_count[s])
                take = idx[: self.buffer_capacity - c]
                if take.size:
                    sl = slice(c, c + take.size)
                    self._buf_pts[s, sl] = pts[take]
                    self._buf_ids[s, sl] = ids[take]
                    self._buf_count[s] = c + take.size
                    todo[take] = False
            if int(self._buf_count.max()) >= self.buffer_capacity:
                self.flush()
        self._dev_buf = None
        self._maybe_merge_tiers()
        return ids

    def bulk_load(
        self, points: jax.Array, values: Optional[jax.Array] = None
    ) -> np.ndarray:
        """Seal a whole corpus at once, bypassing the write buffers.

        On an empty index this is :meth:`build`: a balanced partitioned
        base whose search is bit-equal to a fresh static sharded build.  On
        a live index the corpus seals as ONE generation, routed by the
        existing partition bounds.  Returns external ids like
        :meth:`insert`.
        """
        self._wal_log_insert("bulk_load", points, values)
        had_content = bool(self.segments) or self.n_buffered > 0
        pts, ids = self._register(points, values)
        if pts.shape[0] == 0:
            raise ValueError("bulk_load needs a non-empty (m, d) corpus")
        if not had_content:
            base = ShardedHilbertIndex.build(
                jnp.asarray(pts), self.config, mesh=self.mesh
            )
            self._adopt_base(base, ids)
            return ids
        routes = self._route(pts)
        self._seal([
            (ids[routes == s], pts[routes == s])
            for s in range(self.n_shards)
        ])
        self._maybe_merge_tiers()
        return ids

    def delete(self, ids) -> int:
        """Tombstone external ids; returns how many were newly deleted.

        Unknown ids raise ``KeyError``; repeats are idempotent.  Rows are
        physically dropped by the flush/merge/compaction that next touches
        their shard.
        """
        self._wal_log_delete(ids)
        return self._lsm.delete(ids)

    # -- generation lifecycle ------------------------------------------------

    def _seal(
        self, rows: Sequence[Tuple[np.ndarray, np.ndarray]],
        quant: Optional[quantize.Quantizer] = None,
        *, pad: bool = False,
    ) -> Optional[ShardedSegment]:
        """Seal per-shard (ids, points) rows into one stacked generation.

        Shards pad with cyclic copies of their own rows; a shard with no
        rows holds copies of the generation's smallest-id row, whose
        duplicate ids collapse in the cross-shard merge.  ``quant`` (fit
        over the union when not given) is shared by every shard so
        in-generation cross-shard distances are mutually comparable.

        With ``pad=True`` and ``config.seal_pow2`` the per-shard row count
        rounds up to the next power of two instead of the exact max, so
        steady-state churn recycles a handful of stack shapes and the
        jitted dispatch stops recompiling once warm.  The extra rows are
        more cyclic copies — ``pad_max`` grows, the existing per-
        generation k inflation absorbs them, results stay exact.
        """
        n_valid = np.asarray([ids.size for ids, _ in rows], np.int64)
        if int(n_valid.sum()) == 0:
            return None
        n_pad = int(n_valid.max())
        if pad and self.config.seal_pow2:
            n_pad = _pow2_ceil(max(n_pad, 1))
        all_ids = np.concatenate([ids for ids, _ in rows])
        all_pts = np.concatenate([pts for _, pts in rows])
        j = int(np.argmin(all_ids))
        e0, p0 = np.int32(all_ids[j]), all_pts[j]
        if quant is None:
            quant = quantize.fit(
                jnp.asarray(all_pts), bits=self.config.quantizer.bits,
                sample_limit=self.config.quantizer.sample_limit,
            )
        shard_indexes, id_maps = [], np.zeros(
            (self.n_shards, n_pad), np.int32
        )
        for s, (ids_s, pts_s) in enumerate(rows):
            if ids_s.size == 0:
                id_maps[s] = np.full((n_pad,), e0, np.int32)
                pts_pad = np.tile(p0[None, :], (n_pad, 1))
            else:
                reps = -(-n_pad // ids_s.size)
                id_maps[s] = np.tile(
                    ids_s.astype(np.int32), reps
                )[:n_pad]
                pts_pad = np.tile(pts_s, (reps, 1))[:n_pad]
            with span("lsm.generation_build",
                      rows=int(pts_pad.shape[0]), shard=s), \
                    dispatch_scope("lsm.generation_build"):
                idx, _ = build_with_timings(
                    jnp.asarray(pts_pad), self.config, quant=quant
                )
            shard_indexes.append(idx)
        stack, points = stack_shard_indexes(
            self.mesh, shard_indexes, id_maps,
            store_points=self.config.store_points,
        )
        repl = NamedSharding(self.mesh, P())
        seg = ShardedSegment(
            stack=stack, points=points,
            quant=jax.device_put(quant, repl),
            gen=self._gen, n_valid=n_valid,
            pad_max=int(max(
                (n_pad - int(v) for v in n_valid if v > 0), default=0
            )),
            ids_host=id_maps,
        )
        self._gen += 1
        if self._perms is None:
            self._perms = jax.device_put(shard_indexes[0].forest.perms, repl)
            self._flips = jax.device_put(shard_indexes[0].forest.flips, repl)
        self.segments.append(seg)
        return seg

    def flush(self) -> Optional[ShardedSegment]:
        """Seal every shard's live buffered rows into one generation.

        Dead buffer rows drop here for good.  No-op (returns None) when all
        buffers are empty or fully tombstoned.
        """
        if self._buf_count is None or int(self._buf_count.sum()) == 0:
            return None
        rows = []
        for s in range(self.n_shards):
            c = int(self._buf_count[s])
            ids_s = self._buf_ids[s, :c]
            live = self._lsm.alive[ids_s]
            rows.append((ids_s[live].copy(), self._buf_pts[s, :c][live].copy()))
        self._buf_count[:] = 0
        self._buf_ids[:] = -1
        self._dev_buf = None
        return self._seal(rows, pad=True)

    def _owned_rows(
        self, seg: ShardedSegment, s: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Shard s's owned (pre-padding) external ids + points, host-side."""
        if seg.points is None:
            raise ValueError(
                "cannot re-sort a generation built without stored points "
                "(IndexConfig(store_points=False), or a store_points=False "
                "index adopted via from_sharded)"
            )
        nv = int(seg.n_valid[s])
        ids = seg.ids_host[s, :nv]
        pts = np.asarray(jax.device_get(seg.points[s]))[:nv]
        return ids, pts

    def _merge_segments(
        self, to_merge: Sequence[ShardedSegment]
    ) -> Optional[ShardedSegment]:
        """Replace ``to_merge`` with one generation; tombstoned rows vanish.

        Shard-local by construction: each shard's new rows are the union of
        its own rows across the merged generations (re-sorted by external
        id), so tier merges never move rows between shards — only
        :meth:`compact` re-runs the global partition.
        """
        rows = []
        for s in range(self.n_shards):
            owned = [self._owned_rows(seg, s) for seg in to_merge]
            ids_s = np.concatenate([ids for ids, _ in owned])
            pts_s = np.concatenate([pts for _, pts in owned])
            live = self._lsm.alive[ids_s]
            ids_s, pts_s = ids_s[live], pts_s[live]
            order = np.argsort(ids_s, kind="stable")
            rows.append((ids_s[order], pts_s[order]))
        self.segments = [x for x in self.segments if x not in to_merge]
        return self._seal(rows, pad=True)

    def _maybe_merge_tiers(self) -> None:
        while len(self.segments) > self.max_segments:
            # Only generations holding raw points can be re-sorted; without
            # store_points the generation count is unbounded by design.
            mergeable = [g for g in self.segments if g.points is not None]
            if len(mergeable) < 2:
                return
            smallest = sorted(mergeable, key=lambda g: g.n_owned)[:2]
            self._merge_segments(smallest)

    def compact(self) -> "ShardedMutableHilbertIndex":
        """Full compaction: re-partition and re-balance the survivors.

        Gathers every live row (segments + buffers) in external-id
        (= insertion) order and rebuilds via
        :class:`ShardedHilbertIndex`.build — ``hilbert_partition`` re-runs,
        so rows re-balance across shards and post-compact search is
        bit-equal to a fresh sharded build over the surviving points.
        Raises if any generation was built without stored points
        (``store_points=False``) — there is nothing to re-sort.  Returns
        self (chainable).
        """
        ids, pts = self._gather_live()
        if self._buf_count is not None:
            self._buf_count[:] = 0
            self._buf_ids[:] = -1
        self._dev_buf = None
        self.segments = []
        self._chunk_fns.clear()
        if ids.size == 0:
            self._bounds = None
            return self
        with span("lsm.compact", rows=int(ids.size)), \
                dispatch_scope("lsm.compact"):
            base = ShardedHilbertIndex.build(
                jnp.asarray(pts), self.config, mesh=self.mesh
            )
            self._adopt_base(base, ids)
        return self

    # -- serving-engine hooks ------------------------------------------------

    def snapshot(self) -> "ShardedMutableHilbertIndex":
        """Cheap shared-buffer copy for off-path maintenance (double-buffer).

        Mirrors :meth:`MutableHilbertIndex.snapshot`: sealed generations
        are immutable, so their stacked device arrays are SHARED (zero
        copy) under fresh :class:`ShardedSegment` wrappers (dead-count
        caches must not race between serving copy and shadow); the
        per-shard write buffers, routing bounds, and LSM bookkeeping are
        deep-copied.  The compiled-dispatch cache starts empty on the
        snapshot — the executables are keyed by LSM shape and re-resolve on
        first search after a swap.  The WAL is deliberately NOT carried
        over: the shadow must not re-log replayed mutations; the engine
        transfers the log old→shadow at swap time.
        """
        snap = ShardedMutableHilbertIndex(
            config=self.config, mesh=self.mesh,
            buffer_capacity=self.buffer_capacity,
            max_segments=self.max_segments,
        )
        snap._dim = self._dim
        if self._buf_pts is not None:
            snap._buf_pts = self._buf_pts.copy()
            snap._buf_ids = self._buf_ids.copy()
            snap._buf_count = self._buf_count.copy()
        snap._lsm = self._lsm.clone()
        snap._gen = self._gen
        snap._perms, snap._flips = self._perms, self._flips
        snap._rr = self._rr
        if self._bounds is not None:
            snap._bounds = self._bounds.copy()
            snap._route_lo = np.asarray(self._route_lo).copy()
            snap._route_hi = np.asarray(self._route_hi).copy()
        snap.segments = [
            ShardedSegment(
                stack=seg.stack, points=seg.points, quant=seg.quant,
                gen=seg.gen, n_valid=seg.n_valid.copy(),
                pad_max=seg.pad_max, ids_host=seg.ids_host,
            )
            for seg in self.segments
        ]
        return snap

    def maintenance_stats(self) -> Dict[str, object]:
        """The trigger signals a background maintainer watches (host-only)."""
        next_id = max(self._lsm.next_id, 1)
        return {
            "n_segments": self.n_segments,
            "mergeable_segments": sum(
                1 for g in self.segments if g.points is not None
            ),
            "n_live": self.n_live,
            "n_deleted": self.n_deleted,
            "n_buffered": self.n_buffered,
            "tombstone_ratio": float(self.n_deleted) / float(next_id),
        }

    def _gather_live(self) -> Tuple[np.ndarray, np.ndarray]:
        """All live (ids, points), host-side, sorted by external id."""
        parts_i, parts_p = [], []
        for seg in self.segments:
            for s in range(self.n_shards):
                ids_s, pts_s = self._owned_rows(seg, s)
                parts_i.append(ids_s)
                parts_p.append(pts_s)
        if self._buf_count is not None:
            for s in range(self.n_shards):
                c = int(self._buf_count[s])
                parts_i.append(self._buf_ids[s, :c])
                parts_p.append(self._buf_pts[s, :c])
        if not parts_i:
            d = self._dim or 0
            return np.zeros((0,), np.int32), np.zeros((0, d), np.float32)
        ids = np.concatenate(parts_i)
        pts = np.concatenate(parts_p)
        live = self._lsm.alive[ids]
        ids, pts = ids[live], pts[live]
        order = np.argsort(ids, kind="stable")
        return ids[order].astype(np.int32), np.ascontiguousarray(pts[order])

    # -- search --------------------------------------------------------------

    def _segment_dead_max(self, seg: ShardedSegment) -> int:
        """Worst per-shard tombstone count (padding dups included), cached.

        Safe under the engine's SHARED read lock: deletes hold the write
        side, so the epoch cannot move mid-read; racing readers perform
        an identical idempotent fill (value written before the epoch
        stamp, so a fresh epoch always pairs with a fresh count).
        """
        if seg.dead_epoch != self._lsm.delete_epoch:
            alive = self._lsm.alive
            seg.dead_cache = max(
                seg.n_pad - int(np.count_nonzero(alive[seg.ids_host[s]]))
                for s in range(self.n_shards)
            )
            seg.dead_epoch = self._lsm.delete_epoch
        return seg.dead_cache

    def rewrite_pressure(self, params: Optional[SearchParams] = None) -> int:
        """Generations whose tombstones exceed their stage-2 candidate
        pool under ``params`` — the read-triggered-rewrite condition,
        surfaced as a maintenance trigger for engines that search with
        ``allow_rewrite=False`` (shared read lock: the read path must
        not rebuild segments).  Mirrors the single-device facade.
        """
        if params is None:
            params = SearchParams()
        n = 0
        for seg in list(self.segments):
            cap = params.k2 * min(2 * params.h + 1, seg.n_pad)
            if (self._segment_dead_max(seg) > max(cap - params.k, 0)
                    and seg.points is not None):
                n += 1
        return n

    def _alive_device(self) -> Tuple[int, jax.Array]:
        """The alive mask padded to a pow2 capacity, replicated on device.

        Lock-free-safe lazy mirror: invalidation happens only in
        write-exclusive mutators (the key embeds the delete epoch and id
        cursor), concurrent readers may at worst both ``device_put`` the
        SAME mask (the loser's array is dropped), and the value is
        published before the key so a reader that observes a fresh key
        never pairs it with a stale array.  Readers work off locals —
        ``self`` is re-read once, not per use.
        """
        cap = max(1024, _pow2_ceil(self._lsm.next_id))
        key = (cap, self._lsm.delete_epoch, self._lsm.next_id)
        dev = self._alive_dev
        if self._alive_key != key or dev is None:
            pad = np.zeros((cap,), np.bool_)
            pad[: self._lsm.next_id] = self._lsm.alive
            dev = jax.device_put(
                jnp.asarray(pad), NamedSharding(self.mesh, P())
            )
            self._alive_dev = dev   # value BEFORE key: see docstring
            self._alive_key = key
        return cap, dev

    def _device_buffers(self) -> Tuple[jax.Array, jax.Array]:
        # same lazy-mirror discipline as _alive_device: read into a local,
        # fill idempotently; writers invalidate by assigning None under
        # the engine's exclusive lock
        buf = self._dev_buf
        if buf is None:
            data_sh = NamedSharding(self.mesh, P("data"))
            buf = (
                jax.device_put(jnp.asarray(self._buf_pts), data_sh),
                jax.device_put(jnp.asarray(self._buf_ids), data_sh),
            )
            self._dev_buf = buf
        return buf

    def search(
        self,
        queries: jax.Array,
        params: SearchParams = SearchParams(),
        *,
        backend: str = "auto",
        query_chunk: Optional[int] = None,
        merge: Optional[str] = None,
        prune: Optional[bool] = None,
        allow_rewrite: bool = True,
    ) -> Tuple[jax.Array, jax.Array]:
        """Mesh-wide streaming search; returns (ext ids (Q, k), sq-dists).

        ONE jitted dispatch per query chunk (``last_dispatch_count`` records
        the count): inside ``shard_map`` every device runs the fused
        pipeline over each sealed generation plus a brute-force pass over
        its buffer slice, masks tombstones against the device-resident
        alive mask, deflates the concatenated per-shard pool to a local
        top-k, and the shards reduce via
        :func:`repro.core.distributed.cross_shard_merge_topk` — the same
        ``merge="auto"|"gather"|"tree"`` / ``prune`` knobs as
        :class:`ShardedHilbertIndex` (defaults from the config).  When
        fewer than ``k`` live points exist the tail is id -1 / +inf.

        A generation tombstoned past its stage-2 candidate pool is
        rewritten on the spot (read-triggered shard-local compaction),
        mirroring the single-device mutable index.  ``allow_rewrite=False``
        suppresses that rewrite (the serving engine's shared-read-lock
        path: see :meth:`rewrite_pressure`) at the cost of degraded
        recall on the over-tombstoned generation until maintenance
        compacts it.
        """
        if params is None:
            params = SearchParams()
        merge = distributed_lib.resolve_merge(
            merge if merge is not None else self.config.merge, self.n_shards
        )
        prune = self.config.merge_prune if prune is None else bool(prune)
        use_kernels = resolve_backend(backend) == "pallas"
        if query_chunk is None:
            query_chunk = self.config.query_chunk
        q = jnp.asarray(queries)
        qn, k = q.shape[0], params.k
        dispatches = 0
        self.last_dispatch_count = 0
        if qn == 0 or self._dim is None or (
            not self.segments and self.n_buffered == 0
        ):
            return (
                jnp.full((qn, k), -1, jnp.int32),
                jnp.full((qn, k), jnp.inf, jnp.float32),
            )
        # Read-triggered rewrite: a generation whose tombstones could crowd
        # live neighbors out of its candidate pool is rebuilt (shard-local,
        # dead rows dropped for good) before this search runs.  Suppressed
        # on the engine's shared-read-lock path (allow_rewrite=False).
        if allow_rewrite:
            for seg in list(self.segments):
                cap = params.k2 * min(2 * params.h + 1, seg.n_pad)
                if (self._segment_dead_max(seg) > max(cap - k, 0)
                        and seg.points is not None):
                    self._merge_segments([seg])
        # Per-generation k inflation: padding dups + a pow2 bucket of the
        # worst tombstone count (bucketed so deletes only retrace the
        # dispatch log-many times).
        seg_meta = []
        for seg in self.segments:
            cap = params.k2 * min(2 * params.h + 1, seg.n_pad)
            k_seg = search_lib.inflate_k(
                k, seg.pad_max + _pow2_ceil(self._segment_dead_max(seg)), cap
            )
            seg_meta.append((seg.n_pad, k_seg))
        alive_cap, alive = self._alive_device()
        bpts, bids = self._device_buffers()
        fn = self._chunk_fn(
            params, tuple(seg_meta), use_kernels, alive_cap, merge, prune
        )
        stacks = tuple(seg.stack for seg in self.segments)
        quants = tuple(seg.quant for seg in self.segments)
        repl = NamedSharding(self.mesh, P())
        perms = (
            self._perms if self._perms is not None
            else jax.device_put(jnp.zeros((1, self._dim), jnp.int32), repl)
        )
        flips = (
            self._flips if self._flips is not None
            else jax.device_put(jnp.zeros((1, self._dim), jnp.bool_), repl)
        )
        outs_i, outs_d = [], []
        for s in range(0, qn, query_chunk):
            chunk = q[s : s + query_chunk]
            m = chunk.shape[0]
            bucket = _pow2_bucket(m, query_chunk)
            if bucket > m:
                chunk = jnp.pad(chunk, ((0, bucket - m), (0, 0)))
            with dispatch_scope("sharded_mutable.search"):
                ids, dists = fn(chunk, stacks, quants, perms, flips, bpts,
                                bids, alive)
            dispatches += 1
            if bucket > m:
                ids, dists = ids[:m], dists[:m]
            outs_i.append(ids)
            outs_d.append(dists)
        # one assignment at the end: last_dispatch_count is a diagnostic
        # scalar, and concurrent readers should each publish a consistent
        # per-call count rather than interleave increments
        self.last_dispatch_count = dispatches
        return jnp.concatenate(outs_i), jnp.concatenate(outs_d)

    def _chunk_fn(self, params: SearchParams, seg_meta: tuple,
                  use_kernels: bool, alive_cap: int, merge: str, prune: bool):
        key = (params.k1, params.k2, params.h, params.k, seg_meta,
               use_kernels, alive_cap, self.buffer_capacity, merge, prune)
        fn = self._chunk_fns.get(key)
        if fn is not None:
            return fn
        mesh = self.mesh
        fcfg = self.config.forest
        k1, k2, h, k = params.k1, params.k2, params.h, params.k
        k_buf = max(1, min(k, self.buffer_capacity))
        k_segs = [m[1] for m in seg_meta]
        n_shards = self.n_shards

        def shard_fn(q, stacks, quants, perms, flips, bpts, bids, alive):
            # shard_map keeps every sharded leading axis at local size 1.
            parts_g, parts_d = [], []
            for st, quant, k_seg in zip(stacks, quants, k_segs):
                ids_l, d2 = search_lib.fused_search_chunk(
                    q, st.orders[0], st.directories[0], st.lo[0], st.hi[0],
                    perms, flips, st.master_rank[0], st.sketches[0],
                    st.codes[0], st.master_order[0], quant,
                    bits=fcfg.bits, key_bits=fcfg.key_bits,
                    leaf_size=fcfg.leaf_size, k1=k1, k2=k2, h=h, k=k_seg,
                    use_kernels=use_kernels,
                )
                gids = jnp.where(
                    ids_l >= 0, st.id_map[0][jnp.maximum(ids_l, 0)], -1
                )
                live = (gids >= 0) & alive[
                    jnp.clip(gids, 0, alive.shape[0] - 1)
                ]
                parts_g.append(jnp.where(live, gids, -1))
                parts_d.append(jnp.where(live, d2, jnp.inf))
            bvalid = (bids[0] >= 0) & alive[
                jnp.clip(bids[0], 0, alive.shape[0] - 1)
            ]
            bidx, bd2 = search_lib.brute_force_topk(
                q, bpts[0], bvalid, k=k_buf
            )
            parts_g.append(jnp.where(jnp.isfinite(bd2), bids[0][bidx], -1))
            parts_d.append(bd2)
            cg = jnp.concatenate(parts_g, axis=1)
            cd = jnp.concatenate(parts_d, axis=1)
            return distributed_lib.cross_shard_merge_topk(
                cg, cd, k=k, axis="data", axis_size=n_shards,
                merge=merge, prune=prune,
            )

        fn = jax.jit(
            shard_map(
                shard_fn,
                mesh=mesh,
                in_specs=(P(None, None), P("data"), P(), P(), P(),
                          P("data"), P("data"), P()),
                out_specs=(P(None, None), P(None, None)),
                check_rep=False,
            )
        )
        self._chunk_fns.put(key, fn)
        return fn

    # -- values --------------------------------------------------------------

    def values_at(self, ids, fill=0) -> jax.Array:
        """Gather per-point values for search-result ids; -1 slots get fill."""
        return self._lsm.values_at(ids, fill=fill)

    def values_dense(self) -> jax.Array:
        """The dense by-external-id values array (stale rows where deleted)."""
        return self._lsm.values_dense()

    # -- persistence ---------------------------------------------------------

    def save(self, path: str, *, kind: str = _DEFAULT_KIND,
             extra_meta: Optional[Dict] = None) -> str:
        return save_sharded_mutable_bundle(
            self, path, kind=kind, extra_meta=extra_meta
        )

    @classmethod
    def load(
        cls, path: str, *, mesh: Optional[Mesh] = None,
        kind: str = _DEFAULT_KIND,
    ) -> "ShardedMutableHilbertIndex":
        index, _ = load_sharded_mutable_bundle(path, mesh=mesh, kind=kind)
        return index


def _seg_shard_uid(seg: ShardedSegment, s: int) -> str:
    """Content address of one (generation, shard) bundle for save dedup."""
    h = hashlib.sha1()
    h.update(np.int64(seg.gen).tobytes())
    codes = np.asarray(jax.device_get(seg.stack.codes[s]))
    h.update(np.asarray(
        seg.ids_host[s].shape + codes.shape, np.int64
    ).tobytes())
    h.update(seg.ids_host[s].tobytes())
    h.update(codes.tobytes())
    return h.hexdigest()


def _shard_bundle_uid(seg_dir: str) -> Optional[str]:
    step = checkpoint.latest_step(seg_dir)
    if step is None:
        return None
    try:
        with open(os.path.join(seg_dir, f"step_{step:08d}",
                               "manifest.json")) as f:
            return json.load(f).get("extra", {}).get("segment_uid")
    except (OSError, ValueError):
        return None


def save_sharded_mutable_bundle(
    index: ShardedMutableHilbertIndex,
    path: str,
    *,
    kind: str = _DEFAULT_KIND,
    extra_meta: Optional[Dict] = None,
) -> str:
    """Persist as per-(generation, shard) bundles + sidecar + one manifest.

    Format_version 4: every piece is an atomic ``repro.checkpoint`` bundle
    — one ordinary v2-valid index bundle per (generation, shard), written
    only when its content uid differs from what is on disk, plus a
    buffer/tombstone/values/bounds sidecar at a FRESH step — and the
    top-level JSON manifest renames into place LAST.  A crash mid-save or a
    concurrent load always observes a complete, mutually consistent set;
    bundles referenced by neither the new nor the previous manifest are
    pruned after the commit (one generation of grace).
    """
    os.makedirs(path, exist_ok=True)
    prev_manifest = {}
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            prev_manifest = json.load(f)
    except (OSError, ValueError):
        pass
    s_count = index.n_shards
    seg_entries = []
    for seg in index.segments:
        name = f"gen_{seg.gen:06d}"
        for s in range(s_count):
            shard_dir = os.path.join(path, "segments", name, f"shard_{s:05d}")
            uid = _seg_shard_uid(seg, s)
            if _shard_bundle_uid(shard_dir) != uid:
                shard_index = shard_index_from_stack(
                    index.config, seg.stack, seg.points, seg.quant,
                    index._perms, index._flips, s,
                )
                save_index_bundle(
                    shard_index, shard_dir, kind=_SEG_SHARD_KIND,
                    extra_arrays={"ids": jnp.asarray(seg.ids_host[s])},
                    extra_meta={
                        "shard": s, "n_shards": s_count,
                        "n_valid": int(seg.n_valid[s]),
                        "segment_uid": uid,
                    },
                )
        seg_entries.append({
            "name": name,
            "gen": int(seg.gen),
            "pad_max": int(seg.pad_max),
            "n_valid": [int(v) for v in seg.n_valid],
        })
    # Sidecar: occupied buffer rows (+ shard assignment), tombstones,
    # values, routing bounds — everything the stacked bundles don't carry.
    # Tombstoned buffer rows are KEPT: load() must reconstruct the exact
    # in-memory slot layout so WAL replay crosses the same flush
    # boundaries the live process did (the bit-equal-recovery invariant).
    state: Dict[str, np.ndarray] = {"alive": index._lsm.alive}
    if index._lsm.values is not None:
        state["values"] = index._lsm.values
    d = index._dim if index._dim is not None else 0
    bsh, bid, bpt = [], [], []
    if index._buf_count is not None:
        for s in range(s_count):
            c = int(index._buf_count[s])
            bsh.append(np.full((c,), s, np.int32))
            bid.append(index._buf_ids[s, :c].copy())
            bpt.append(index._buf_pts[s, :c].copy())
    state["buffer_shard"] = (
        np.concatenate(bsh) if bsh else np.zeros((0,), np.int32)
    )
    state["buffer_ids"] = (
        np.concatenate(bid) if bid else np.zeros((0,), np.int32)
    )
    state["buffer_points"] = (
        np.concatenate(bpt) if bpt else np.zeros((0, d), np.float32)
    )
    if index._bounds is not None:
        state["bounds"] = index._bounds
        state["route_lo"] = np.asarray(index._route_lo, np.float32)
        state["route_hi"] = np.asarray(index._route_hi, np.float32)
    state_dir = os.path.join(path, "state")
    state_step = (checkpoint.latest_step(state_dir) or 0) + 1
    checkpoint.save(state_dir, step=state_step, tree=state, extra={})
    manifest = {
        "kind": kind,
        "format_version": _FORMAT_VERSION,
        "config": index.config.to_dict(),
        "n_shards": s_count,
        "buffer_capacity": index.buffer_capacity,
        "max_segments": index.max_segments,
        "next_id": int(index._lsm.next_id),
        "gen": int(index._gen),
        "dim": index._dim,
        "track_values": index._lsm.track_values,
        "has_bounds": index._bounds is not None,
        "state_step": state_step,
        "segments": seg_entries,
        "extra_meta": extra_meta or {},
    }
    fault_point(
        "sharded_mutable.save.pre_manifest",
        path=os.path.join(path, _MANIFEST),
    )
    checkpoint.atomic_write_json(os.path.join(path, _MANIFEST), manifest)
    keep = {e["name"] for e in manifest["segments"]} | {
        e["name"] for e in prev_manifest.get("segments", [])
    }
    seg_root = os.path.join(path, "segments")
    if os.path.isdir(seg_root):
        for name in os.listdir(seg_root):
            if name.startswith("gen_") and name not in keep:
                shutil.rmtree(os.path.join(seg_root, name),
                              ignore_errors=True)
    checkpoint.prune_steps(
        state_dir, {state_step, prev_manifest.get("state_step")}
    )
    # The manifest is the commit point: every record logged before it is
    # now covered by the checkpoint.  A crash in between just replays the
    # covered tail as no-ops (next_id watermark).
    if index._wal is not None:
        index._wal.truncate()
    return path


def load_sharded_mutable_bundle(
    path: str, *, mesh: Optional[Mesh] = None, kind: str = _DEFAULT_KIND
) -> Tuple[ShardedMutableHilbertIndex, Dict]:
    """Inverse of :func:`save_sharded_mutable_bundle`; returns (index, meta).

    Same-shard-count loads are array-identical round-trips.  A mesh whose
    ``data`` axis differs from the checkpoint's shard count triggers a
    compact-on-load RESHARD (live rows gathered, partition rebuilt at the
    new count, buffered rows folded in).  A directory holding a v3 static
    sharded checkpoint (no v4 manifest) is adopted via
    :meth:`ShardedMutableHilbertIndex.from_sharded` — the format-upgrade
    path.
    """
    if mesh is None:
        from repro.launch.mesh import data_mesh

        mesh = data_mesh()
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.exists(mpath):
        if not os.path.exists(os.path.join(path, _STATIC_MANIFEST)):
            raise FileNotFoundError(
                f"no sharded-mutable (v4) or sharded (v3) manifest under "
                f"{path!r}"
            )
        base = ShardedHilbertIndex.load(path, mesh=mesh)
        index = ShardedMutableHilbertIndex.from_sharded(base)
        _recover_wal(index, path)
        return index, {}
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("kind") != kind:
        raise ValueError(
            f"{path!r} is not a sharded-mutable checkpoint of kind {kind!r} "
            f"(kind={manifest.get('kind')!r})"
        )
    config = IndexConfig.from_dict(manifest["config"])
    target = int(mesh.shape["data"])
    saved = int(manifest["n_shards"])
    state = _restore_state_bundle(
        os.path.join(path, "state"), manifest.get("state_step")
    )

    if target != saved:
        # Compact-on-load reshard: gather live rows, rebuild at the new
        # count (buffered rows fold into the rebuilt base).
        if target == 1:
            raise ValueError(
                "cannot load a sharded-mutable checkpoint onto a 1-device "
                "mesh as ShardedMutableHilbertIndex; use "
                "load_sharded_mutable_as_mutable for the single-device "
                "mutable layout"
            )
        ids, pts = _gather_live_v4(path, manifest, state)
        index = ShardedMutableHilbertIndex(
            config=dataclasses.replace(config, shards=None), mesh=mesh,
            buffer_capacity=int(manifest["buffer_capacity"]),
            max_segments=int(manifest["max_segments"]),
        )
        _restore_lsm(index, manifest, state)
        index._gen = int(manifest["gen"])
        if manifest.get("dim") is not None:
            index._dim = int(manifest["dim"])
            index._alloc_buffers()
        if ids.size:
            base = ShardedHilbertIndex.build(
                jnp.asarray(pts), index.config, mesh=mesh
            )
            index._adopt_base(base, ids)
        _recover_wal(index, path)
        return index, manifest.get("extra_meta", {})

    index = ShardedMutableHilbertIndex(
        config=config, mesh=mesh,
        buffer_capacity=int(manifest["buffer_capacity"]),
        max_segments=int(manifest["max_segments"]),
    )
    _restore_lsm(index, manifest, state)
    index._gen = int(manifest["gen"])
    if manifest.get("dim") is not None:
        index._dim = int(manifest["dim"])
        index._alloc_buffers()
        bsh = np.asarray(state["buffer_shard"], np.int64)
        for i in range(bsh.shape[0]):
            s = int(bsh[i])
            c = int(index._buf_count[s])
            index._buf_pts[s, c] = state["buffer_points"][i]
            index._buf_ids[s, c] = state["buffer_ids"][i]
            index._buf_count[s] = c + 1
    if manifest.get("has_bounds") and "bounds" in state:
        index._bounds = np.asarray(state["bounds"], np.uint32)
        index._route_lo = np.asarray(state["route_lo"], np.float32)
        index._route_hi = np.asarray(state["route_hi"], np.float32)
    repl = NamedSharding(mesh, P())
    for entry in manifest["segments"]:
        loaded = _load_segment_bundles(path, entry, saved)
        shard_indexes = [idx for idx, _ in loaded]
        id_maps = np.stack([ids for _, ids in loaded])
        stack, points = stack_shard_indexes(
            mesh, shard_indexes, id_maps,
            store_points=all(ix.points is not None for ix in shard_indexes),
        )
        index.segments.append(ShardedSegment(
            stack=stack, points=points,
            quant=jax.device_put(shard_indexes[0].quant, repl),
            gen=int(entry["gen"]),
            n_valid=np.asarray(entry["n_valid"], np.int64),
            pad_max=int(entry["pad_max"]),
            ids_host=id_maps,
        ))
        if index._perms is None:
            index._perms = jax.device_put(
                shard_indexes[0].forest.perms, repl
            )
            index._flips = jax.device_put(
                shard_indexes[0].forest.flips, repl
            )
    _recover_wal(index, path)
    return index, manifest.get("extra_meta", {})


def _restore_lsm(index, manifest: Dict,
                 state: Dict[str, np.ndarray]) -> None:
    index._lsm.next_id = int(manifest["next_id"])
    index._lsm.alive = np.asarray(state["alive"], np.bool_)
    index._lsm.track_values = manifest.get("track_values")
    if "values" in state:
        index._lsm.values = state["values"]


def _load_segment_bundles(path: str, entry: Dict, n_shards: int):
    """One v4 generation's per-shard (HilbertIndex, ext-id array) pairs."""
    out = []
    for s in range(n_shards):
        idx, extras, _ = load_index_bundle(
            os.path.join(path, "segments", entry["name"], f"shard_{s:05d}"),
            kind=_SEG_SHARD_KIND,
        )
        out.append((idx, np.asarray(jax.device_get(extras["ids"]),
                                    np.int32)))
    return out


def _gather_live_v4(path: str, manifest: Dict, state: Dict
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Live (ids, points) of a v4 checkpoint, sorted by external id."""
    saved = int(manifest["n_shards"])
    parts_i = [np.asarray(state["buffer_ids"], np.int32)]
    parts_p = [np.asarray(state["buffer_points"], np.float32)]
    for entry in manifest["segments"]:
        for s, (idx, ids) in enumerate(
            _load_segment_bundles(path, entry, saved)
        ):
            if idx.points is None:
                raise ValueError(
                    "cannot reshard a sharded-mutable checkpoint whose "
                    "segments lack stored points (IndexConfig("
                    "store_points=False)); load on a matching mesh instead"
                )
            nv = int(entry["n_valid"][s])
            parts_i.append(ids[:nv])
            parts_p.append(np.asarray(jax.device_get(idx.points))[:nv])
    ids = np.concatenate(parts_i)
    pts = np.concatenate(parts_p)
    live = np.asarray(state["alive"], np.bool_)[ids]
    ids, pts = ids[live], pts[live]
    order = np.argsort(ids, kind="stable")
    return ids[order].astype(np.int32), np.ascontiguousarray(pts[order])


def load_sharded_mutable_as_mutable(path: str, *, kind: str = _DEFAULT_KIND):
    """Degrade a v4 checkpoint onto ONE device: the mutable single-device
    layout, external ids (and values) preserved.

    The reshard-to-one story for serving workers without a mesh: live rows
    gather in external-id order (buffered rows included) and seal as one
    :class:`repro.index.MutableHilbertIndex` segment — a compact-on-load,
    like the multi-device reshard.  Returns that mutable index.
    """
    from repro.index.facade import HilbertIndex
    from repro.index.mutable import MutableHilbertIndex, Segment

    mpath = os.path.join(path, _MANIFEST)
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("kind") != kind:
        raise ValueError(
            f"{path!r} is not a sharded-mutable checkpoint of kind {kind!r} "
            f"(kind={manifest.get('kind')!r})"
        )
    state = _restore_state_bundle(
        os.path.join(path, "state"), manifest.get("state_step")
    )
    ids, pts = _gather_live_v4(path, manifest, state)
    config = dataclasses.replace(
        IndexConfig.from_dict(manifest["config"]), shards=None
    )
    mut = MutableHilbertIndex(
        config, buffer_capacity=int(manifest["buffer_capacity"]),
        max_segments=int(manifest["max_segments"]),
    )
    _restore_lsm(mut, manifest, state)
    if manifest.get("dim") is not None:
        d = int(manifest["dim"])
        mut._dim = d
        mut._buf_points = np.zeros((mut.buffer_capacity, d), np.float32)
        mut._buf_ids = np.full((mut.buffer_capacity,), -1, np.int32)
    if ids.size:
        mut.segments = [Segment(
            index=HilbertIndex.build(jnp.asarray(pts), config),
            ids=ids, gen=0,
        )]
        mut._gen = 1
    # Acknowledged writes survive the degrade-to-one-device path too: the
    # sharded WAL's records are layout-agnostic ops, so they replay into
    # (and re-attach to) the single-device facade directly.
    _recover_wal(mut, path)
    return mut
