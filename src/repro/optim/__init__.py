from repro.optim.adamw import (  # noqa: F401
    OptimizerConfig,
    apply_updates,
    init_opt_state,
    lr_at,
)
