"""AdamW with dtype-configurable moments + error-feedback compression.

No optax dependency — the container ships bare jax.  Distributed-training
knobs:

* ``moment_dtype='bfloat16'`` halves optimizer-state HBM (the lever that
  fits nemotron-4-340b's 4 TB fp32 Adam state into v5e-256; see DESIGN.md).
* ``compression='bf16' | 'topk'`` with **error feedback**: the update is
  quantized/sparsified and the residual is carried to the next step, so the
  DP all-reduce moves 2× / ~20× fewer bytes while convergence is preserved
  (Karimireddy et al., 2019).  On the production mesh the cast happens
  before XLA's gradient reduce-scatter, so the collective itself shrinks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" to halve optimizer HBM
    compression: str = "none"          # none | bf16 | topk
    topk_frac: float = 0.05


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any, cfg: OptimizerConfig) -> Dict[str, Any]:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)  # noqa: E731
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compression in ("bf16", "topk"):
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _compress(cfg: OptimizerConfig, g: jax.Array, err: jax.Array):
    """Error-feedback compression of one gradient leaf."""
    acc = g.astype(jnp.float32) + err
    if cfg.compression == "bf16":
        sent = acc.astype(jnp.bfloat16).astype(jnp.float32)
    else:  # topk by magnitude (per-leaf)
        k = max(1, int(cfg.topk_frac * acc.size))
        flat = acc.reshape(-1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        sent = jnp.where(jnp.abs(acc) >= thresh, acc, 0.0)
    return sent, acc - sent


def apply_updates(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    cfg: OptimizerConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    new_err = state.get("err")
    if cfg.compression in ("bf16", "topk"):
        pairs = jax.tree.map(
            lambda g, e: _compress(cfg, g, e), grads, state["err"]
        )
        grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))

    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mf = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        vf = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if new_err is not None:
        new_state["err"] = new_err
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
