"""Deterministic, resumable synthetic LM data pipeline.

Every batch is a pure function of (seed, step) — counter-based PRNG — so a
restore-from-checkpoint resumes the stream exactly (fault-tolerance test
asserts bit-identical post-restore loss trajectories), and any host can
materialize any shard without coordination (the property that scales the
loader to 1000+ hosts: host h loads rows [h·B/H, (h+1)·B/H) of batch
``step`` directly).

The token stream is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs, giving a learnable distribution (loss decreases measurably
within tens of steps at smoke scale).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 8


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Zipf unigram table + motif bank (shared, tiny)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = (probs / probs.sum()).astype(np.float64)
        self._motifs = rng.integers(
            0, cfg.vocab_size, (cfg.n_motifs, cfg.motif_len), dtype=np.int64
        )

    def batch_at(self, step: int, host: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
        """Materialize (this host's rows of) batch ``step``."""
        cfg = self.cfg
        rows = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host, 0xD1CE])
        )
        toks = rng.choice(
            cfg.vocab_size, size=(rows, cfg.seq_len + 1), p=self._probs
        ).astype(np.int64)
        # overlay motifs: ~25% of positions covered by repeated n-grams
        n_spans = (rows * (cfg.seq_len + 1)) // (4 * cfg.motif_len)
        if n_spans:
            r = rng.integers(0, rows, n_spans)
            c = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len, n_spans)
            m = rng.integers(0, cfg.n_motifs, n_spans)
            for i in range(n_spans):
                toks[r[i], c[i] : c[i] + cfg.motif_len] = self._motifs[m[i]]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((rows, cfg.seq_len), np.float32),
        }

    def jax_batch(self, step: int, extra: Optional[Dict[str, jax.Array]] = None):
        b = {k: jnp.asarray(v) for k, v in self.batch_at(step).items()}
        if extra:
            b.update(extra)
        return b
