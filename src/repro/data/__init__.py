from repro.data import ann_datasets, pipeline  # noqa: F401
