"""Synthetic ANN datasets with exact ground truth.

Stand-ins for PUBMED23 (23M x 384) / GOOAQ (3M x 384) at container scale.
Embedding-like data: clustered unit-norm vectors (text-embedding geometry),
plus an isotropic Gaussian control.  Ground truth is exact brute force,
chunked to bound memory.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "clustered_embeddings",
    "lowrank_embeddings",
    "lowrank_dataset_with_queries",
    "dataset_with_queries",
    "gaussian",
    "exact_knn",
    "exact_knn_graph",
    "recall_at_k",
]


def clustered_embeddings(
    n: int,
    d: int,
    n_clusters: int = 64,
    seed: int = 0,
    noise: float = 0.25,
    decay: float = 0.35,
) -> np.ndarray:
    """Unit-norm clustered vectors with a decaying covariance spectrum.

    Real sentence-embedding sets (PUBMED23/GOOAQ are MiniLM-style vectors)
    concentrate variance in a few tens of principal directions; the power-law
    per-dim scale (``decay``) reproduces that.  Space-filling-curve locality
    depends strongly on this anisotropy — the isotropic control lives in
    :func:`gaussian` (and is the documented worst case for the method).
    """
    rng = np.random.default_rng(seed)
    scale = ((1.0 + np.arange(d)) ** -decay).astype(np.float32)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32) * scale
    assign = rng.integers(0, n_clusters, n)
    x = centers[assign] + noise * rng.normal(size=(n, d)).astype(np.float32) * scale
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return x.astype(np.float32)


def lowrank_embeddings(
    n: int,
    d: int,
    n_clusters: int = 64,
    r: int = 16,
    noise: float = 0.9,
    seed: int = 0,
) -> np.ndarray:
    """Clusters living on low-dimensional local manifolds (intrinsic dim r≪d).

    The realistic proxy for MiniLM-style corpora (PUBMED23/GOOAQ): ambient
    d=384 but local intrinsic dimensionality ~10–30, which gives (a) smooth
    local density with *meaningful distance gaps* between the 30th and 300th
    neighbor (rankable by a 4-bit quantizer) and (b) strong per-dim
    correlation between true neighbors (what space-filling-curve locality
    exploits).  Isotropic full-rank cluster noise has neither — in d=384 all
    within-cluster distances concentrate and recall@30 becomes unresolvable
    for ANY quantized index; see EXPERIMENTS.md §Datasets.

    Resulting stats at n=20k: NN cos ≈ 0.82 (1st) / 0.61 (30th), random-pair
    cos ≈ 0.0 — matching published MiniLM corpus statistics.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.integers(0, n_clusters, n)
    u = rng.normal(size=(n_clusters, d, r)).astype(np.float32)
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    spec = ((1.0 + np.arange(r)) ** -0.5).astype(np.float32)
    z = rng.normal(size=(n, r)).astype(np.float32) * spec
    x = centers[assign] + noise * np.einsum("ndr,nr->nd", u[assign], z)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return x.astype(np.float32)


def lowrank_dataset_with_queries(
    n: int,
    q: int,
    d: int,
    n_clusters: int = 64,
    r: int = 16,
    noise: float = 0.9,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(data, held-out queries), one distribution — the challenge's regime."""
    allpts = lowrank_embeddings(
        n + q, d, n_clusters=n_clusters, r=r, noise=noise, seed=seed
    )
    perm = np.random.default_rng(seed + 0x9E3779B9).permutation(n + q)
    allpts = allpts[perm]
    return allpts[:n], allpts[n:]


def gaussian(n: int, d: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def dataset_with_queries(
    n: int,
    q: int,
    d: int,
    n_clusters: int = 64,
    seed: int = 0,
    noise: float = 0.25,
    decay: float = 0.35,
) -> Tuple[np.ndarray, np.ndarray]:
    """(data, held-out queries) drawn from ONE distribution.

    SISAP challenge queries come from the corpus distribution (PUBMED23
    queries are paper abstracts like the indexed ones); drawing queries from
    *re-generated* cluster centers is an out-of-distribution regime the
    challenge does not test and space-filling-curve locality does not claim.
    """
    allpts = clustered_embeddings(
        n + q, d, n_clusters=n_clusters, seed=seed, noise=noise, decay=decay
    )
    rng = np.random.default_rng(seed + 0x9E3779B9)
    perm = rng.permutation(n + q)
    allpts = allpts[perm]
    return allpts[:n], allpts[n:]


def exact_knn(
    data: np.ndarray, queries: np.ndarray, k: int, chunk: int = 1024
) -> Tuple[np.ndarray, np.ndarray]:
    """Brute-force k-NN (squared L2). Returns (ids (Q,k), dists (Q,k))."""
    data_sq = (data * data).sum(1)
    ids = np.empty((len(queries), k), np.int32)
    dists = np.empty((len(queries), k), np.float32)
    for s in range(0, len(queries), chunk):
        q = queries[s : s + chunk]
        d2 = data_sq[None, :] - 2.0 * (q @ data.T) + (q * q).sum(1)[:, None]
        part = np.argpartition(d2, k - 1, axis=1)[:, :k]
        pd = np.take_along_axis(d2, part, axis=1)
        srt = np.argsort(pd, axis=1)
        ids[s : s + chunk] = np.take_along_axis(part, srt, axis=1)
        dists[s : s + chunk] = np.take_along_axis(pd, srt, axis=1)
    return ids, dists


def exact_knn_graph(data: np.ndarray, k: int, chunk: int = 1024) -> np.ndarray:
    """Exact k-NN graph ids (self excluded)."""
    ids, _ = exact_knn(data, data, k + 1, chunk=chunk)
    out = np.empty((len(data), k), np.int32)
    for i in range(len(data)):
        row = ids[i]
        row = row[row != i][:k]
        out[i] = row
    return out


def recall_at_k(pred_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |pred ∩ true| / k (the challenge's recall metric)."""
    k = true_ids.shape[1]
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(p[:k].tolist()) & set(t.tolist()))
    return hits / (len(true_ids) * k)
