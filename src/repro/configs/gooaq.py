"""GOOAQ / Task-2 graph-construction configs — paper Table 2 verbatim.

3M × 384-dim vectors; challenge limits 16 GB / 8 cores, recall@15 > 0.8,
ranked by construction time.  The paper's submission was the fastest
(74 s at recall 80.5%)."""

from repro.core.types import ForestConfig, GraphParams

N_POINTS = 3_000_000
DIM = 384

FOREST = ForestConfig(bits=4, key_bits=448, leaf_size=100, seed=0)

# Table 2: (time s, recall %) — n, k1, k2
TABLE2 = [
    GraphParams(n_orders=80, k1=96, k2=60, k=15),     # 74 s,  80.5%
    GraphParams(n_orders=112, k1=106, k2=75, k=15),   # 109 s, 85.5%
    GraphParams(n_orders=160, k1=130, k2=100, k=15),  # 164 s, 90.5%
    GraphParams(n_orders=280, k1=168, k2=150, k=15),  # 330 s, 95.5%
    GraphParams(n_orders=720, k1=170, k2=300, k=15),  # 856 s, 98.5%
]
