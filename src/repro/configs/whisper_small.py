"""whisper-small [audio]: enc-dec, conv frontend stubbed to frame embeddings.

12L (dec) d_model=768 12H (kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356].
Adaptations (DESIGN.md): learned positions -> parameter-free sinusoidal so
the assigned 32k decode shapes lower; conv frontend is a stub per the brief
(input_specs supplies 1500 precomputed frame embeddings).
"""

from repro.models.config import ATTN, DENSE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pattern=(LayerSpec(mixer=ATTN, ffn=DENSE, cross_attn=True),),
    act="gelu_plain",
    norm="layernorm",
    is_encdec=True,
    n_enc_layers=12,
    enc_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(mixer=ATTN, ffn=DENSE, cross_attn=True),),
    act="gelu_plain",
    norm="layernorm",
    is_encdec=True,
    n_enc_layers=2,
    enc_frames=16,
)
