"""granite-moe-1b-a400m [moe]: 32 experts top-8. 24L d=1024 16H kv=8
ff=512 (per expert) vocab=49155 [hf:ibm-granite/granite-3.0-1b-a400m-base]."""

from repro.models.config import MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    pattern=(LayerSpec(ffn=MOE),),
    n_experts=32,
    topk_experts=8,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    pattern=(LayerSpec(ffn=MOE),),
    n_experts=8,
    topk_experts=4,
    # drop-free capacity (= E/k): exact train/decode equivalence in tests
    capacity_factor=2.0,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)
