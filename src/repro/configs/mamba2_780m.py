"""mamba2-780m [ssm]: attention-free SSD. 48L d=1536 vocab=50280
ssm_state=128 [arXiv:2405.21060]. d_inner=3072, 48 SSD heads of dim 64."""

from repro.models.config import MAMBA, NONE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,     # placeholders: no attention layers exist in the pattern
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(mixer=MAMBA, ffn=NONE),),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    pattern=(LayerSpec(mixer=MAMBA, ffn=NONE),),
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)
