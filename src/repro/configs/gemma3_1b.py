"""gemma3-1b [dense]: 5 local : 1 global, 26L d=1152 4H kv=1 hd=256
ff=6912 vocab=262144, tied embeddings [hf:google/gemma-3-1b-pt].

Pattern block = 6 layers (5×local(window 512, θ=10k) + 1×global(θ=1M));
26 = 4 blocks + 2 tail local layers.
"""

from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(window=512, rope_theta=10_000.0)
_GLOBAL = LayerSpec(window=0, rope_theta=1_000_000.0)

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke",
    family="dense",
    n_layers=8,  # one full pattern block + 2 tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(
        LayerSpec(window=8, rope_theta=10_000.0),
        LayerSpec(window=8, rope_theta=10_000.0),
        LayerSpec(window=8, rope_theta=10_000.0),
        LayerSpec(window=8, rope_theta=10_000.0),
        LayerSpec(window=8, rope_theta=10_000.0),
        LayerSpec(window=0, rope_theta=1_000_000.0),
    ),
    act="gelu",
    norm="rmsnorm",
    tie_embeddings=True,
)
