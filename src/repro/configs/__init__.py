"""Config registry: 10 assigned architectures + paper dataset configs.

``get_config(name)`` returns the full published config; ``smoke=True``
returns the reduced same-family config used by CPU smoke tests.  The input
shape set is fixed by the assignment (LM shapes: seq_len × global_batch);
``shape_applicable`` encodes the skip rules (long_500k needs sub-quadratic
attention; encoder-only would skip decode — none here are encoder-only).
"""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = (
    "whisper_small",
    "yi_34b",
    "gemma3_1b",
    "nemotron_4_340b",
    "granite_3_8b",
    "jamba_v01_52b",
    "llava_next_34b",
    "mamba2_780m",
    "mixtral_8x22b",
    "granite_moe_1b",
)

# (seq_len, global_batch, kind): kind "train" lowers train_step,
# "prefill" lowers prefill, "decode" lowers serve_step with a seq_len cache.
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    key = name.replace("-", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.SMOKE if smoke else mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's rules."""
    if shape == "long_500k":
        has_window = any(s.window > 0 for s in cfg.pattern)
        has_ssm = any(s.mixer == "mamba" for s in cfg.pattern)
        if not (has_window or has_ssm):
            return False, (
                "pure full-attention arch: 512k decode needs sub-quadratic "
                "attention / bounded KV (see DESIGN.md §Arch-applicability)"
            )
    return True, ""
