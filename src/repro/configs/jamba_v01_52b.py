"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE every 2nd
layer. 32L d=4096 32H kv=8 ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887].

Pattern block = 8 layers: position 0 is attention, 1-7 mamba; MoE FFN at odd
positions (4 per block -> 16 MoE layers of 32, the paper's every-2nd-layer).
Adaptation (DESIGN.md): Jamba ships Mamba-1 scans; we implement the Mamba-2
SSD dual (chunked matmul form) — the TPU-native equivalent — keeping the
published state size (N=16) and d_inner=2·d_model.
"""

from repro.models.config import ATTN, DENSE, MAMBA, MOE, LayerSpec, ModelConfig

_P = (
    LayerSpec(mixer=ATTN, ffn=DENSE),
    LayerSpec(mixer=MAMBA, ffn=MOE),
    LayerSpec(mixer=MAMBA, ffn=DENSE),
    LayerSpec(mixer=MAMBA, ffn=MOE),
    LayerSpec(mixer=MAMBA, ffn=DENSE),
    LayerSpec(mixer=MAMBA, ffn=MOE),
    LayerSpec(mixer=MAMBA, ffn=DENSE),
    LayerSpec(mixer=MAMBA, ffn=MOE),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pattern=_P,
    n_experts=16,
    topk_experts=2,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    act="silu",
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    n_layers=8,  # one pattern block
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=_P,
    n_experts=4,
    topk_experts=2,
    # drop-free capacity (= E/k): exact train/decode equivalence in tests
    capacity_factor=2.0,
    ssm_state=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    act="silu",
    norm="rmsnorm",
)
