"""yi-34b [dense]: llama-arch GQA. 60L d=7168 56H kv=8 ff=20480 v=64000."""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    pattern=(LayerSpec(rope_theta=5_000_000.0),),
    act="silu",
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="yi-34b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(rope_theta=5_000_000.0),),
    act="silu",
    norm="rmsnorm",
)
