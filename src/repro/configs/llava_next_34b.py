"""llava-next-34b [vlm]: yi-34b backbone + anyres patch embeddings (stub).

60L d=7168 56H kv=8 ff=20480 v=64000; the vision tower is a STUB per the
brief — input_specs() supplies 2880 precomputed patch embeddings (anyres:
base 576 + 4 tiles × 576) which replace the first 2880 token positions.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    pattern=(LayerSpec(rope_theta=5_000_000.0),),
    act="silu",
    norm="rmsnorm",
    n_patches=2880,
    patch_dim=1024,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(rope_theta=5_000_000.0),),
    act="silu",
    norm="rmsnorm",
    n_patches=8,
    patch_dim=32,
)
