"""PUBMED23 / Task-1 index configs — the paper's own hyperparameters.

23M × 384-dim fp32 vectors (36 GB raw); challenge limits: 16 GB RAM,
8 cores, recall@30 > 0.7.  Table 1 settings reproduced verbatim; at
container scale the same structures run at reduced N (see
benchmarks/task1_table1.py), at paper scale they are exercised shape-only.
"""

from repro.core.types import ForestConfig, QuantizerConfig, SearchParams

N_POINTS = 23_000_000
DIM = 384

# Index (paper §3.1): ≤160 trees, ~100-point leaves, 384-bit sketches,
# 4-bit codes sharing the MSB plane with the sketch.
FOREST = ForestConfig(n_trees=160, bits=4, key_bits=448, leaf_size=100, seed=0)
QUANT = QuantizerConfig(bits=4)

# Table 1 rows (n, k1, k2, h) — the 16 submitted hyperparameter combos.
TABLE1 = [
    SearchParams(k1=1420, k2=370, h=2, k=30),   # n=160: recall 72.9%
    SearchParams(k1=1420, k2=360, h=2, k=30),
    SearchParams(k1=1300, k2=350, h=2, k=30),
    SearchParams(k1=1300, k2=340, h=2, k=30),
    SearchParams(k1=1200, k2=330, h=2, k=30),
    SearchParams(k1=1200, k2=320, h=2, k=30),
    SearchParams(k1=1100, k2=310, h=2, k=30),
    SearchParams(k1=1100, k2=300, h=2, k=30),
    SearchParams(k1=4000, k2=1000, h=2, k=30),  # n=120: recall 79.1%
    SearchParams(k1=3200, k2=1000, h=2, k=30),
    SearchParams(k1=2800, k2=1000, h=2, k=30),
    SearchParams(k1=2400, k2=1000, h=2, k=30),
    SearchParams(k1=2000, k2=1000, h=2, k=30),
    SearchParams(k1=1800, k2=1000, h=2, k=30),
    SearchParams(k1=1600, k2=1000, h=2, k=30),
    SearchParams(k1=1600, k2=800, h=2, k=30),
]
TABLE1_TREES = [160] * 8 + [120] * 8

# RAM budget (paper §3.1): ~76 MB/tree × ≤160 trees + ~1.1 GB sketches +
# 4-bit codes with one bitplane shared => ~4.5 GB stage-2.
#
# Our "tree" = per-tree point order + rank directory.  With 32-bit ids the
# order alone is 92 MB; the paper's 76 MB implies ⌈log2 23M⌉ = 25-bit
# packed ids (23M·25/8 = 72 MB + directory ≈ 76 MB) — the budget below
# models the packed production layout (compute paths use int32 in RAM).
def memory_budget_bytes(n_trees: int = 160) -> dict:
    id_bits = max(1, (N_POINTS - 1).bit_length())            # 25
    order = N_POINTS * id_bits // 8                           # 72 MB
    directory = N_POINTS // FOREST.leaf_size * (FOREST.key_bits // 8)
    sketches = N_POINTS * DIM // 8                            # 1.10 GB
    codes = N_POINTS * DIM // 2                               # 4.42 GB
    shared = N_POINTS * DIM // 8                              # MSB plane
    return {
        "per_tree": order + directory,
        "forest": n_trees * (order + directory),
        "sketches": sketches,
        "codes": codes,
        "stage2_combined": sketches + codes - shared,
    }
