"""nemotron-4-340b [dense]: GQA + squared-ReLU MLP, layernorm.
96L d=18432 96H kv=8 hd=192 ff=73728 vocab=256000 [arXiv:2402.16819]."""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    pattern=(LayerSpec(),),
    act="relu2",
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=256,
    vocab_size=512,
    pattern=(LayerSpec(),),
    act="relu2",
    norm="layernorm",
)
