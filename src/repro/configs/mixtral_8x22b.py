"""mixtral-8x22b [moe]: 8 experts top-2, SWA. 56L d=6144 48H kv=8
ff=16384 (per expert) vocab=32768 [arXiv:2401.04088]; window 4096 per the
assignment."""

from repro.models.config import MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec(ffn=MOE, window=4096, rope_theta=1_000_000.0),),
    n_experts=8,
    topk_experts=2,
    act="silu",
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    pattern=(LayerSpec(ffn=MOE, window=8, rope_theta=1_000_000.0),),
    n_experts=4,
    topk_experts=2,
    # drop-free capacity (= E/k): exact train/decode equivalence in tests
    capacity_factor=2.0,
    act="silu",
    norm="rmsnorm",
)
