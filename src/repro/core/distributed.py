"""Distributed Hilbert sort + k-NN-graph construction (1000+-node posture).

At cluster scale a single-host Hilbert sort is impossible; we implement the
paper's ordering as a **sample sort** over the mesh's 'data' axis inside
``shard_map``:

  local key-gen → local sort → all-gather splitter samples → bucket →
  ``all_to_all`` exchange (keys travel WITH their payload: global ids +
  sketches, so stage-2 filtering needs no cross-shard gathers) →
  local merge.

Every shard ends with a *padded* slice of the global Hilbert order (valid
prefix + sentinel tail; sample-sort imbalance is bounded by the oversample
rate, and overflow — dropped points — is returned as a counter that MUST be
zero in production, asserted in tests).

Task-2 neighbor windows cross shard boundaries via a ±k₁ **halo exchange**
(``lax.ppermute`` of each shard's valid edge rows), making the paper's
"extract k₁ neighbors around position i" boundary-correct at any device
count.  Candidates are routed back to their home shard (gid // local_n)
with a second all_to_all, where the running sketch-filtered top-k₂ merge is
the same associative merge the single-device path uses.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import hilbert, quantize, sketch
from repro.core.types import ForestConfig, GraphParams

_MAXU = jnp.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Sample sort (shard_map core)
# ---------------------------------------------------------------------------


def _local_lexsort(keys: jax.Array) -> jax.Array:
    w = keys.shape[1]
    return jnp.lexsort(tuple(keys[:, i] for i in range(w - 1, -1, -1)))


def _bucket_of(splitters: jax.Array, keys_sorted: jax.Array) -> jax.Array:
    """splitters (p-1, W); sorted keys (n, W) -> bucket ids in [0, p)."""
    n = keys_sorted.shape[0]
    m = splitters.shape[0]
    steps = max(1, int(np.ceil(np.log2(m + 1))))

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        midk = splitters[mid]
        go_right = ~hilbert.lex_less(keys_sorted, midk)  # key >= splitter
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo = jnp.zeros((n,), jnp.int32)
    hi = jnp.full((n,), m, jnp.int32)
    lo, _ = lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def sample_sort_sharded(
    keys: jax.Array,              # (N, W) uint32, sharded over axis dim 0
    payload: Dict[str, jax.Array],  # each (N, ...), same sharding
    mesh: Mesh,
    axis: str = "data",
    oversample: int = 64,
    cap_factor: float = 2.0,
):
    """Returns (keys_out (N·cf? padded per shard), payload_out, n_valid, overflow).

    Output arrays have per-shard length ``cap_total = cap_factor · N/p``;
    rows ≥ n_valid[shard] are sentinels.  Concatenating the valid prefixes
    over shards yields the globally sorted sequence.
    """
    p = mesh.shape[axis]
    n, w = keys.shape
    local_n = n // p
    cap = max(8, int(cap_factor * local_n / p))  # per (src,dst) bucket slots
    cap_total = cap * p

    def shard_fn(keys_l, *payload_l):
        names = list(payload.keys())
        payload_d = dict(zip(names, payload_l))
        ln = keys_l.shape[0]

        order = _local_lexsort(keys_l)
        keys_s = keys_l[order]
        pay_s = {k: v[order] for k, v in payload_d.items()}

        # --- splitters from an all-gathered sample ---
        s = min(oversample, ln)
        samp_idx = (jnp.arange(s) * (ln // s)).astype(jnp.int32)
        cand = keys_s[samp_idx]                       # (s, W)
        allc = lax.all_gather(cand, axis)             # (p, s, W)
        flat = allc.reshape(p * s, w)
        flat = flat[_local_lexsort(flat)]
        split_idx = (jnp.arange(1, p) * s).astype(jnp.int32)
        splitters = flat[split_idx - 1]               # (p-1, W)

        bucket = _bucket_of(splitters, keys_s)        # (ln,) nondecreasing
        counts = jnp.sum(jax.nn.one_hot(bucket, p, dtype=jnp.int32), axis=0)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(ln, dtype=jnp.int32) - offsets[bucket]
        valid = pos < cap
        overflow = jnp.sum(~valid).astype(jnp.int32)
        slot = jnp.where(valid, bucket * cap + pos, p * cap)

        send_keys = jnp.full((p * cap + 1, w), _MAXU, jnp.uint32)
        send_keys = send_keys.at[slot].set(keys_s)[: p * cap]
        recv_keys = lax.all_to_all(
            send_keys.reshape(p, cap, w), axis, split_axis=0, concat_axis=0,
            tiled=False,
        ).reshape(p * cap, w)

        recv_pay = {}
        for kname, v in pay_s.items():
            fill = (
                jnp.zeros((p * cap + 1,) + v.shape[1:], v.dtype)
                if jnp.issubdtype(v.dtype, jnp.floating)
                else jnp.full((p * cap + 1,) + v.shape[1:], -1, v.dtype)
            )
            sv = fill.at[slot].set(v)[: p * cap]
            rv = lax.all_to_all(
                sv.reshape((p, cap) + v.shape[1:]), axis, split_axis=0,
                concat_axis=0, tiled=False,
            ).reshape((p * cap,) + v.shape[1:])
            recv_pay[kname] = rv

        # --- local merge; sentinels (MAXU keys) sort to the tail ---
        morder = _local_lexsort(recv_keys)
        keys_o = recv_keys[morder]
        pay_o = {k: v[morder] for k, v in recv_pay.items()}
        is_valid = ~jnp.all(keys_o == _MAXU, axis=1)
        n_valid = jnp.sum(is_valid).astype(jnp.int32)
        out = [keys_o] + [pay_o[k] for k in names]
        return (*out, n_valid[None], overflow[None])

    in_specs = (P(axis),) + tuple(P(axis) for _ in payload)
    out_specs = (
        (P(axis),) + tuple(P(axis) for _ in payload) + (P(axis), P(axis))
    )
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    outs = fn(keys, *payload.values())
    keys_out = outs[0]
    pay_out = dict(zip(payload.keys(), outs[1 : 1 + len(payload)]))
    n_valid = outs[-2]
    overflow = outs[-1]
    return keys_out, pay_out, n_valid, overflow


# ---------------------------------------------------------------------------
# Distributed Hilbert order
# ---------------------------------------------------------------------------


def distributed_hilbert_order(
    points: jax.Array,            # (N, d) sharded over 'data'
    mesh: Mesh,
    cfg: ForestConfig,
    lo: jax.Array,
    hi: jax.Array,
    perm: Optional[jax.Array] = None,
    flip: Optional[jax.Array] = None,
    payload: Optional[Dict[str, jax.Array]] = None,
    axis: str = "data",
    cap_factor: float = 2.0,
):
    """Global Hilbert ordering of sharded points (+payload), sample-sorted."""
    n = points.shape[0]
    keys = hilbert.hilbert_keys(
        points, bits=cfg.bits, key_bits=cfg.key_bits, lo=lo, hi=hi,
        perm=perm, flip=flip,
    )
    gids = jnp.arange(n, dtype=jnp.int32)
    pay = {"gid": gids}
    if payload:
        pay.update(payload)
    return sample_sort_sharded(keys, pay, mesh, axis=axis, cap_factor=cap_factor)


def hilbert_partition(
    points: jax.Array,            # (n, d) host or device array
    cfg: ForestConfig,
    mesh: Optional[Mesh] = None,
    n_shards: Optional[int] = None,
    axis: str = "data",
) -> list:
    """Row-partition ``points`` into contiguous runs of the master Hilbert order.

    The layout primitive of :class:`repro.index.sharded.ShardedHilbertIndex`:
    each returned ``np.ndarray`` of global row ids is one shard's residency
    set, and concatenating them walks the (un-permuted) master Hilbert curve
    — so every shard's rows are a locality-tight curve segment and a
    per-shard top-k merge loses as little recall as the curve allows
    (the hyperorthogonal well-folded ordering argument).

    Multi-device meshes compute the order with the sample sort above
    (each device keys+sorts only its slice); when the mesh is trivial or
    ``n`` is not divisible by the device count (the sample sort's shard_map
    needs equal input slices) it falls back to the single-device sort —
    same keys, same order up to equal-key ties.

    Returns ``n_shards`` id arrays of length ``ceil(n / n_shards)`` (the
    last may be shorter; shards past the data are empty arrays).
    """
    from repro.launch.mesh import data_mesh

    if mesh is None:
        mesh = data_mesh()
    p = mesh.shape[axis]
    if n_shards is None:
        n_shards = p
    n = points.shape[0]
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    order = None
    if p > 1 and n % p == 0:
        pts_sh = jax.device_put(points, NamedSharding(mesh, P(axis, None)))
        keys_o, pay_o, n_valid, ovf = distributed_hilbert_order(
            pts_sh, mesh, cfg, lo, hi, axis=axis
        )
        if int(jnp.sum(ovf)) == 0:
            nv = np.asarray(n_valid)
            gids = np.asarray(pay_o["gid"]).reshape(p, -1)
            order = np.concatenate([gids[r, : nv[r]] for r in range(p)])
        # overflow (bounded-capacity bucket spill) would drop rows; fall
        # back to the exact single-device sort rather than lose points.
    if order is None:
        from repro.core.search import hilbert_master_sort

        order, _ = hilbert_master_sort(jnp.asarray(points), cfg, lo, hi)
        order = np.asarray(order)
    per = -(-n // n_shards)
    return [order[s * per : (s + 1) * per] for s in range(n_shards)]


# ---------------------------------------------------------------------------
# Partition bounds + insert routing (the sharded-mutable write path)
# ---------------------------------------------------------------------------


_MAX_KEY_FILL = np.uint32(0xFFFFFFFF)


def _np_lex_ge(keys: np.ndarray, bound: np.ndarray) -> np.ndarray:
    """Vectorized lexicographic ``keys[i] >= bound`` over (m, W) uint32 rows."""
    m = keys.shape[0]
    result = np.zeros((m,), np.bool_)
    decided = np.zeros((m,), np.bool_)
    for w in range(keys.shape[1]):
        gt = ~decided & (keys[:, w] > bound[w])
        lt = ~decided & (keys[:, w] < bound[w])
        result |= gt
        decided |= gt | lt
    result |= ~decided  # all words equal -> key == bound -> ge
    return result


def curve_partition_bounds(
    first_points: list,            # per shard: (d,) np array or None (empty)
    cfg: ForestConfig,
    lo: np.ndarray,
    hi: np.ndarray,
) -> np.ndarray:
    """Master-curve boundary keys of a contiguous Hilbert partition.

    ``first_points[s]`` is the first row (in master-curve order) that shard
    ``s`` owns, or ``None`` for an empty shard.  Returns ``(S-1, W)`` uint32
    where row ``s-1`` is shard ``s``'s opening key; empty shards get the
    all-ones MAX key so :func:`route_to_shards` never routes new rows to
    them (the curve ran out of data before reaching their range).  Keys are
    computed with the *global* ``lo``/``hi`` bounds the partition itself
    used, so routing agrees with :func:`hilbert_partition` up to equal-key
    ties.
    """
    from repro.core import hilbert as hilbert_lib

    n_shards = len(first_points)
    w = hilbert_lib.key_words(cfg.key_bits)
    bounds = np.full((max(n_shards - 1, 0), w), _MAX_KEY_FILL, np.uint32)
    present = [s for s in range(1, n_shards) if first_points[s] is not None]
    if present:
        pts = jnp.asarray(np.stack([first_points[s] for s in present]))
        keys = np.asarray(hilbert_lib.hilbert_keys(
            pts, bits=cfg.bits, key_bits=cfg.key_bits,
            lo=jnp.asarray(lo), hi=jnp.asarray(hi),
        ))
        for row, s in enumerate(present):
            bounds[s - 1] = keys[row]
    return bounds


def route_to_shards(
    points: np.ndarray,            # (m, d)
    cfg: ForestConfig,
    lo: np.ndarray,
    hi: np.ndarray,
    bounds: np.ndarray,            # (S-1, W) from curve_partition_bounds
) -> np.ndarray:
    """Route rows to the shard owning their master-curve range.

    Returns ``(m,)`` int32 shard indices: ``sum_s [key >= bounds[s]]`` — a
    lexicographic searchsorted against the partition's opening keys.  Points
    outside the frozen ``lo``/``hi`` box clamp to the box edge (same
    behavior as the curve quantization itself), so routing is total.
    """
    from repro.core import hilbert as hilbert_lib

    if points.shape[0] == 0:
        return np.zeros((0,), np.int32)
    keys = np.asarray(hilbert_lib.hilbert_keys(
        jnp.asarray(points, jnp.float32), bits=cfg.bits,
        key_bits=cfg.key_bits, lo=jnp.asarray(lo), hi=jnp.asarray(hi),
    ))
    shard = np.zeros((points.shape[0],), np.int32)
    for b in bounds:
        shard += _np_lex_ge(keys, b).astype(np.int32)
    return shard


# ---------------------------------------------------------------------------
# Halo windows (Task-2 stage 1, boundary-correct)
# ---------------------------------------------------------------------------


def halo_window_candidates(
    gids_sorted: jax.Array,       # (N_pad,) int32 sharded; -1 = sentinel
    sketches_sorted: jax.Array,   # (N_pad, Ws) uint32 sharded (same order)
    n_valid: jax.Array,           # (p,) int32 sharded (1 per shard)
    mesh: Mesh,
    k1: int,
    axis: str = "data",
):
    """Per resident point: (k1 candidate gids, k1 hamming dists), windows
    crossing shard edges via ppermute halo of each shard's valid edges."""
    p = mesh.shape[axis]
    half = k1 // 2

    def shard_fn(gids_l, sk_l, nv):
        ln = gids_l.shape[0]
        nv = nv[0]
        rank = lax.axis_index(axis)

        # halo: send my first/last `half` VALID rows to prev/next shard
        first_g = lax.dynamic_slice_in_dim(gids_l, 0, half)
        first_s = lax.dynamic_slice_in_dim(sk_l, 0, half)
        start = jnp.maximum(nv - half, 0)
        last_g = jnp.take(gids_l, start + jnp.arange(half), axis=0,
                          mode="clip")
        last_s = jnp.take(sk_l, start + jnp.arange(half), axis=0, mode="clip")
        # mask tail halo rows beyond nv
        tail_valid = (start + jnp.arange(half)) < nv
        last_g = jnp.where(tail_valid, last_g, -1)

        fwd = [(i, (i + 1) % p) for i in range(p)]
        bwd = [(i, (i - 1) % p) for i in range(p)]
        from_prev_g = lax.ppermute(last_g, axis, fwd)    # prev shard's tail
        from_prev_s = lax.ppermute(last_s, axis, fwd)
        from_next_g = lax.ppermute(first_g, axis, bwd)   # next shard's head
        from_next_s = lax.ppermute(first_s, axis, bwd)
        # ring wrap: rank 0 has no prev, rank p-1 no next
        from_prev_g = jnp.where(rank == 0, -1, from_prev_g)
        from_next_g = jnp.where(rank == p - 1, -1, from_next_g)

        # ext layout: [prev-halo | local rows | half sentinel slots]; the
        # next-shard halo is spliced in right AFTER the valid prefix (at
        # ext index half+nv) so windows at the boundary see true neighbors,
        # not sentinel padding.
        ext_g = jnp.concatenate(
            [from_prev_g, gids_l, jnp.full((half,), -1, gids_l.dtype)]
        )
        ext_s = jnp.concatenate(
            [from_prev_s, sk_l, jnp.zeros((half,) + sk_l.shape[1:], sk_l.dtype)]
        )
        ext_g = lax.dynamic_update_slice_in_dim(ext_g, from_next_g, half + nv, 0)
        ext_s = lax.dynamic_update_slice_in_dim(ext_s, from_next_s, half + nv, 0)
        # resident row j lives at ext position j + half; window is
        # [j+half-half, j+half+half] minus self.
        deltas = jnp.concatenate([
            jnp.arange(-half, 0, dtype=jnp.int32),
            jnp.arange(1, k1 - half + 1, dtype=jnp.int32),
        ])
        pos = jnp.arange(ln, dtype=jnp.int32)[:, None] + half + deltas[None, :]
        pos = jnp.clip(pos, 0, ln + 2 * half - 1)
        cand_g = jnp.take(ext_g, pos, axis=0, mode="clip")      # (ln, k1)
        cand_s = jnp.take(ext_s, pos, axis=0, mode="clip")      # (ln, k1, Ws)
        # candidates beyond this shard's valid region point at sentinel rows
        row_ok = (jnp.arange(ln, dtype=jnp.int32) < nv)[:, None]
        cand_g = jnp.where(row_ok & (cand_g >= 0), cand_g, -1)

        hd = sketch.hamming_distance(sk_l[:, None, :], cand_s)   # (ln, k1)
        hd = jnp.where(cand_g >= 0, hd, jnp.int32(2**30))
        self_mask = cand_g == gids_l[:, None]
        hd = jnp.where(self_mask, jnp.int32(2**30), hd)
        return cand_g, hd

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )
    return fn(gids_sorted, sketches_sorted, n_valid)


# ---------------------------------------------------------------------------
# Route results home + merge (Task-2 main loop)
# ---------------------------------------------------------------------------


def route_home(
    owner_gid: jax.Array,   # (N_pad,) sharded; -1 sentinel
    cand_g: jax.Array,      # (N_pad, k1)
    cand_d: jax.Array,      # (N_pad, k1)
    mesh: Mesh,
    n_points: int,
    axis: str = "data",
    cap_factor: float = 1.5,
):
    """all_to_all candidates to gid's home shard; returns them in home-local
    gid order: (cands (local_n, k1), dists (local_n, k1)) per shard."""
    p = mesh.shape[axis]
    local_n = n_points // p
    k1 = cand_g.shape[1]
    cap = max(8, int(cap_factor * local_n / p))

    def shard_fn(og, cg, cd):
        ln = og.shape[0]
        home = jnp.where(og >= 0, og // local_n, p)      # (ln,)
        # positions within each destination bucket
        onehot = jax.nn.one_hot(jnp.clip(home, 0, p - 1), p, dtype=jnp.int32)
        onehot = jnp.where((og >= 0)[:, None], onehot, 0)
        run = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.sum(run * onehot, axis=1)
        valid = (og >= 0) & (pos < cap)
        overflow = jnp.sum((og >= 0) & (pos >= cap)).astype(jnp.int32)
        slot = jnp.where(valid, home * cap + pos, p * cap)

        sg = jnp.full((p * cap + 1,), -1, jnp.int32).at[slot].set(og)[: p * cap]
        scg = jnp.full((p * cap + 1, k1), -1, jnp.int32).at[slot].set(cg)[: p * cap]
        scd = jnp.full((p * cap + 1, k1), 2**30, jnp.int32).at[slot].set(cd)[: p * cap]

        rg = lax.all_to_all(sg.reshape(p, cap), axis, 0, 0, tiled=False).reshape(-1)
        rcg = lax.all_to_all(scg.reshape(p, cap, k1), axis, 0, 0, tiled=False).reshape(-1, k1)
        rcd = lax.all_to_all(scd.reshape(p, cap, k1), axis, 0, 0, tiled=False).reshape(-1, k1)

        # scatter into local gid order
        rank = lax.axis_index(axis)
        local_gid = jnp.where(rg >= 0, rg - rank * local_n, local_n)
        out_c = jnp.full((local_n + 1, k1), -1, jnp.int32).at[local_gid].set(rcg)[:local_n]
        out_d = jnp.full((local_n + 1, k1), 2**30, jnp.int32).at[local_gid].set(rcd)[:local_n]
        return out_c, out_d, overflow[None]

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
        check_rep=False,
    )
    return fn(owner_gid, cand_g, cand_d)


def distributed_knn_graph(
    points: jax.Array,            # (N, d) — device_put sharded over 'data'
    params: GraphParams,
    forest_cfg: ForestConfig,
    mesh: Mesh,
    axis: str = "data",
) -> Tuple[jax.Array, jax.Array, int]:
    """Multi-node Task 2.  Returns (ids (N,k), d² (N,k), total_overflow).

    Quantized codes are REPLICATED for the final ADC ranking (the paper's
    4-bit codes: 23M×384 = 4.4 GB — replicable at any scale); vectors,
    sketches and all sort traffic stay sharded.
    """
    n, d = points.shape
    quant = quantize.fit(points, bits=4)
    codes = quantize.encode(quant, points)
    sks = sketch.sketches_from_codes(codes, bits=4)
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)

    data_sh = NamedSharding(mesh, P(axis))
    points = jax.device_put(points, NamedSharding(mesh, P(axis, None)))
    sks = jax.device_put(sks, NamedSharding(mesh, P(axis, None)))

    rng = np.random.default_rng(params.seed)
    best_id = jax.device_put(
        jnp.full((n, params.k2), -1, jnp.int32), NamedSharding(mesh, P(axis, None))
    )
    best_d = jax.device_put(
        jnp.full((n, params.k2), 2**30, jnp.int32), NamedSharding(mesh, P(axis, None))
    )
    total_overflow = 0
    for _ in range(params.n_orders):
        perm = jnp.asarray(rng.permutation(d).astype(np.int32))
        flip = jnp.asarray(rng.integers(0, 2, d).astype(bool))
        keys_o, pay_o, n_valid, ovf1 = distributed_hilbert_order(
            points, mesh, forest_cfg, lo, hi, perm, flip,
            payload={"sk": sks}, axis=axis,
        )
        cand_g, cand_d = halo_window_candidates(
            pay_o["gid"], pay_o["sk"], n_valid, mesh, params.k1, axis=axis
        )
        home_c, home_d, ovf2 = route_home(
            pay_o["gid"], cand_g, cand_d, mesh, n, axis=axis
        )
        best_id, best_d = _merge_sharded(best_id, best_d, home_c, home_d, params.k2)
        total_overflow += int(jnp.sum(ovf1)) + int(jnp.sum(ovf2))

    # final: exact ADC ranking against replicated codes
    ids, dists = _final_adc(points, best_id, quant, codes, params.k)
    return ids, dists, total_overflow


@functools.partial(jax.jit, static_argnames=("k2",))
def _merge_sharded(best_id, best_d, new_id, new_d, k2: int):
    ids = jnp.concatenate([best_id, new_id], axis=1)
    ds = jnp.concatenate([best_d, new_d], axis=1)
    sort_idx = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, sort_idx, axis=1)
    ds_s = jnp.take_along_axis(ds, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[:, :1], bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1
    )
    ds_s = jnp.where(dup | (ids_s < 0), jnp.int32(2**30), ds_s)
    neg, idx = lax.top_k(-ds_s, k2)
    return jnp.take_along_axis(ids_s, idx, axis=1), -neg


@functools.partial(jax.jit, static_argnames=("k",))
def _final_adc(points, best_id, quant, codes, k: int):
    cand_codes = jnp.take(codes, jnp.maximum(best_id, 0), axis=0)  # (N,k2,d)
    d2 = quantize.adc_distance(quant, points, cand_codes)
    n = points.shape[0]
    d2 = jnp.where(best_id < 0, jnp.inf, d2)
    d2 = jnp.where(best_id == jnp.arange(n, dtype=jnp.int32)[:, None], jnp.inf, d2)
    neg, idx = lax.top_k(-d2, k)
    return jnp.take_along_axis(best_id, idx, axis=1), -neg


# ---------------------------------------------------------------------------
# Cross-shard top-k merge (the sharded facades' reduction tail)
# ---------------------------------------------------------------------------


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def resolve_merge(merge: str, n_shards: int) -> str:
    """Resolve a ``merge`` knob ("auto" | "gather" | "tree") to a concrete path.

    ``"auto"`` picks the butterfly tree when the shard count is a power of
    two (its XOR-partner schedule needs one) and the flat gather otherwise;
    an *explicit* ``"tree"`` on a non-pow2 shard count is a caller error and
    raises rather than silently degrading.
    """
    if merge not in ("auto", "gather", "tree"):
        raise ValueError(f"merge={merge!r}: expected 'auto', 'gather' or 'tree'")
    if merge == "auto":
        return "tree" if is_pow2(n_shards) else "gather"
    if merge == "tree" and not is_pow2(n_shards):
        raise ValueError(
            f"merge='tree' needs a power-of-two shard count, got {n_shards}; "
            "use merge='auto' to fall back to 'gather'"
        )
    return merge


def tree_merge_topk(ids, d2, *, k: int, axis: str, axis_size: int,
                    prune: bool = False):
    """Butterfly all-reduce of :func:`repro.core.search.merge_topk`.

    Runs INSIDE a shard_map body.  Each rank first deflates its local
    candidate pool (Q, C) — however inflated by padding/tombstone slack —
    to a true local top-k, then performs log2(S) ``lax.ppermute`` hops on
    the XOR-partner (recursive-doubling) schedule: at step ``s`` rank
    ``r`` exchanges its running (Q, k) partial with rank ``r ^ s`` and
    merges.  Interconnect traffic is k rows per query per hop instead of
    the gather path's (S-1)·C rows, and the flat merge over an S·C pool
    is replaced by log2(S) merges over 2k pools.

    Determinism: both members of a pair merge the SAME concatenation —
    the lower rank's block first (``merge_topk_pair`` keyed on
    ``(rank & s) == 0``) — so by induction every rank holds bit-identical
    partials after every hop, and the final (Q, k) is safe to declare
    replicated (``out_specs P(None)``) even with ``check_rep=False``.

    ``prune=True`` adds one ``lax.pmin`` of each rank's local kth-best
    distance before the first hop and masks local candidates strictly
    worse than that global bound λ.  Exact: some rank holds k distinct
    ids at distance ≤ λ, so a candidate with d > λ can never enter the
    global top-k, and survivors' tie order is untouched — results stay
    bit-equal, ids included.

    Requires ``axis_size`` to be a power of two (checked by
    :func:`resolve_merge` before tracing).
    """
    from repro.core import search as search_lib

    ids_k, d_k = search_lib.merge_topk(ids, d2, k=k)  # shard-local deflation
    if axis_size == 1:
        return ids_k, d_k
    rank = lax.axis_index(axis)
    if prune:
        lam = lax.pmin(d_k[:, -1], axis)
        keep = d_k <= lam[:, None]
        ids_k = jnp.where(keep, ids_k, -1)
        d_k = jnp.where(keep, d_k, jnp.inf)
    step = 1
    while step < axis_size:
        perm = [(r, r ^ step) for r in range(axis_size)]
        other_ids = lax.ppermute(ids_k, axis, perm)
        other_d = lax.ppermute(d_k, axis, perm)
        first = (rank & step) == 0
        ids_k, d_k = search_lib.merge_topk_pair(
            ids_k, d_k, other_ids, other_d, first, k=k
        )
        step *= 2
    return ids_k, d_k


def gather_merge_topk(ids, d2, *, k: int, axis: str):
    """Flat reference reduction: all_gather every rank's pool, merge once.

    The pre-tree behavior, kept bit-exact as ``merge="gather"`` — the
    parity baseline the tree path is asserted against in
    ``scripts/sharded_check.py``.  Per device it moves (S-1)·C candidate
    rows per query and flat-merges an S·C pool.
    """
    from repro.core import search as search_lib

    all_ids = lax.all_gather(ids, axis)  # (S, Q, C)
    all_d = lax.all_gather(d2, axis)
    qn = ids.shape[0]
    pool = all_ids.shape[0] * all_ids.shape[2]
    merged_ids = jnp.moveaxis(all_ids, 0, 1).reshape(qn, pool)
    merged_d = jnp.moveaxis(all_d, 0, 1).reshape(qn, pool)
    return search_lib.merge_topk(merged_ids, merged_d, k=k)


def cross_shard_merge_topk(ids, d2, *, k: int, axis: str, axis_size: int,
                           merge: str, prune: bool = False):
    """The one cross-shard merge tail shared by both sharded facades.

    Called inside the shard_map body with each rank's (Q, C) local
    candidates (global ids, -1 padding, +inf masked distances); returns a
    replicated (Q, k).  ``merge`` must already be resolved to ``"gather"``
    or ``"tree"`` (see :func:`resolve_merge`); the two return identical
    sorted distances bit-for-bit, and identical ids up to distance ties
    (with ``prune``, ids are bit-equal to the unpruned tree).
    """
    if merge == "gather":
        return gather_merge_topk(ids, d2, k=k, axis=axis)
    if merge == "tree":
        return tree_merge_topk(ids, d2, k=k, axis=axis, axis_size=axis_size,
                               prune=prune)
    raise ValueError(f"unresolved merge strategy {merge!r}")
