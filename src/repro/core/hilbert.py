"""Fast Hilbert sort for high-dimensional points, TPU-native formulation.

The paper's fast Hilbert sort [Imamura et al., SISAP 2016] is a recursive,
in-place binary partition that follows the Hilbert curve's Gray-code orthant
order one axis at a time — average O(n log n), no Hilbert indices ever
materialized.  That control-flow shape does not map onto TPU.  We keep the
*insight* (only enough curve depth to isolate small cells is needed) and
compute, per point, a **truncated Hilbert key**: the top ``key_bits`` bits of
the Hilbert index, via Skilling's transform ("Programming the Hilbert curve",
AIP Conf. Proc. 707, 2004).  Skilling's transform is O(d·b) identical bit-ops
per point — perfectly data-parallel over n points (VPU-friendly) — and the
truncated keys are sorted lexicographically with ``jnp.lexsort``.

Key layout: a key is ``W = ceil(key_bits/32)`` uint32 words, word 0 most
significant, bit 31 of word 0 the most significant bit.  The Hilbert index bit
stream interleaves the transformed coordinates MSB-level-first:
``stream[s] = bit (b-1 - s//d) of X[s % d]``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "axes_to_transpose",
    "transpose_to_axes",
    "quantize_points",
    "hilbert_keys",
    "hilbert_sort",
    "lex_less",
    "lex_searchsorted",
    "key_words",
]


def key_words(key_bits: int) -> int:
    """Number of uint32 words used to store a ``key_bits``-bit key."""
    return -(-key_bits // 32)


# ---------------------------------------------------------------------------
# Skilling transform
# ---------------------------------------------------------------------------


def _level_pass(x: jax.Array, level: int, reverse: bool) -> jax.Array:
    """One level of Skilling's "inverse undo", without a sequential scan.

    Skilling's per-level loop threads a carry register through the dims:
      i == 0:  if X[0] & Q: X[0] ^= P                     (invert register)
      i >= 1:  if X[i] & Q: carry ^= P                    (invert register)
               else:        swap P-masked low bits of carry and X[i]
    (the else-branch algebra: t=(c^Xi)&P; c^=t; Xi^=t  ==  an exact swap of
    the low P bits).  Because each step either *inverts* the register or
    *swaps* it with a column, the value any column receives is the low bits
    of the **previous swap column** (or the initial register), XOR'd by P if
    the number of intervening inverts is odd.  That is a cummax (previous
    swap index) + cumsum (invert parity) + gather — fully data-parallel.
    ``reverse=True`` runs the involution backwards (dims d-1..1, then the
    i==0 op), which is the inverse pass used by :func:`transpose_to_axes`.

    Note: a straightforward ``lax.scan`` formulation is miscompiled by
    XLA:CPU at batch >= 32 (carry vectorization bug, jax 0.8.2); this
    formulation is also asymptotically better (O(log d) depth on TPU).
    """
    n, d = x.shape
    q = jnp.uint32(1 << level)
    p = jnp.uint32((1 << level) - 1)
    np_ = jnp.uint32(~((1 << level) - 1) & 0xFFFFFFFF)

    x0 = x[:, 0]
    cond0 = (x0 & q) != 0
    if d == 1:
        return jnp.where(cond0, x0 ^ p, x0)[:, None]

    body = x[:, 1:]
    if reverse:
        body = body[:, ::-1]

    cond = (body & q) != 0          # invert ops           (n, d-1)
    swap = ~cond                    # swap ops
    inv = cond.astype(jnp.int32)
    s_excl = jnp.cumsum(inv, axis=1) - inv          # inverts before t
    total = jnp.sum(inv, axis=1)                    # (n,)
    if not reverse:
        # forward: the i==0 self-invert happens before everything
        s_excl = s_excl + cond0.astype(jnp.int32)[:, None]
        total = total + cond0.astype(jnp.int32)

    tpos = jnp.broadcast_to(jnp.arange(d - 1, dtype=jnp.int32)[None, :], (n, d - 1))
    swap_pos = jnp.where(swap, tpos, jnp.int32(-1))
    run_max = lax.cummax(swap_pos, axis=1)
    prev = jnp.concatenate(
        [jnp.full((n, 1), -1, jnp.int32), run_max[:, :-1]], axis=1
    )  # previous swap strictly before t

    src_gather = jnp.take_along_axis(body, jnp.maximum(prev, 0).astype(jnp.int32), axis=1)
    src_low = jnp.where(prev < 0, x0[:, None], src_gather) & p
    s_at_prev = jnp.take_along_axis(s_excl, jnp.maximum(prev, 0).astype(jnp.int32), axis=1)
    s_j = jnp.where(prev < 0, 0, s_at_prev)
    parity = ((s_excl - s_j) & 1) == 1
    new_low = jnp.where(parity, src_low ^ p, src_low)
    body_new = jnp.where(swap, (body & np_) | new_low, body)

    # final register -> column 0
    last_swap = run_max[:, -1]                     # (n,)
    v_gather = jnp.take_along_axis(
        body, jnp.maximum(last_swap, 0)[:, None].astype(jnp.int32), axis=1
    )[:, 0]
    v_src = jnp.where(last_swap < 0, x0, v_gather) & p
    s_last = jnp.take_along_axis(
        s_excl, jnp.maximum(last_swap, 0)[:, None].astype(jnp.int32), axis=1
    )[:, 0]
    s_last = jnp.where(last_swap < 0, 0, s_last)
    par_end = total - s_last
    if reverse:
        # reverse: the i==0 self-invert happens after everything
        par_end = par_end + cond0.astype(jnp.int32)
    v_end = jnp.where((par_end & 1) == 1, v_src ^ p, v_src)
    x0_new = (x0 & np_) | v_end

    if reverse:
        body_new = body_new[:, ::-1]
    return jnp.concatenate([x0_new[:, None], body_new], axis=1)


def _prefix_xor(x: jax.Array) -> jax.Array:
    """Inclusive prefix-XOR over axis 1 via Hillis-Steele doubling."""
    n, d = x.shape
    s = 1
    while s < d:
        x = x ^ jnp.concatenate(
            [jnp.zeros((n, s), x.dtype), x[:, :-s]], axis=1
        )
        s <<= 1
    return x


def axes_to_transpose(coords: jax.Array, bits: int) -> jax.Array:
    """Skilling's AxesToTranspose, vectorized over points.

    Args:
      coords: (n, d) uint32 grid coordinates, each in [0, 2**bits).
      bits: number of bits per coordinate (b).

    Returns:
      (n, d) uint32 "transpose" representation: bit ``l`` of output column
      ``i`` is Hilbert-index bit at stream position ``(bits-1-l)*d + i``.
    """
    x = coords.astype(jnp.uint32)
    n, d = x.shape

    # --- Inverse undo: for Q = M .. 2 (scan-free level pass). ---
    for level in range(bits - 1, 0, -1):
        x = _level_pass(x, level, reverse=False)

    # --- Gray encode: X[i] ^= X[i-1] (already-updated) == prefix-XOR. ---
    # Hillis-Steele doubling instead of ``lax.associative_scan``: when the
    # associative scan is fused with ``_level_pass`` under jit, XLA:CPU
    # miscompiles the composition (observed at d=2, bits=2, jax 0.4.37:
    # jitted keys disagree with op-by-op eval and collide).  Same O(log d)
    # depth, no scan primitive for the fuser to mangle.
    x = _prefix_xor(x)
    t = jnp.zeros((n,), jnp.uint32)
    last = x[:, -1]
    for level in range(bits - 1, 0, -1):
        q = jnp.uint32(1 << level)
        t = jnp.where((last & q) != 0, t ^ jnp.uint32((1 << level) - 1), t)
    return x ^ t[:, None]


def transpose_to_axes(transpose: jax.Array, bits: int) -> jax.Array:
    """Inverse of :func:`axes_to_transpose` (used by tests/oracles)."""
    x = transpose.astype(jnp.uint32)
    n, d = x.shape

    # Gray decode.  Forward computed t from the pre-XOR y[:, -1]; here we
    # only have z = y ^ t, but t's contribution to bit `level` comes solely
    # from already-reconstructed higher levels, so probe (z ^ t_sofar).
    t = jnp.zeros((n,), jnp.uint32)
    last = x[:, -1]
    for level in range(bits - 1, 0, -1):
        q = jnp.uint32(1 << level)
        t = jnp.where(((last ^ t) & q) != 0, t ^ jnp.uint32((1 << level) - 1), t)
    x = x ^ t[:, None]
    # Invert the prefix-XOR: X[i] ^= X[i+1]... walk from high index down.
    # prefix-xor y[i] = x[0]^..^x[i]  =>  x[i] = y[i] ^ y[i-1].
    x = jnp.concatenate([x[:, :1], x[:, 1:] ^ x[:, :-1]], axis=1)

    # Undo "inverse undo": same involutive level pass, run backwards
    # (dims d-1..1 then the i==0 op), levels in the opposite order.
    for level in range(1, bits):
        x = _level_pass(x, level, reverse=True)
    return x


# ---------------------------------------------------------------------------
# Keys
# ---------------------------------------------------------------------------


def quantize_points(
    points: jax.Array,
    bits: int,
    lo: jax.Array,
    hi: jax.Array,
) -> jax.Array:
    """Uniformly quantize fp points (n, d) into [0, 2**bits) grid coords."""
    span = jnp.maximum(hi - lo, 1e-12)
    levels = (1 << bits) - 1
    t = (points - lo) / span
    g = jnp.clip(jnp.round(t * levels), 0, levels)
    return g.astype(jnp.uint32)


def _pack_bits_to_words(bit_cols, n: int, key_bits: int) -> jax.Array:
    """Pack a (n, L*d) {0,1} bit matrix into (n, W) uint32, MSB-first."""
    w = key_words(key_bits)
    total = w * 32
    bits_mat = bit_cols[:, :key_bits]
    pad = total - bits_mat.shape[1]
    if pad:
        bits_mat = jnp.pad(bits_mat, ((0, 0), (0, pad)))
    bits_mat = bits_mat.reshape(n, w, 32).astype(jnp.uint32)
    shifts = (31 - jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    words = jnp.sum(bits_mat << shifts[None, None, :], axis=-1, dtype=jnp.uint32)
    return words


@functools.partial(jax.jit, static_argnames=("bits", "key_bits"))
def hilbert_keys(
    points: jax.Array,
    *,
    bits: int,
    key_bits: int,
    lo: jax.Array,
    hi: jax.Array,
    perm: Optional[jax.Array] = None,
    flip: Optional[jax.Array] = None,
) -> jax.Array:
    """Truncated Hilbert keys for fp points.

    Args:
      points: (n, d) float array.
      bits: grid bits per axis (curve depth).
      key_bits: number of leading Hilbert-index bits to keep.
      lo/hi: (d,) quantization bounds.
      perm: optional (d,) axis permutation (the forest's randomization).
      flip: optional (d,) bool, per-axis reflection.

    Returns:
      (n, W) uint32 packed keys, word 0 most significant.
    """
    n, d = points.shape
    if key_bits > d * bits:
        raise ValueError(f"key_bits={key_bits} exceeds d*bits={d * bits}")
    coords = quantize_points(points, bits, lo, hi)
    if flip is not None:
        levels = jnp.uint32((1 << bits) - 1)
        coords = jnp.where(flip[None, :], levels - coords, coords)
    if perm is not None:
        coords = coords[:, perm]
    tr = axes_to_transpose(coords, bits)
    # Interleave MSB-level-first: level b-1 of all dims, then b-2, ...
    n_levels = -(-key_bits // d)
    cols = []
    for j in range(n_levels):
        level = bits - 1 - j
        cols.append((tr >> jnp.uint32(level)) & jnp.uint32(1))
    bit_cols = jnp.concatenate(cols, axis=1)
    return _pack_bits_to_words(bit_cols, n, key_bits)


def _lexsort_words(keys: jax.Array) -> jax.Array:
    """argsort of (n, W) packed keys, lexicographic, word 0 primary."""
    w = keys.shape[1]
    # jnp.lexsort: LAST key is the primary sort key.
    return jnp.lexsort(tuple(keys[:, i] for i in range(w - 1, -1, -1)))


@functools.partial(jax.jit, static_argnames=("bits", "key_bits"))
def hilbert_sort(
    points: jax.Array,
    *,
    bits: int,
    key_bits: int,
    lo: jax.Array,
    hi: jax.Array,
    perm: Optional[jax.Array] = None,
    flip: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Hilbert-sort ``points``; returns (order, sorted_keys).

    ``order`` is an int32 permutation such that ``points[order]`` walks the
    (truncated) Hilbert curve; ``sorted_keys`` are the packed keys in that
    order (used to build the rank directory / "compressed Hilbert tree").
    """
    keys = hilbert_keys(
        points, bits=bits, key_bits=key_bits, lo=lo, hi=hi, perm=perm, flip=flip
    )
    order = _lexsort_words(keys).astype(jnp.int32)
    return order, keys[order]


# ---------------------------------------------------------------------------
# Lexicographic search over packed keys
# ---------------------------------------------------------------------------


def lex_less(a: jax.Array, b: jax.Array) -> jax.Array:
    """Lexicographic ``a < b`` over trailing word axis (word 0 primary)."""
    w = a.shape[-1]
    out = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]), bool)
    for i in range(w - 1, -1, -1):
        ai, bi = a[..., i], b[..., i]
        out = (ai < bi) | ((ai == bi) & out)
    return out


@jax.jit
def lex_searchsorted(sorted_keys: jax.Array, query_keys: jax.Array) -> jax.Array:
    """Vectorized left-insertion binary search on packed multi-word keys.

    Args:
      sorted_keys: (m, W) uint32, lexicographically sorted.
      query_keys: (q, W) uint32.

    Returns:
      (q,) int32 positions p with sorted[p-1] < query <= sorted[p] semantics
      (``searchsorted(..., side='left')``).
    """
    m = sorted_keys.shape[0]
    q = query_keys.shape[0]
    steps = max(1, int(np.ceil(np.log2(m + 1))))

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) // 2
        mid_keys = sorted_keys[mid]  # (q, W) gather
        go_right = lex_less(mid_keys, query_keys)  # sorted[mid] < query
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo = jnp.zeros((q,), jnp.int32)
    hi = jnp.full((q,), m, jnp.int32)
    lo, _ = lax.fori_loop(0, steps, body, (lo, hi))
    return lo
