"""Hilbert forest: multiple Hilbert trees under randomized axis orders.

A "tree" on TPU is an implicit structure: the Hilbert-sorted **order** (an
int32 permutation) plus a **rank directory** — every ``leaf_size``-th sorted
key.  Locating a query's position is a vectorized lexicographic binary search
over the directory, the exact analogue of the paper's compressed Hilbert tree
(subtrees of ~100 points truncated to leaves; 76 MB vs 400 MB per tree).

All functions here are pure jitted stages; the public entry point that
composes them is :class:`repro.index.HilbertIndex`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hilbert
from repro.core.types import ForestConfig

__all__ = ["HilbertForest", "build_forest", "tree_candidates"]


class HilbertForest(NamedTuple):
    """Stacked per-tree state (T trees over n points in d dims)."""

    perms: jax.Array  # (T, d) int32 — randomized axis orders
    flips: jax.Array  # (T, d) bool  — randomized reflections
    orders: jax.Array  # (T, n) int32 — point ids in per-tree Hilbert order
    directories: jax.Array  # (T, n_dir, W) uint32 — sampled sorted keys
    lo: jax.Array  # (d,) quantization bounds
    hi: jax.Array  # (d,)

    @property
    def n_trees(self) -> int:
        return self.orders.shape[0]

    @property
    def n_points(self) -> int:
        return self.orders.shape[1]

    def memory_bytes(self) -> int:
        """In-RAM index footprint (the paper's 16 GB budget accounting)."""
        return sum(
            np.prod(a.shape) * a.dtype.itemsize
            for a in (self.perms, self.flips, self.orders, self.directories)
        )


def forest_randomization(cfg: ForestConfig, d: int) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    perms = np.stack([rng.permutation(d) for _ in range(cfg.n_trees)]).astype(np.int32)
    flips = rng.integers(0, 2, size=(cfg.n_trees, d)).astype(bool)
    return perms, flips


@functools.partial(jax.jit, static_argnames=("bits", "key_bits", "leaf_size"))
def _build_tree(points, lo, hi, perm, flip, *, bits, key_bits, leaf_size):
    order, sorted_keys = hilbert.hilbert_sort(
        points, bits=bits, key_bits=key_bits, lo=lo, hi=hi, perm=perm, flip=flip
    )
    directory = sorted_keys[::leaf_size]
    return order, directory


def build_forest(points: jax.Array, cfg: ForestConfig) -> HilbertForest:
    """Build ``cfg.n_trees`` Hilbert trees (streamed; one key array live)."""
    n, d = points.shape
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    perms_np, flips_np = forest_randomization(cfg, d)
    orders, dirs = [], []
    for t in range(cfg.n_trees):
        order, directory = _build_tree(
            points,
            lo,
            hi,
            jnp.asarray(perms_np[t]),
            jnp.asarray(flips_np[t]),
            bits=cfg.bits,
            key_bits=cfg.key_bits,
            leaf_size=cfg.leaf_size,
        )
        orders.append(order)
        dirs.append(directory)
    return HilbertForest(
        perms=jnp.asarray(perms_np),
        flips=jnp.asarray(flips_np),
        orders=jnp.stack(orders),
        directories=jnp.stack(dirs),
        lo=lo,
        hi=hi,
    )


@functools.partial(jax.jit, static_argnames=("bits", "key_bits", "leaf_size", "k1"))
def tree_candidates(
    queries: jax.Array,
    order: jax.Array,
    directory: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    perm: jax.Array,
    flip: jax.Array,
    *,
    bits: int,
    key_bits: int,
    leaf_size: int,
    k1: int,
) -> jax.Array:
    """Per-tree stage-1: locate each query in Hilbert order, take k1 around.

    Returns (Q, k1) int32 point ids (the paper's "extract k1 candidates near
    q's position").  Window edges clip; duplicates are handled downstream.
    """
    n = order.shape[0]
    qkeys = hilbert.hilbert_keys(
        queries, bits=bits, key_bits=key_bits, lo=lo, hi=hi, perm=perm, flip=flip
    )
    j = hilbert.lex_searchsorted(directory, qkeys)  # (Q,) in [0, n_dir]
    # directory[j-1] <= q < directory[j]  =>  true rank in ((j-1)·leaf, j·leaf];
    # center the window on the interval midpoint to avoid a +leaf/2 bias.
    rank = jnp.clip(j * leaf_size - leaf_size // 2, 0, n - 1)
    start = jnp.clip(rank - k1 // 2, 0, max(n - k1, 0))
    pos = start[:, None] + jnp.arange(k1, dtype=jnp.int32)[None, :]
    pos = jnp.clip(pos, 0, n - 1)
    return order[pos]
