"""Hilbert forest core: the paper's contribution as composable JAX modules."""

from repro.core import forest, hilbert, knn_graph, quantize, search, sketch  # noqa: F401
from repro.core.types import (  # noqa: F401
    ForestConfig,
    GraphParams,
    QuantizerConfig,
    SearchParams,
)
