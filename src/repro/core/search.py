"""Algorithm 1 jitted stages: approximate k-NN search with a Hilbert forest.

.. note::
   The public entry point is :class:`repro.index.HilbertIndex` — a
   self-describing facade that carries its build config, so search never
   takes a config argument.  This module now holds the **pure jitted
   stages** the facade composes, plus thin deprecation shims
   (:func:`build_index` / :func:`search`) for one release of backward
   compatibility.

Pipeline (paper §3.1): forest candidates (coarse) → Hamming filter on shared
sketches (fine) → master-order ±h expansion → asymmetric fp32-vs-4-bit
distance → top-k.

Implementation notes vs the pseudocode:
  * The paper first collects ALL n·k1 candidates per query, then filters.
    At challenge scale that transient alone is ~9 GB; we instead keep a
    running sketch-filtered top-k2 and merge each tree's k1 candidates into
    it — identical result (top-k2 of a union is associative), constant
    memory, and the same trick the paper itself uses for Task 2.
  * Candidates are tracked by their **master-order position** so stage 2 is
    a contiguous ±h window and all gathers hit the master-rearranged arrays
    (the paper's memory-locality trick; on TPU this turns into coalesced
    gathers over the sorted copies).
  * Duplicates (same point from several trees / overlapping windows) are
    deduped during the merge so the final top-k can't contain repeats.

Fused scan pipeline (the serving hot path): :func:`fused_search_chunk` runs
the WHOLE per-chunk pipeline — query sketching, a ``lax.scan`` over the
stacked forest arrays (``orders``/``directories``/``perms``/``flips``) that
replaces the per-tree Python loop, and the packed-code stage 2 — inside ONE
jitted computation, so a query chunk costs one XLA dispatch regardless of
``n_trees``.  Stage 2 reads candidate codes as contiguous ±h **windowed
dynamic slices** from the nibble-packed ``(n, ceil(d/8))`` uint32 resident
codes (half the HBM traffic of unpacked uint8) instead of a ``(Q, C, d)``
random gather; on TPU the window distances route through the Pallas
``qdist_windows_from_packed`` kernel, elsewhere through a packed XLA path
that unpacks losslessly and is therefore bit-identical to unpacked ADC.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import forest as forest_lib
from repro.core import quantize, sketch
from repro.core.types import ForestConfig, QuantizerConfig, SearchParams

__all__ = [
    "HilbertForestIndex",
    "build_index",
    "search",
    "hilbert_master_sort",
    "stage1_tree_merge",
    "stage2_expand_rank",
    "stage2_packed_windows",
    "fused_search_chunk",
    "merge_topk",
    "brute_force_topk",
    "inflate_k",
    "paper_memory_model",
]

_INF = jnp.int32(2**30)


def paper_memory_model(n: int, d: int, sketch_bytes: int, forest_bytes: int
                       ) -> dict:
    """The paper's RAM-budget table (§3.1) as a dict of byte counts.

    Single source of truth for both the legacy container's and the facade's
    ``memory_report`` (previously copy-pasted).  ``quantized_bytes`` is the
    4-bit-packed accounting — since PR 3 the codes are RESIDENT in exactly
    this layout, so it equals the actual ``codes_master.nbytes``.
    """
    packed_codes = n * (-(-d // 8)) * 4  # 4-bit packed into uint32 words
    shared = n * (-(-d // 32)) * 4  # MSB plane counted once
    return {
        "forest_bytes": forest_bytes,
        "sketch_bytes": sketch_bytes,
        "quantized_bytes": packed_codes,
        "shared_bit_savings": shared,
        "combined_stage2_bytes": sketch_bytes + packed_codes - shared,
    }


class HilbertForestIndex(NamedTuple):
    """DEPRECATED legacy container — use :class:`repro.index.HilbertIndex`.

    Carries no config, so callers of the legacy :func:`search` must re-supply
    the exact build-time ``ForestConfig`` (the footgun the facade removes).
    Codes here stay UNPACKED (n, d) uint8 for one release of layout
    compatibility; the facade stores them nibble-packed.
    """

    forest: forest_lib.HilbertForest
    quant: quantize.Quantizer
    codes_master: jax.Array  # (n, d) uint8, master-order layout
    sketches_master: jax.Array  # (n, Ws) uint32, master-order layout
    master_order: jax.Array  # (n,) int32: position -> point id
    master_rank: jax.Array  # (n,) int32: point id -> position

    @property
    def n_points(self) -> int:
        return self.master_order.shape[0]

    def memory_report(self) -> dict:
        """Bytes by component, mirroring the paper's RAM budget table."""
        return paper_memory_model(
            self.n_points,
            self.codes_master.shape[1],
            int(np.prod(self.sketches_master.shape)) * 4,
            self.forest.memory_bytes(),
        )


@functools.partial(jax.jit, static_argnames=("cfg",))
def hilbert_master_sort(points, cfg: ForestConfig, lo, hi):
    """Un-permuted Hilbert sort defining the master order (pure stage)."""
    from repro.core import hilbert

    return hilbert.hilbert_sort(
        points, bits=cfg.bits, key_bits=cfg.key_bits, lo=lo, hi=hi
    )


def _merge_topk_dedup(best_pos, best_dist, new_pos, new_dist, k: int):
    """Merge candidate sets keyed by position; dedup; keep k smallest dists."""
    pos = jnp.concatenate([best_pos, new_pos], axis=1)
    dist = jnp.concatenate([best_dist, new_dist], axis=1)
    # Dedup: sort by position; equal-adjacent entries are duplicates (same
    # position ⇒ same sketch ⇒ same distance), mask all but the first.
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    dist_s = jnp.take_along_axis(dist, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    dist_s = jnp.where(dup, _INF, dist_s)
    neg, idx = lax.top_k(-dist_s, k)
    return jnp.take_along_axis(pos_s, idx, axis=1), -neg


@functools.partial(
    jax.jit, static_argnames=("bits", "key_bits", "leaf_size", "k1", "k2",
                              "use_kernels")
)
def stage1_tree_merge(
    queries,
    qsketches,
    best_pos,
    best_dist,
    order,
    directory,
    lo,
    hi,
    perm,
    flip,
    master_rank,
    sketches_master,
    *,
    bits,
    key_bits,
    leaf_size,
    k1,
    k2,
    use_kernels=False,
):
    """One tree's stage-1: candidates → Hamming filter → merge into top-k2."""
    cand_ids = forest_lib.tree_candidates(
        queries, order, directory, lo, hi, perm, flip,
        bits=bits, key_bits=key_bits, leaf_size=leaf_size, k1=k1,
    )  # (Q, k1)
    mpos = master_rank[cand_ids]  # (Q, k1) master positions
    csk = sketches_master[mpos]  # (Q, k1, Ws)
    if use_kernels:
        from repro.kernels.hamming import hamming_rows

        hd = hamming_rows(qsketches, csk, use_kernel=True)  # (Q, k1)
    else:
        hd = sketch.hamming_distance(qsketches[:, None, :], csk)  # (Q, k1)
    return _merge_topk_dedup(best_pos, best_dist, mpos, hd, k2)


def _expand_windows(best_pos, n: int, h: int):
    """±h windows as (starts (Q, k2), pos (Q, k2, window), window size).

    Each surviving stage-1 position expands to a CONTIGUOUS window of
    ``window = min(2h+1, n)`` master-order rows starting at
    ``clip(best_pos - h, 0, n - window)`` — near the array edges the window
    shifts in-bounds instead of clamping to duplicate rows, so the candidate
    set is always a superset of the clamped expansion.  Contiguity is what
    lets candidate codes be read with windowed dynamic slices instead of a
    (Q, C, d) random gather.
    """
    window = min(2 * h + 1, n)
    starts = jnp.clip(best_pos - h, 0, n - window)  # (Q, k2)
    pos = starts[:, :, None] + jnp.arange(window, dtype=jnp.int32)[None, None, :]
    return starts, pos, window


def _window_slices(rows: jax.Array, starts: jax.Array, window: int) -> jax.Array:
    """Read (Q, k2) contiguous row windows: (n, W) -> (Q, k2, window, W)."""
    return jax.vmap(
        jax.vmap(lambda s: lax.dynamic_slice_in_dim(rows, s, window, axis=0))
    )(starts)


def _dedup_rank_topk(pos, d2, valid, master_order, k: int):
    """Sort by position, mask duplicates/invalid to +inf, final top-k.

    Shared tail of both stage-2 layouts: given identical (pos, d2, valid)
    inputs the outputs are identical, which is what makes the packed and
    unpacked search paths bit-identical on the XLA backend.

    The candidate pool is ``k2 * min(2h+1, n)``, which on a tiny index (or
    a tiny mutable segment queried with an inflated k) can be smaller than
    ``k``; the top-k is taken over the pool and the tail padded with
    id -1 / +inf — the same padding contract as ``brute_force_topk``.
    """
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    d2_s = jnp.take_along_axis(d2, sort_idx, axis=1)
    valid_s = jnp.take_along_axis(valid, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    d2_s = jnp.where((~dup) & valid_s, d2_s, jnp.inf)
    k_top = min(k, pos_s.shape[1])
    neg, idx = lax.top_k(-d2_s, k_top)
    final_pos = jnp.take_along_axis(pos_s, idx, axis=1)
    ids, dist = master_order[final_pos], -neg
    if k_top < k:
        qn, pad = ids.shape[0], k - k_top
        ids = jnp.concatenate(
            [ids, jnp.full((qn, pad), -1, ids.dtype)], axis=1
        )
        dist = jnp.concatenate(
            [dist, jnp.full((qn, pad), jnp.inf, dist.dtype)], axis=1
        )
    return ids, dist


@functools.partial(jax.jit, static_argnames=("h", "k"))
def stage2_expand_rank(
    queries, best_pos, codes_master, master_order, quant, *, h, k
):
    """±h expansion, dedup, exact ADC distance, top-k — UNPACKED codes.

    ``codes_master`` is (n, d) uint8.  Kept as the parity/benchmark
    reference for :func:`stage2_packed_windows`; both share the same
    windowed candidate expansion and dedup/top-k tail, so on the XLA
    backend their results are bit-identical (pack/unpack is lossless).
    """
    n = master_order.shape[0]
    qn, k2 = best_pos.shape
    starts, pos, window = _expand_windows(best_pos, n, h)
    codes = _window_slices(codes_master, starts, window)  # (Q, k2, window, d)
    codes = codes.reshape(qn, k2 * window, codes_master.shape[1])
    d2 = quantize.adc_distance(quant, queries, codes)  # (Q, C) fp32
    valid = jnp.broadcast_to((best_pos >= 0)[:, :, None], pos.shape)
    return _dedup_rank_topk(
        pos.reshape(qn, -1), d2, valid.reshape(qn, -1), master_order, k
    )


@functools.partial(jax.jit, static_argnames=("h", "k", "use_kernels"))
def stage2_packed_windows(
    queries, best_pos, codes_packed, master_order, quant, *, h, k,
    use_kernels=False,
):
    """Stage 2 on the RESIDENT nibble-packed codes (n, ceil(d/8)) uint32.

    Candidate codes are read as contiguous ±h windowed dynamic slices of
    the packed words (0.5 B/dim of traffic).  Distances route through
    ``repro.kernels.qdist.qdist_windows_from_packed``: the Pallas kernel
    when ``use_kernels`` (TPU target; interpret mode on CPU), else a packed
    XLA path that unpacks losslessly — bit-identical to
    :func:`stage2_expand_rank` on the same candidates.
    """
    n = master_order.shape[0]
    d = quant.centroids.shape[0]
    qn, k2 = best_pos.shape
    starts, pos, window = _expand_windows(best_pos, n, h)
    win = _window_slices(codes_packed, starts, window)  # (Q, k2, window, W)
    win = win.reshape(qn, k2 * window, codes_packed.shape[1])
    if use_kernels:
        from repro.kernels.qdist import qdist_windows_from_packed

        d2 = qdist_windows_from_packed(
            queries, win, quant.centroids, d=d, use_kernel=True,
            interpret=jax.default_backend() != "tpu",
        )
    else:
        d2 = quantize.adc_distance_packed(quant, queries, win, d=d)
    valid = jnp.broadcast_to((best_pos >= 0)[:, :, None], pos.shape)
    return _dedup_rank_topk(
        pos.reshape(qn, -1), d2, valid.reshape(qn, -1), master_order, k
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "key_bits", "leaf_size", "k1", "k2", "h", "k", "use_kernels"
    ),
)
def fused_search_chunk(
    queries,
    orders,
    directories,
    lo,
    hi,
    perms,
    flips,
    master_rank,
    sketches_master,
    codes_packed,
    master_order,
    quant,
    *,
    bits,
    key_bits,
    leaf_size,
    k1,
    k2,
    h,
    k,
    use_kernels=False,
):
    """ONE dispatch per query chunk: sketch → scan over trees → packed stage 2.

    The per-tree Python loop becomes a ``lax.scan`` over the stacked forest
    arrays (``orders`` (T, n), ``directories`` (T, n_dir, W), ``perms``/
    ``flips`` (T, d)), so the stage-1 cost is one XLA dispatch regardless of
    ``n_trees``; query sketching and the packed windowed stage 2 fuse into
    the same computation.  Results are bit-identical to the per-tree loop +
    unpacked stage 2 (all stage-1 state is integer; stage 2 shares the same
    candidate expansion and, on XLA, the same lossless-unpack ADC).
    """
    qn = queries.shape[0]
    qsk = sketch.make_sketches(quant, queries)
    init = (
        jnp.full((qn, k2), -1, jnp.int32),
        jnp.full((qn, k2), _INF, jnp.int32),
    )

    def body(carry, tree):
        order, directory, perm, flip = tree
        best_pos, best_dist = stage1_tree_merge(
            queries, qsk, carry[0], carry[1],
            order, directory, lo, hi, perm, flip,
            master_rank, sketches_master,
            bits=bits, key_bits=key_bits, leaf_size=leaf_size, k1=k1, k2=k2,
            use_kernels=use_kernels,
        )
        return (best_pos, best_dist), None

    (best_pos, _), _ = lax.scan(body, init, (orders, directories, perms, flips))
    return stage2_packed_windows(
        queries, best_pos, codes_packed, master_order, quant,
        h=h, k=k, use_kernels=use_kernels,
    )


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(ids, dists, *, k):
    """Associative cross-source top-k merge over (id, distance) candidates.

    The one merge shared by every fan-out search path: the sharded index
    merges per-shard top-k's (queries replicated, rows sharded), and the
    mutable index merges per-segment + write-buffer top-k's.  Top-k of a
    union is associative, so merging per-source top-k's is exact.

    Args:
      ids: (Q, C) int32 candidate ids; ``-1`` marks a padding slot.
      dists: (Q, C) float distances; non-finite entries are masked out.
      k: results per query (static).

    Returns:
      (ids (Q, k) int32, dists (Q, k)) sorted by ascending distance.

    Contract details, relied on by the call sites:
      * **Dedup by id**: the same id appearing in several sources (a point
        duplicated across shard boundaries as sentinel padding, or a stale
        row surviving mutable-index compaction) is kept once, at its
        SMALLEST distance; among equal distances the earliest input column
        wins.
      * **Column-stable tie order**: survivors keep their original column
        positions for the final ``lax.top_k``, so equal-distance results
        rank by input column order — a single already-sorted source passes
        through bit-identically (the mutable index's single-segment case).
      * **Padding**: when fewer than ``k`` finite candidates exist, the
        tail is id -1 / distance +inf — the same contract as
        :func:`brute_force_topk` and the stage-2 pipeline.

    Associativity is what makes *tree* reduction exact: merging
    per-source top-k's pairwise in any bracketing yields sorted
    distances bit-equal to one flat merge of the full pool (property-
    tested in ``tests/test_sharded.py``), which is the basis of the
    sharded facades' log2(S)-hop cross-shard merge
    (:func:`repro.core.distributed.cross_shard_merge_topk`).
    """
    qn, c = ids.shape
    # Locate duplicates without reordering: stable-lexsort each row by
    # (id primary, dist secondary), mark all but the first entry of every
    # equal-id run, and scatter the mask back to the original columns.
    order = jnp.lexsort((dists, ids), axis=1)
    ids_s = jnp.take_along_axis(ids, order, axis=1)
    dup_s = jnp.concatenate(
        [jnp.zeros_like(ids_s[:, :1], bool), ids_s[:, 1:] == ids_s[:, :-1]],
        axis=1,
    )
    rows = jnp.arange(qn, dtype=jnp.int32)[:, None]
    dup = jnp.zeros(ids.shape, bool).at[rows, order].set(dup_s)
    d = jnp.where(dup | (ids < 0) | ~jnp.isfinite(dists), jnp.inf, dists)
    k_top = min(k, c)
    neg, idx = lax.top_k(-d, k_top)
    out_ids = jnp.take_along_axis(ids, idx, axis=1)
    out_d = -neg
    out_ids = jnp.where(jnp.isfinite(out_d), out_ids, -1)
    if k_top < k:
        pad = k - k_top
        out_ids = jnp.concatenate(
            [out_ids, jnp.full((qn, pad), -1, out_ids.dtype)], axis=1
        )
        out_d = jnp.concatenate(
            [out_d, jnp.full((qn, pad), jnp.inf, out_d.dtype)], axis=1
        )
    return out_ids, out_d


def merge_topk_pair(ids_a, d_a, ids_b, d_b, first, *, k):
    """One hop of a pairwise :func:`merge_topk` tree reduction.

    Concatenates the two (Q, k) candidate sets and flat-merges them, with
    ``first`` — a traced boolean, broadcast over queries — choosing which
    source occupies the *leading* columns.  Column order is what breaks
    equal-distance ties in ``merge_topk``, so when two ranks of a
    butterfly exchange partial results and both call this with ``first``
    keyed to the lower rank, they merge identical column layouts and
    produce bit-identical outputs — the invariant that lets the sharded
    facades emit the reduction's result as a replicated array.

    Not jitted standalone: it is traced inside shard_map bodies (and the
    pure-host property test) where ``first`` is a per-rank scalar.
    """
    cat_i = jnp.where(
        first,
        jnp.concatenate([ids_a, ids_b], axis=1),
        jnp.concatenate([ids_b, ids_a], axis=1),
    )
    cat_d = jnp.where(
        first,
        jnp.concatenate([d_a, d_b], axis=1),
        jnp.concatenate([d_b, d_a], axis=1),
    )
    return merge_topk(cat_i, cat_d, k=k)


def inflate_k(k: int, dead: int, pool: int) -> int:
    """Tombstone-aware per-source ``k`` inflation (the LSM search contract).

    A sealed segment queried for ``k`` results can have up to ``dead`` of
    them masked by tombstones (or duplicate padding rows, on the sharded
    layout), so every fan-out search path asks each source for
    ``k + dead`` candidates, capped at the source's stage-2 candidate pool
    ``pool`` (beyond which inflation cannot help) and floored at 1.  Shared
    by :class:`repro.index.MutableHilbertIndex` (per segment) and
    :class:`repro.index.ShardedMutableHilbertIndex` (per generation,
    uniform across shards).
    """
    return max(1, min(k + dead, pool))


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force_topk(queries, points, valid, *, k):
    """Exact squared-L2 top-k against a small point set (pure stage).

    The mutable index's write buffer is searched this way: ``points`` is the
    fixed-capacity buffer (so the jit cache is stable across fills) and
    ``valid`` masks dead / unfilled rows to +inf.  Uses the Gram expansion
    ||q-p||^2 = ||q||^2 - 2<q,p> + ||p||^2 so the transient is (Q, B), not
    (Q, B, d).  Returns (row indices into ``points`` (Q, k), d2 (Q, k));
    masked rows surface as d2 = +inf.
    """
    qq = jnp.sum(queries * queries, axis=1)[:, None]
    pp = jnp.sum(points * points, axis=1)[None, :]
    d2 = jnp.maximum(qq - 2.0 * (queries @ points.T) + pp, 0.0)
    d2 = jnp.where(valid[None, :], d2, jnp.inf)
    neg, idx = lax.top_k(-d2, k)
    return idx, -neg


# ---------------------------------------------------------------------------
# Deprecation shims (one release): delegate to repro.index.HilbertIndex so
# old callers get bit-identical results from the same jitted stages.
# ---------------------------------------------------------------------------

# The legacy container keeps codes unpacked; the facade wants them packed.
# Cache the packed form per codes array so repeated legacy search() calls
# don't repack the whole database every time.  Keyed by id(); a weakref
# finalizer evicts the entry when the source array dies, so the id can
# never be reused against a stale entry and dropped legacy indexes don't
# pin database-sized arrays for the process lifetime.
_PACKED_SHIM_CACHE: dict = {}


def _packed_codes_cached(codes: jax.Array) -> jax.Array:
    import weakref

    key = id(codes)
    hit = _PACKED_SHIM_CACHE.get(key)
    if hit is None or hit[0]() is not codes:
        packed = quantize.pack_codes(codes)
        try:
            ref = weakref.ref(codes)
            weakref.finalize(codes, _PACKED_SHIM_CACHE.pop, key, None)
        except TypeError:  # not weakref-able: skip caching
            return packed
        _PACKED_SHIM_CACHE[key] = (ref, packed)
        hit = _PACKED_SHIM_CACHE[key]
    return hit[1]


def build_index(
    points: jax.Array,
    forest_cfg: ForestConfig,
    quant_cfg: QuantizerConfig = QuantizerConfig(),
) -> HilbertForestIndex:
    """DEPRECATED: use ``repro.index.HilbertIndex.build(points, cfg)``."""
    warnings.warn(
        "repro.core.search.build_index is deprecated; use "
        "repro.index.HilbertIndex.build(points, IndexConfig(...))",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import HilbertIndex, IndexConfig

    idx = HilbertIndex.build(
        points,
        IndexConfig(forest=forest_cfg, quantizer=quant_cfg, store_points=False),
    )
    # The facade stores codes nibble-packed; the legacy container documents
    # the unpacked (n, d) uint8 layout, so unpack (lossless) on the way out.
    return HilbertForestIndex(
        forest=idx.forest,
        quant=idx.quant,
        codes_master=quantize.unpack_codes(idx.codes_master, idx.dim),
        sketches_master=idx.sketches_master,
        master_order=idx.master_order,
        master_rank=idx.master_rank,
    )


def search(
    index: HilbertForestIndex,
    queries: jax.Array,
    params: SearchParams,
    forest_cfg: ForestConfig,
    query_chunk: int = 2048,
    use_kernels: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """DEPRECATED: use ``repro.index.HilbertIndex.search(queries, params)``.

    This legacy entry point requires re-supplying the build-time
    ``forest_cfg``; a mismatch silently corrupts results.  The facade stores
    the config on the index and removes the argument entirely.
    """
    warnings.warn(
        "repro.core.search.search is deprecated; use "
        "repro.index.HilbertIndex.search(queries, params) — the index "
        "carries its own config",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import HilbertIndex, IndexConfig

    idx = HilbertIndex(
        config=IndexConfig(
            forest=forest_cfg,
            quantizer=QuantizerConfig(bits=index.quant.bits),
            store_points=False,
        ),
        forest=index.forest,
        quant=index.quant,
        codes_master=_packed_codes_cached(index.codes_master),
        sketches_master=index.sketches_master,
        master_order=index.master_order,
        master_rank=index.master_rank,
        points=None,
    )
    return idx.search(
        queries,
        params,
        backend="pallas" if use_kernels else "xla",
        query_chunk=query_chunk,
    )
