"""Algorithm 1: approximate k-NN search with a Hilbert forest.

Pipeline (paper §3.1): forest candidates (coarse) → Hamming filter on shared
sketches (fine) → master-order ±h expansion → asymmetric fp32-vs-4-bit
distance → top-k.

Implementation notes vs the pseudocode:
  * The paper first collects ALL n·k1 candidates per query, then filters.
    At challenge scale that transient alone is ~9 GB; we instead keep a
    running sketch-filtered top-k2 and merge each tree's k1 candidates into
    it — identical result (top-k2 of a union is associative), constant
    memory, and the same trick the paper itself uses for Task 2.
  * Candidates are tracked by their **master-order position** so stage 2 is
    a contiguous ±h window and all gathers hit the master-rearranged arrays
    (the paper's memory-locality trick; on TPU this turns into coalesced
    gathers over the sorted copies).
  * Duplicates (same point from several trees / overlapping windows) are
    deduped during the merge so the final top-k can't contain repeats.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import forest as forest_lib
from repro.core import quantize, sketch
from repro.core.types import ForestConfig, QuantizerConfig, SearchParams

__all__ = ["HilbertForestIndex", "build_index", "search"]

_INF = jnp.int32(2**30)


class HilbertForestIndex(NamedTuple):
    forest: forest_lib.HilbertForest
    quant: quantize.Quantizer
    codes_master: jax.Array  # (n, d) uint8, master-order layout
    sketches_master: jax.Array  # (n, Ws) uint32, master-order layout
    master_order: jax.Array  # (n,) int32: position -> point id
    master_rank: jax.Array  # (n,) int32: point id -> position

    @property
    def n_points(self) -> int:
        return self.master_order.shape[0]

    def memory_report(self) -> dict:
        """Bytes by component, mirroring the paper's RAM budget table."""
        d = self.codes_master.shape[1]
        packed_codes = self.n_points * (-(-d // 8)) * 4  # 4-bit packed
        sketches = int(np.prod(self.sketches_master.shape)) * 4
        shared = self.n_points * (-(-d // 32)) * 4  # MSB plane counted once
        return {
            "forest_bytes": self.forest.memory_bytes(),
            "sketch_bytes": sketches,
            "quantized_bytes": packed_codes,
            "shared_bit_savings": shared,
            "combined_stage2_bytes": sketches + packed_codes - shared,
        }


def build_index(
    points: jax.Array,
    forest_cfg: ForestConfig,
    quant_cfg: QuantizerConfig = QuantizerConfig(),
) -> HilbertForestIndex:
    """Full Task-1 preprocessing: quantize, sketch, forest, master order."""
    n, d = points.shape
    quant = quantize.fit(points, bits=quant_cfg.bits, sample_limit=quant_cfg.sample_limit)
    codes = quantize.encode(quant, points)
    sketches = sketch.sketches_from_codes(codes, bits=quant_cfg.bits)

    f = forest_lib.build_forest(points, forest_cfg)

    # Master order: an un-permuted Hilbert sort; vectors/sketches rearranged.
    master_order, _ = hilbert_master_sort(points, forest_cfg, f.lo, f.hi)
    master_rank = jnp.zeros((n,), jnp.int32).at[master_order].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    return HilbertForestIndex(
        forest=f,
        quant=quant,
        codes_master=codes[master_order],
        sketches_master=sketches[master_order],
        master_order=master_order,
        master_rank=master_rank,
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def hilbert_master_sort(points, cfg: ForestConfig, lo, hi):
    from repro.core import hilbert

    return hilbert.hilbert_sort(
        points, bits=cfg.bits, key_bits=cfg.key_bits, lo=lo, hi=hi
    )


def _merge_topk_dedup(best_pos, best_dist, new_pos, new_dist, k: int):
    """Merge candidate sets keyed by position; dedup; keep k smallest dists."""
    pos = jnp.concatenate([best_pos, new_pos], axis=1)
    dist = jnp.concatenate([best_dist, new_dist], axis=1)
    # Dedup: sort by position; equal-adjacent entries are duplicates (same
    # position ⇒ same sketch ⇒ same distance), mask all but the first.
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    dist_s = jnp.take_along_axis(dist, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    dist_s = jnp.where(dup, _INF, dist_s)
    neg, idx = lax.top_k(-dist_s, k)
    return jnp.take_along_axis(pos_s, idx, axis=1), -neg


@functools.partial(
    jax.jit, static_argnames=("bits", "key_bits", "leaf_size", "k1", "k2",
                              "use_kernels")
)
def _stage1_tree_merge(
    queries,
    qsketches,
    best_pos,
    best_dist,
    order,
    directory,
    lo,
    hi,
    perm,
    flip,
    master_rank,
    sketches_master,
    *,
    bits,
    key_bits,
    leaf_size,
    k1,
    k2,
    use_kernels=False,
):
    cand_ids = forest_lib.tree_candidates(
        queries, order, directory, lo, hi, perm, flip,
        bits=bits, key_bits=key_bits, leaf_size=leaf_size, k1=k1,
    )  # (Q, k1)
    mpos = master_rank[cand_ids]  # (Q, k1) master positions
    csk = sketches_master[mpos]  # (Q, k1, Ws)
    if use_kernels:
        from repro.kernels.hamming import hamming_rows

        hd = hamming_rows(qsketches, csk, use_kernel=True)  # (Q, k1)
    else:
        hd = sketch.hamming_distance(qsketches[:, None, :], csk)  # (Q, k1)
    return _merge_topk_dedup(best_pos, best_dist, mpos, hd, k2)


@functools.partial(jax.jit, static_argnames=("h", "k"))
def _stage2_expand_rank(
    queries, best_pos, codes_master, master_order, quant, *, h, k
):
    """±h master-order expansion, dedup, exact ADC distance, final top-k."""
    n = master_order.shape[0]
    deltas = jnp.arange(-h, h + 1, dtype=jnp.int32)
    pos = best_pos[:, :, None] + deltas[None, None, :]
    pos = jnp.clip(pos, 0, n - 1).reshape(best_pos.shape[0], -1)  # (Q, C)
    # Invalid slots (pos was -1 sentinel) clip to >=0; mask them via best_pos.
    valid = (best_pos >= 0)[:, :, None].astype(jnp.int32)
    valid = jnp.broadcast_to(valid, (best_pos.shape[0], best_pos.shape[1], 2 * h + 1))
    valid = valid.reshape(best_pos.shape[0], -1)
    # Dedup positions.
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    valid_s = jnp.take_along_axis(valid, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    keep = (~dup) & (valid_s == 1)

    codes = codes_master[pos_s]  # (Q, C, d) uint8
    d2 = quantize.adc_distance(quant, queries, codes)  # (Q, C) fp32
    d2 = jnp.where(keep, d2, jnp.inf)
    neg, idx = lax.top_k(-d2, k)
    final_pos = jnp.take_along_axis(pos_s, idx, axis=1)
    return master_order[final_pos], -neg


def search(
    index: HilbertForestIndex,
    queries: jax.Array,
    params: SearchParams,
    forest_cfg: ForestConfig,
    query_chunk: int = 2048,
    use_kernels: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Batched Algorithm-1 search. Returns (ids (Q, k), sq-distances).

    ``use_kernels=True`` routes the stage-2 Hamming filter through the
    Pallas ``hamming_rows`` kernel (interpret-mode on CPU; compiled Mosaic
    on TPU) — same results, asserted in tests/test_kernels_integration."""
    outs_i, outs_d = [], []
    qn = queries.shape[0]
    for s in range(0, qn, query_chunk):
        q = queries[s : s + query_chunk]
        pad = 0
        if q.shape[0] < query_chunk and qn > query_chunk:
            pad = query_chunk - q.shape[0]
            q = jnp.pad(q, ((0, pad), (0, 0)))
        ids, dists = _search_chunk(index, q, params, forest_cfg, use_kernels)
        if pad:
            ids, dists = ids[:-pad], dists[:-pad]
        outs_i.append(ids)
        outs_d.append(dists)
    return jnp.concatenate(outs_i), jnp.concatenate(outs_d)


def _search_chunk(index, queries, params, forest_cfg, use_kernels=False):
    f = index.forest
    qn = queries.shape[0]
    qsk = sketch.make_sketches(index.quant, queries)
    best_pos = jnp.full((qn, params.k2), -1, jnp.int32)
    best_dist = jnp.full((qn, params.k2), _INF, jnp.int32)
    for t in range(f.n_trees):
        best_pos, best_dist = _stage1_tree_merge(
            queries, qsk, best_pos, best_dist,
            f.orders[t], f.directories[t], f.lo, f.hi, f.perms[t], f.flips[t],
            index.master_rank, index.sketches_master,
            bits=forest_cfg.bits, key_bits=forest_cfg.key_bits,
            leaf_size=forest_cfg.leaf_size, k1=params.k1, k2=params.k2,
            use_kernels=use_kernels,
        )
    return _stage2_expand_rank(
        queries, best_pos, index.codes_master, index.master_order, index.quant,
        h=params.h, k=params.k,
    )
