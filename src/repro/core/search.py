"""Algorithm 1 jitted stages: approximate k-NN search with a Hilbert forest.

.. note::
   The public entry point is :class:`repro.index.HilbertIndex` — a
   self-describing facade that carries its build config, so search never
   takes a config argument.  This module now holds the **pure jitted
   stages** the facade composes, plus thin deprecation shims
   (:func:`build_index` / :func:`search`) for one release of backward
   compatibility.

Pipeline (paper §3.1): forest candidates (coarse) → Hamming filter on shared
sketches (fine) → master-order ±h expansion → asymmetric fp32-vs-4-bit
distance → top-k.

Implementation notes vs the pseudocode:
  * The paper first collects ALL n·k1 candidates per query, then filters.
    At challenge scale that transient alone is ~9 GB; we instead keep a
    running sketch-filtered top-k2 and merge each tree's k1 candidates into
    it — identical result (top-k2 of a union is associative), constant
    memory, and the same trick the paper itself uses for Task 2.
  * Candidates are tracked by their **master-order position** so stage 2 is
    a contiguous ±h window and all gathers hit the master-rearranged arrays
    (the paper's memory-locality trick; on TPU this turns into coalesced
    gathers over the sorted copies).
  * Duplicates (same point from several trees / overlapping windows) are
    deduped during the merge so the final top-k can't contain repeats.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import forest as forest_lib
from repro.core import quantize, sketch
from repro.core.types import ForestConfig, QuantizerConfig, SearchParams

__all__ = [
    "HilbertForestIndex",
    "build_index",
    "search",
    "hilbert_master_sort",
    "stage1_tree_merge",
    "stage2_expand_rank",
    "brute_force_topk",
]

_INF = jnp.int32(2**30)


class HilbertForestIndex(NamedTuple):
    """DEPRECATED legacy container — use :class:`repro.index.HilbertIndex`.

    Carries no config, so callers of the legacy :func:`search` must re-supply
    the exact build-time ``ForestConfig`` (the footgun the facade removes).
    """

    forest: forest_lib.HilbertForest
    quant: quantize.Quantizer
    codes_master: jax.Array  # (n, d) uint8, master-order layout
    sketches_master: jax.Array  # (n, Ws) uint32, master-order layout
    master_order: jax.Array  # (n,) int32: position -> point id
    master_rank: jax.Array  # (n,) int32: point id -> position

    @property
    def n_points(self) -> int:
        return self.master_order.shape[0]

    def memory_report(self) -> dict:
        """Bytes by component, mirroring the paper's RAM budget table."""
        d = self.codes_master.shape[1]
        packed_codes = self.n_points * (-(-d // 8)) * 4  # 4-bit packed
        sketches = int(np.prod(self.sketches_master.shape)) * 4
        shared = self.n_points * (-(-d // 32)) * 4  # MSB plane counted once
        return {
            "forest_bytes": self.forest.memory_bytes(),
            "sketch_bytes": sketches,
            "quantized_bytes": packed_codes,
            "shared_bit_savings": shared,
            "combined_stage2_bytes": sketches + packed_codes - shared,
        }


@functools.partial(jax.jit, static_argnames=("cfg",))
def hilbert_master_sort(points, cfg: ForestConfig, lo, hi):
    """Un-permuted Hilbert sort defining the master order (pure stage)."""
    from repro.core import hilbert

    return hilbert.hilbert_sort(
        points, bits=cfg.bits, key_bits=cfg.key_bits, lo=lo, hi=hi
    )


def _merge_topk_dedup(best_pos, best_dist, new_pos, new_dist, k: int):
    """Merge candidate sets keyed by position; dedup; keep k smallest dists."""
    pos = jnp.concatenate([best_pos, new_pos], axis=1)
    dist = jnp.concatenate([best_dist, new_dist], axis=1)
    # Dedup: sort by position; equal-adjacent entries are duplicates (same
    # position ⇒ same sketch ⇒ same distance), mask all but the first.
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    dist_s = jnp.take_along_axis(dist, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    dist_s = jnp.where(dup, _INF, dist_s)
    neg, idx = lax.top_k(-dist_s, k)
    return jnp.take_along_axis(pos_s, idx, axis=1), -neg


@functools.partial(
    jax.jit, static_argnames=("bits", "key_bits", "leaf_size", "k1", "k2",
                              "use_kernels")
)
def stage1_tree_merge(
    queries,
    qsketches,
    best_pos,
    best_dist,
    order,
    directory,
    lo,
    hi,
    perm,
    flip,
    master_rank,
    sketches_master,
    *,
    bits,
    key_bits,
    leaf_size,
    k1,
    k2,
    use_kernels=False,
):
    """One tree's stage-1: candidates → Hamming filter → merge into top-k2."""
    cand_ids = forest_lib.tree_candidates(
        queries, order, directory, lo, hi, perm, flip,
        bits=bits, key_bits=key_bits, leaf_size=leaf_size, k1=k1,
    )  # (Q, k1)
    mpos = master_rank[cand_ids]  # (Q, k1) master positions
    csk = sketches_master[mpos]  # (Q, k1, Ws)
    if use_kernels:
        from repro.kernels.hamming import hamming_rows

        hd = hamming_rows(qsketches, csk, use_kernel=True)  # (Q, k1)
    else:
        hd = sketch.hamming_distance(qsketches[:, None, :], csk)  # (Q, k1)
    return _merge_topk_dedup(best_pos, best_dist, mpos, hd, k2)


@functools.partial(jax.jit, static_argnames=("h", "k"))
def stage2_expand_rank(
    queries, best_pos, codes_master, master_order, quant, *, h, k
):
    """±h master-order expansion, dedup, exact ADC distance, final top-k."""
    n = master_order.shape[0]
    deltas = jnp.arange(-h, h + 1, dtype=jnp.int32)
    pos = best_pos[:, :, None] + deltas[None, None, :]
    pos = jnp.clip(pos, 0, n - 1).reshape(best_pos.shape[0], -1)  # (Q, C)
    # Invalid slots (pos was -1 sentinel) clip to >=0; mask them via best_pos.
    valid = (best_pos >= 0)[:, :, None].astype(jnp.int32)
    valid = jnp.broadcast_to(valid, (best_pos.shape[0], best_pos.shape[1], 2 * h + 1))
    valid = valid.reshape(best_pos.shape[0], -1)
    # Dedup positions.
    sort_idx = jnp.argsort(pos, axis=1)
    pos_s = jnp.take_along_axis(pos, sort_idx, axis=1)
    valid_s = jnp.take_along_axis(valid, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(pos_s[:, :1], bool), pos_s[:, 1:] == pos_s[:, :-1]], axis=1
    )
    keep = (~dup) & (valid_s == 1)

    codes = codes_master[pos_s]  # (Q, C, d) uint8
    d2 = quantize.adc_distance(quant, queries, codes)  # (Q, C) fp32
    d2 = jnp.where(keep, d2, jnp.inf)
    neg, idx = lax.top_k(-d2, k)
    final_pos = jnp.take_along_axis(pos_s, idx, axis=1)
    return master_order[final_pos], -neg


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force_topk(queries, points, valid, *, k):
    """Exact squared-L2 top-k against a small point set (pure stage).

    The mutable index's write buffer is searched this way: ``points`` is the
    fixed-capacity buffer (so the jit cache is stable across fills) and
    ``valid`` masks dead / unfilled rows to +inf.  Uses the Gram expansion
    ||q-p||^2 = ||q||^2 - 2<q,p> + ||p||^2 so the transient is (Q, B), not
    (Q, B, d).  Returns (row indices into ``points`` (Q, k), d2 (Q, k));
    masked rows surface as d2 = +inf.
    """
    qq = jnp.sum(queries * queries, axis=1)[:, None]
    pp = jnp.sum(points * points, axis=1)[None, :]
    d2 = jnp.maximum(qq - 2.0 * (queries @ points.T) + pp, 0.0)
    d2 = jnp.where(valid[None, :], d2, jnp.inf)
    neg, idx = lax.top_k(-d2, k)
    return idx, -neg


# ---------------------------------------------------------------------------
# Deprecation shims (one release): delegate to repro.index.HilbertIndex so
# old callers get bit-identical results from the same jitted stages.
# ---------------------------------------------------------------------------


def build_index(
    points: jax.Array,
    forest_cfg: ForestConfig,
    quant_cfg: QuantizerConfig = QuantizerConfig(),
) -> HilbertForestIndex:
    """DEPRECATED: use ``repro.index.HilbertIndex.build(points, cfg)``."""
    warnings.warn(
        "repro.core.search.build_index is deprecated; use "
        "repro.index.HilbertIndex.build(points, IndexConfig(...))",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import HilbertIndex, IndexConfig

    idx = HilbertIndex.build(
        points,
        IndexConfig(forest=forest_cfg, quantizer=quant_cfg, store_points=False),
    )
    return HilbertForestIndex(
        forest=idx.forest,
        quant=idx.quant,
        codes_master=idx.codes_master,
        sketches_master=idx.sketches_master,
        master_order=idx.master_order,
        master_rank=idx.master_rank,
    )


def search(
    index: HilbertForestIndex,
    queries: jax.Array,
    params: SearchParams,
    forest_cfg: ForestConfig,
    query_chunk: int = 2048,
    use_kernels: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """DEPRECATED: use ``repro.index.HilbertIndex.search(queries, params)``.

    This legacy entry point requires re-supplying the build-time
    ``forest_cfg``; a mismatch silently corrupts results.  The facade stores
    the config on the index and removes the argument entirely.
    """
    warnings.warn(
        "repro.core.search.search is deprecated; use "
        "repro.index.HilbertIndex.search(queries, params) — the index "
        "carries its own config",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.index import HilbertIndex, IndexConfig

    idx = HilbertIndex(
        config=IndexConfig(
            forest=forest_cfg,
            quantizer=QuantizerConfig(bits=index.quant.bits),
            store_points=False,
        ),
        forest=index.forest,
        quant=index.quant,
        codes_master=index.codes_master,
        sketches_master=index.sketches_master,
        master_order=index.master_order,
        master_rank=index.master_rank,
        points=None,
    )
    return idx.search(
        queries,
        params,
        backend="pallas" if use_kernels else "xla",
        query_chunk=query_chunk,
    )
