"""Algorithm 2 jitted stages: approximate k-NN graph construction (Task 2).

.. note::
   The public entry point is ``repro.index.HilbertIndex.knn_graph(params)``,
   which **reuses the already-fit quantizer/codes/sketches** of a built
   index instead of re-fitting.  This module holds the pure pipeline
   (:func:`knn_graph_from_sketches`) the facade consumes, plus a
   deprecation shim (:func:`build_knn_graph`) for one release.

Every point is a query, so no tree/binary-search is needed: a point's
stage-1 candidates are its ±k1/2 rank-neighbors in each Hilbert order, and
an order can be discarded as soon as its candidates are merged — memory is
constant in the number of orders (paper §4.1: "memory consumption remains
constant, with only the computation time increasing").

As in :mod:`repro.core.search` we merge each order's candidates into a
running sketch-filtered top-k2 (associative, exact) instead of materializing
all n·k1 candidates (which would be ~92 GB at challenge scale).

Unlike Algorithm 1's serving path, graph construction never touches the
quantized codes (the final re-rank is exact fp32 against the stored
points), so it is unaffected by the packed-resident code layout the search
path moved to — only the shared sketches flow in from the index.
"""

from __future__ import annotations

import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import hilbert, quantize, sketch
from repro.core.types import ForestConfig, GraphParams, QuantizerConfig

__all__ = ["build_knn_graph", "knn_graph_from_sketches"]

_INF = jnp.int32(2**30)


@functools.partial(jax.jit, static_argnames=("bits", "key_bits"))
def order_and_rank(points, lo, hi, perm, flip, *, bits, key_bits):
    """One Hilbert order + its inverse rank (pure stage)."""
    order, _ = hilbert.hilbert_sort(
        points, bits=bits, key_bits=key_bits, lo=lo, hi=hi, perm=perm, flip=flip
    )
    n = order.shape[0]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    return order, rank


@functools.partial(jax.jit, static_argnames=("k1", "k2"))
def merge_order(best_id, best_dist, order, rank, sketches, *, k1, k2):
    """Merge one Hilbert order's rank-window candidates into the top-k2."""
    n = order.shape[0]
    half = k1 // 2
    # ±half window around each point's rank, self excluded by distance mask.
    deltas = jnp.concatenate(
        [jnp.arange(-half, 0, dtype=jnp.int32), jnp.arange(1, k1 - half + 1, dtype=jnp.int32)]
    )  # k1 offsets, 0 excluded
    pos = rank[:, None] + deltas[None, :]
    pos = jnp.clip(pos, 0, n - 1)
    cand = order[pos]  # (N, k1) ids
    hd = sketch.hamming_distance(sketches[:, None, :], sketches[cand])
    self_mask = cand == jnp.arange(n, dtype=jnp.int32)[:, None]
    hd = jnp.where(self_mask, _INF, hd)

    ids = jnp.concatenate([best_id, cand], axis=1)
    dist = jnp.concatenate([best_dist, hd], axis=1)
    sort_idx = jnp.argsort(ids, axis=1)
    ids_s = jnp.take_along_axis(ids, sort_idx, axis=1)
    dist_s = jnp.take_along_axis(dist, sort_idx, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[:, :1], bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=1
    )
    dist_s = jnp.where(dup, _INF, dist_s)
    neg, idx = lax.top_k(-dist_s, k2)
    return jnp.take_along_axis(ids_s, idx, axis=1), -neg


@functools.partial(jax.jit, static_argnames=("k",))
def final_select_chunk(points, best_id_chunk, row_start, *, k):
    """Exact fp32 distances to the k2 survivors; top-k (paper: top-15)."""
    cand_vecs = points[best_id_chunk]  # (C, k2, d)
    rows = row_start + jnp.arange(best_id_chunk.shape[0], dtype=jnp.int32)
    diff = points[rows][:, None, :] - cand_vecs
    d2 = jnp.sum(diff * diff, axis=-1)
    d2 = jnp.where(best_id_chunk < 0, jnp.inf, d2)
    d2 = jnp.where(best_id_chunk == rows[:, None], jnp.inf, d2)
    neg, idx = lax.top_k(-d2, k)
    return jnp.take_along_axis(best_id_chunk, idx, axis=1), -neg


def knn_graph_from_sketches(
    points: jax.Array,
    sketches: jax.Array,
    params: GraphParams,
    *,
    bits: int,
    key_bits: int,
    lo: jax.Array,
    hi: jax.Array,
    chunk: int = 1 << 16,
) -> Tuple[jax.Array, jax.Array]:
    """Full Algorithm-2 pipeline over pre-computed sketches (pure function).

    ``sketches`` must be in point-id order (row i = point i).  Both the
    facade (which reuses the index's fitted sketches) and the legacy shim
    (which fits its own) funnel through here, so results are bit-identical.
    """
    n, d = points.shape
    rng = np.random.default_rng(params.seed)
    best_id = jnp.full((n, params.k2), -1, jnp.int32)
    best_dist = jnp.full((n, params.k2), _INF, jnp.int32)
    for _ in range(params.n_orders):
        perm = jnp.asarray(rng.permutation(d).astype(np.int32))
        flip = jnp.asarray(rng.integers(0, 2, d).astype(bool))
        order, rank = order_and_rank(
            points, lo, hi, perm, flip, bits=bits, key_bits=key_bits
        )
        best_id, best_dist = merge_order(
            best_id, best_dist, order, rank, sketches, k1=params.k1, k2=params.k2
        )
    # Final exact selection, chunked over points to bound the (N, k2, d)
    # gather transient.
    ids_out, d_out = [], []
    for s in range(0, n, chunk):
        ids_c, d_c = final_select_chunk(
            points, best_id[s : s + chunk], s, k=params.k
        )
        ids_out.append(ids_c)
        d_out.append(d_c)
    return jnp.concatenate(ids_out), jnp.concatenate(d_out)


def build_knn_graph(
    points: jax.Array,
    params: GraphParams,
    quant_cfg: QuantizerConfig = QuantizerConfig(),
    forest_cfg: ForestConfig = ForestConfig(),
    chunk: int = 1 << 16,
) -> Tuple[jax.Array, jax.Array]:
    """DEPRECATED: use ``repro.index.HilbertIndex.build(...).knn_graph(...)``.

    Re-fits a quantizer/sketches from scratch on every call; the facade
    reuses the ones already fitted at index build time.
    """
    warnings.warn(
        "repro.core.knn_graph.build_knn_graph is deprecated; use "
        "repro.index.HilbertIndex.knn_graph(params), which reuses the "
        "index's fitted quantizer/sketches",
        DeprecationWarning,
        stacklevel=2,
    )
    quant = quantize.fit(points, bits=quant_cfg.bits, sample_limit=quant_cfg.sample_limit)
    codes = quantize.encode(quant, points)
    sketches = sketch.sketches_from_codes(codes, bits=quant_cfg.bits)
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    return knn_graph_from_sketches(
        points, sketches, params,
        bits=forest_cfg.bits, key_bits=forest_cfg.key_bits, lo=lo, hi=hi,
        chunk=chunk,
    )
