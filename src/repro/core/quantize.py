"""4-bit quantile quantization with the paper's shared sketch bit.

The paper compresses 23M fp32 vectors (36 GB) to 4-bit codes, and shares one
bit between the code and the 384-bit sketch, for a combined 4.5 GB.  The
sharing pins the construction: the code's MSB must *be* the sketch bit, i.e.
the per-dimension median threshold.  We therefore fit a per-dimension
16-level **quantile** grid (cell boundaries at quantiles k/16), so that
``code >= 8  <=>  x >= median``.

Queries are never quantized (paper §3.1): final distances are asymmetric —
fp32 query against dequantized (centroid) database vectors.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Quantizer",
    "fit",
    "encode",
    "decode",
    "adc_distance",
    "adc_distance_packed",
    "pack_codes",
    "unpack_codes",
]


class Quantizer(NamedTuple):
    """Per-dim quantile grid.

    boundaries: (d, L-1) float32 — interior cell boundaries (quantiles k/L).
    centroids: (d, L) float32 — per-cell reconstruction values.
    """

    boundaries: jax.Array
    centroids: jax.Array

    @property
    def bits(self) -> int:
        return int(np.log2(self.centroids.shape[1]))


def fit(data: jax.Array, bits: int = 4, sample_limit: int = 262144) -> Quantizer:
    """Fit per-dimension quantile boundaries/centroids on (a sample of) data."""
    n = data.shape[0]
    if n > sample_limit:
        idx = np.random.default_rng(0).choice(n, sample_limit, replace=False)
        data = data[jnp.asarray(idx)]
    levels = 1 << bits
    qs_b = jnp.arange(1, levels) / levels
    qs_c = (jnp.arange(levels) + 0.5) / levels
    boundaries = jnp.quantile(data, qs_b, axis=0).T.astype(jnp.float32)  # (d, L-1)
    centroids = jnp.quantile(data, qs_c, axis=0).T.astype(jnp.float32)  # (d, L)
    return Quantizer(boundaries, centroids)


@jax.jit
def encode(quant: Quantizer, x: jax.Array) -> jax.Array:
    """Quantize (n, d) floats to (n, d) uint8 codes in [0, 2**bits).

    ``code = #{boundaries < x}`` — a handful of vectorized compares instead of
    a per-row searchsorted (bits=4 -> 15 compares; VPU-trivial).
    """
    # (n, d, L-1) broadcast compare, summed over cells.
    code = jnp.sum(
        x[:, :, None] >= quant.boundaries[None, :, :], axis=-1, dtype=jnp.int32
    )
    return code.astype(jnp.uint8)


@jax.jit
def decode(quant: Quantizer, codes: jax.Array) -> jax.Array:
    """Reconstruct (n, d) float32 from uint8 codes via centroid lookup."""
    return jax.vmap(
        lambda c: jnp.take_along_axis(
            quant.centroids, c[:, None].astype(jnp.int32), axis=1
        )[:, 0]
    )(codes)


@jax.jit
def adc_distance(quant: Quantizer, queries: jax.Array, codes: jax.Array) -> jax.Array:
    """Asymmetric squared-L2: fp32 queries (q, d) vs codes (q, c, d).

    Dequantizes codes to centroids and computes ``sum((q - r)^2)`` — the
    MXU-friendly TPU formulation (vs the CPU per-dim LUT gather).  The Pallas
    kernel in ``repro.kernels.qdist`` implements the same contract.
    """
    recon = jax.vmap(jax.vmap(
        lambda c: jnp.take_along_axis(quant.centroids, c[:, None].astype(jnp.int32), axis=1)[:, 0]
    ))(codes)  # (q, c, d)
    diff = queries[:, None, :] - recon
    return jnp.sum(diff * diff, axis=-1)


def pack_codes(codes: jax.Array) -> jax.Array:
    """Pack (n, d) 4-bit codes into (n, ceil(d/8)) uint32 words.

    This is the **resident** representation: the paper budgets 23M x 384 x
    4 bit = 4.4 GB (MSB shared with the sketch), and :class:`HilbertIndex`
    stores ``codes_master`` in exactly this layout — half the RAM and HBM
    traffic of unpacked uint8.  The qdist Pallas kernel consumes the packed
    words directly on TPU; the XLA path unpacks candidate windows on the fly
    (:func:`adc_distance_packed`), which is lossless and therefore
    bit-identical to computing on unpacked codes.
    """
    n, d = codes.shape
    pad = (-d) % 8
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
    c = codes.reshape(n, -1, 8).astype(jnp.uint32)
    shifts = jnp.arange(8, dtype=jnp.uint32) * 4
    return jnp.sum(c << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_codes(packed: jax.Array, d: int) -> jax.Array:
    """Inverse of :func:`pack_codes` (lossless; works on any leading shape).

    ``packed`` is (..., W) uint32; returns (..., d) uint8.
    """
    w = packed.shape[-1]
    shifts = jnp.arange(8, dtype=jnp.uint32) * 4
    c = (packed[..., None] >> shifts) & jnp.uint32(0xF)
    return c.reshape(*packed.shape[:-1], w * 8)[..., :d].astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("d",))
def adc_distance_packed(
    quant: Quantizer, queries: jax.Array, packed: jax.Array, *, d: int
) -> jax.Array:
    """:func:`adc_distance` on nibble-packed candidate codes (q, c, W).

    Unpacks to uint8 and reuses :func:`adc_distance`, so the result is
    **bit-identical** to the unpacked path (pack/unpack is lossless).  The
    TPU serving path instead feeds the packed words straight to the Pallas
    kernel (``repro.kernels.qdist.qdist_windows_from_packed``), trading bit
    identity for the 0.5 B/dim HBM roofline.
    """
    return adc_distance(quant, queries, unpack_codes(packed, d))
