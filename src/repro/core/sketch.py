"""Binary sketches = the shared MSBs of the 4-bit quantizer (paper §3.1).

For d=384 dims the sketch is exactly 384 bits: bit i is ``x_i >= median_i``,
which is also the MSB of dimension i's 4-bit code — "one bit is shared with
the sketch".  Hamming distance = XOR + popcount over packed uint32 lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.quantize import Quantizer

__all__ = ["sketch_words", "make_sketches", "sketches_from_codes", "hamming_distance"]


def sketch_words(d: int) -> int:
    return -(-d // 32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack (n, d) {0,1} into (n, ceil(d/32)) uint32, bit 31 of word 0 first."""
    n, d = bits.shape
    w = sketch_words(d)
    pad = w * 32 - d
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    b = bits.reshape(n, w, 32).astype(jnp.uint32)
    shifts = (31 - jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(b << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


@jax.jit
def make_sketches(quant: Quantizer, x: jax.Array) -> jax.Array:
    """Sketch fp vectors directly: bit i = x_i >= median_i (packed uint32)."""
    levels = quant.centroids.shape[1]
    median = quant.boundaries[:, levels // 2 - 1]  # quantile 1/2
    return pack_bits((x >= median[None, :]).astype(jnp.uint32))


@jax.jit
def sketches_from_codes(codes: jax.Array, bits: int = 4) -> jax.Array:
    """Sketch = code MSB (the shared bit); exact alias of make_sketches."""
    msb = (codes >= (1 << (bits - 1))).astype(jnp.uint32)
    return pack_bits(msb)


@jax.jit
def hamming_distance(a: jax.Array, b: jax.Array) -> jax.Array:
    """Hamming distance between packed sketches.

    a: (..., W) uint32, b: (..., W) uint32 (broadcastable) -> (...) int32.
    The Pallas kernel in ``repro.kernels.hamming`` implements the batched
    (Q, C) contract; this jnp form is the oracle and the CPU path.
    """
    x = jnp.bitwise_xor(a, b)
    return jnp.sum(lax.population_count(x).astype(jnp.int32), axis=-1)
