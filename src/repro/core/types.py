"""Shared config dataclasses for the Hilbert forest core."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    """Hilbert forest shape.

    Attributes:
      n_trees: number of Hilbert trees (paper: ``n``; Task 1 used up to 160).
      bits: grid bits per axis for the curve (curve depth).
      key_bits: truncated Hilbert-key width in bits (packed to uint32 words).
      leaf_size: points per compressed-tree leaf (paper: ~100); the rank
        directory stores every ``leaf_size``-th key.
      seed: PRNG seed for per-tree axis permutations/reflections.
    """

    n_trees: int = 16
    bits: int = 4
    key_bits: int = 128
    leaf_size: int = 100
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class QuantizerConfig:
    """4-bit shared-MSB quantizer (paper §3.1).

    ``bits=4`` gives 16 quantile cells per dim whose upper half starts at the
    median — the code MSB doubles as the sketch bit ("one bit is shared").
    """

    bits: int = 4
    sample_limit: int = 262144  # quantile-fit subsample


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Algorithm 1 hyper-parameters (paper Table 1 names)."""

    k1: int = 64  # candidates per query per tree
    k2: int = 128  # sketch-filter survivors
    h: int = 2  # master-order expansion half-width
    k: int = 30  # final neighbors returned


@dataclasses.dataclass(frozen=True)
class GraphParams:
    """Algorithm 2 hyper-parameters (paper Table 2 names)."""

    n_orders: int = 80
    k1: int = 96
    k2: int = 60
    k: int = 15
    seed: int = 0
