"""Deterministic fault injection for durability testing.

See :mod:`repro.testing.faults` for the injection-point API and
``docs/DURABILITY.md`` for the catalog of registered points.
"""

from .faults import (  # noqa: F401
    FaultInjected,
    fault_point,
    install_plan,
    parse_plan,
    registered_points,
    reset,
)
