"""Deterministic, addressable fault injection points.

Durability code is only trustworthy if the crashes it claims to survive
can actually be produced, at exactly the instants that matter: between a
payload write and its manifest commit, between a rename and the parent
directory fsync, mid-way through a WAL append.  This module provides
named *injection points* that production code threads through those
instants::

    from repro.testing.faults import fault_point
    ...
    fault_point("ckpt.manifest.pre_rename", path=tmp_manifest)

A point is a no-op (one dict lookup) unless a *fault plan* is active, so
the call sites stay in the production path permanently — the tested
protocol IS the shipped protocol, with no test-only forks.

Fault plans
-----------
A plan maps point names to an action, armed on the point's N-th hit
(1-based, default 1).  Plans come from the environment — the subprocess
crash matrix in ``scripts/crash_check.py`` sets them per child — or from
:func:`install_plan` for in-process tests::

    REPRO_FAULTS="wal.append.post_write@3=kill;ckpt.manifest.pre_rename=raise"

Actions:

``raise``
    Raise :class:`FaultInjected` (an ``IOError`` subclass), as if the
    underlying syscall failed.
``kill``
    ``SIGKILL`` the current process — no atexit, no flushing, the
    closest userspace approximation of a power cut.
``torn:N``
    Truncate the point's ``path`` to ``N`` bytes, then ``SIGKILL``: a
    write that only partially reached the disk before the crash.
``bitflip``
    Flip one bit in the middle of ``path`` and *continue silently* —
    bit-rot.  Detection must come from CRCs/digests, not from errors.

Tracing
-------
With ``REPRO_FAULT_TRACE=/path`` every hit appends one ``name`` line to
the file (opened/fsynced/closed per hit so a later ``kill`` can't lose
it).  The crash matrix runs a trace pass first to enumerate the points a
workload actually exercises, then replays it once per point with a
``kill`` armed there.
"""

from __future__ import annotations

import os
import signal
import threading
from typing import Dict, Optional, Tuple

__all__ = [
    "FaultInjected", "fault_point", "install_plan", "parse_plan",
    "registered_points", "reset",
]


class FaultInjected(IOError):
    """Raised by a ``raise``-mode fault point, as if the I/O failed."""

    def __init__(self, point: str):
        super().__init__(f"fault injected at {point!r}")
        self.point = point


# {name: (hit_number, mode)} — mode is "raise" | "kill" | "torn:N" | "bitflip"
_plan: Optional[Dict[str, Tuple[int, str]]] = None
_trace_path: Optional[str] = None
_hits: Dict[str, int] = {}
_lock = threading.Lock()
_env_loaded = False


def parse_plan(spec: str) -> Dict[str, Tuple[int, str]]:
    """Parse ``"name@hit=mode;name2=mode"`` into a plan dict."""
    plan: Dict[str, Tuple[int, str]] = {}
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        name, _, mode = part.partition("=")
        name, _, hit = name.partition("@")
        mode = mode.strip() or "raise"
        if not (mode in ("raise", "kill", "bitflip")
                or mode.startswith("torn:")):
            raise ValueError(f"unknown fault mode {mode!r} in {part!r}")
        plan[name.strip()] = (int(hit) if hit else 1, mode)
    return plan


def install_plan(plan: Optional[Dict[str, Tuple[int, str]]],
                 trace_path: Optional[str] = None) -> None:
    """Arm a fault plan in-process (tests); resets hit counters."""
    global _plan, _trace_path, _env_loaded
    with _lock:
        _plan = dict(plan) if plan else None
        _trace_path = trace_path
        _hits.clear()
        _env_loaded = True     # explicit install overrides the environment


def reset() -> None:
    """Disarm any plan and forget hit counts (environment re-read next hit)."""
    global _plan, _trace_path, _env_loaded
    with _lock:
        _plan = None
        _trace_path = None
        _hits.clear()
        _env_loaded = False


def registered_points() -> Dict[str, int]:
    """``{name: hits_so_far}`` for every point hit in this process."""
    with _lock:
        return dict(_hits)


def _load_env_locked() -> None:
    global _plan, _trace_path, _env_loaded
    if _env_loaded:
        return
    _env_loaded = True
    spec = os.environ.get("REPRO_FAULTS", "")
    _plan = parse_plan(spec) if spec.strip() else None
    _trace_path = os.environ.get("REPRO_FAULT_TRACE") or None


def _flip_bit(path: str) -> None:
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size == 0:
            return
        f.seek(size // 2)
        byte = f.read(1)
        f.seek(size // 2)
        f.write(bytes([byte[0] ^ 0x10]))


def fault_point(name: str, path: Optional[str] = None) -> None:
    """Declare a crash-consistency point; acts only under an armed plan.

    ``path`` names the file a ``torn:N``/``bitflip`` action corrupts;
    pass the file most recently written before this point.
    """
    with _lock:
        _load_env_locked()
        if _plan is None and _trace_path is None:
            return
        _hits[name] = hit = _hits.get(name, 0) + 1
        trace, plan = _trace_path, _plan
    if trace is not None:
        fd = os.open(trace, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, (name + "\n").encode())
            os.fsync(fd)
        finally:
            os.close(fd)
    if plan is None:
        return
    armed = plan.get(name)
    if armed is None or armed[0] != hit:
        return
    mode = armed[1]
    if mode == "raise":
        raise FaultInjected(name)
    if mode == "bitflip":
        if path is not None and os.path.exists(path):
            _flip_bit(path)
        return
    if mode.startswith("torn:"):
        n = int(mode.split(":", 1)[1])
        if path is not None and os.path.exists(path):
            fd = os.open(path, os.O_WRONLY)
            try:
                os.ftruncate(fd, n)
                os.fsync(fd)
            finally:
                os.close(fd)
    # torn falls through to kill: a torn write only exists because the
    # process died before completing it.
    os.kill(os.getpid(), signal.SIGKILL)
