"""Metrics/trace export over HTTP — stdlib only, one daemon thread.

``serve_metrics(port)`` starts a ``ThreadingHTTPServer`` exposing the
process-global registry and tracer:

* ``/metrics``      — Prometheus text exposition (scrape target);
* ``/metrics.json`` — the registry's JSON snapshot;
* ``/trace``        — Chrome-trace JSON of the tracer's span buffer
  (load in ``chrome://tracing`` or Perfetto);
* ``/healthz``      — readiness: 200 ``ok`` normally, 503 ``degraded``
  while the serving engine is in degraded read-only mode (its WAL became
  unwritable — the ``engine_degraded`` gauge).  Point the load
  balancer's write-path health check here.

Port 0 binds an ephemeral port; read it back from ``server.port``.
Wired into ``launch/serve.py --metrics-port``; scraped by the CI
serving-smoke job (``scripts/metrics_smoke.py``).
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from . import trace
from .registry import MetricsRegistry, default_registry

__all__ = ["MetricsServer", "serve_metrics"]


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry
    tracer: trace.Tracer

    def do_GET(self):  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/healthz":
            # Degraded = the engine refuses writes (WAL unwritable) but
            # keeps serving reads; a dead/absent engine's callback gauge
            # reads NaN and counts as healthy (nothing to protect).
            v = self.registry.snapshot().get("engine_degraded", 0.0)
            degraded = isinstance(v, (int, float)) and v == v and v > 0
            body = b"degraded\n" if degraded else b"ok\n"
            self.send_response(503 if degraded else 200)
            self.send_header("Content-Type", "text/plain; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
            return
        if path == "/metrics":
            body = self.registry.prometheus_text().encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/metrics.json":
            body = self.registry.to_json().encode()
            ctype = "application/json"
        elif path == "/trace":
            body = json.dumps(self.tracer.chrome_trace()).encode()
            ctype = "application/json"
        else:
            self.send_response(404)
            self.send_header("Content-Length", "0")
            self.end_headers()
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # quiet: scrapes aren't events
        pass


class MetricsServer:
    """Owns the HTTP server + its daemon thread.  ``close()`` to stop."""

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[trace.Tracer] = None):
        handler = type("Handler", (_Handler,), {
            "registry": registry or default_registry(),
            "tracer": tracer or trace.default_tracer(),
        })
        self._httpd = ThreadingHTTPServer((host, int(port)), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            daemon=True, name="repro-metrics-http",
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_metrics(port: int = 0, host: str = "127.0.0.1") -> MetricsServer:
    """Start the metrics endpoint on ``port`` (0 = ephemeral)."""
    return MetricsServer(port=port, host=host)
