"""Online recall estimation: sampled queries vs. an exact shadow.

The paper's claim is recall at a latency/memory budget; benchmarks
verify it offline, but a serving deployment needs to SEE recall while
churn reshapes the index (tombstone masking, generation inflation, and
compaction all move it).  :class:`RecallProbe` closes that loop:

* ``offer()`` — called on the serving path with a served batch's queries
  and returned ids.  A seeded coin keeps a configurable fraction; kept
  batches pin a zero-copy ``snapshot()`` of the index they were served
  against (so later writes can't skew the ground truth) and go on a
  bounded pending queue.  Cost when the coin says no: one RNG draw.
* ``score_pending()`` — called OFF the query path (the engine runs it on
  the maintenance thread): for each pending batch, extract the live
  points from the snapshot, brute-force exact top-k in float64 numpy on
  the host, and score ``|approx ∩ exact| / k`` per query.  Results feed
  a rolling window exported as the ``engine_recall_at_k`` gauge.

Ground truth needs raw points: every layout built with the default
``store_points=True`` works; with ``store_points=False`` the probe
reports nothing rather than guessing (``engine_recall_unscorable_total``
counts the skips).  Scoring cost is ``O(n_live * d)`` per sampled query
— the overhead accounting lives in docs/OBSERVABILITY.md.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from .registry import MetricsRegistry, default_registry

__all__ = [
    "RecallProbeConfig", "RecallProbe", "live_points", "exact_topk",
    "recall_at_k",
]


@dataclass(frozen=True)
class RecallProbeConfig:
    """Sampling policy for the online recall probe.

    * ``fraction`` — probability a served batch is sampled (per batch,
      not per row; a batch is scored whole).
    * ``max_pending`` — bound on unscored sampled batches; offers beyond
      it are dropped (counted), so a stalled scorer can't accumulate
      snapshots without limit.
    * ``window`` — rolling per-query recall samples retained for the
      gauge.
    * ``seed`` — sampling RNG seed (deterministic probes in tests).
    """

    fraction: float = 0.05
    max_pending: int = 8
    window: int = 512
    seed: int = 0

    def __post_init__(self):
        if not (0.0 <= self.fraction <= 1.0):
            raise ValueError(f"fraction must be in [0, 1], got {self.fraction}")
        if self.max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {self.max_pending}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")


def live_points(index: Any) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """``(ids, points)`` of every live row in ``index``, host-side.

    Handles all four facades.  Returns ``None`` when the layout cannot
    produce exact ground truth (points not stored).  Ids are unique
    across LSM generations — re-inserts get fresh sequential ids, so no
    cross-generation shadowing/dedup is needed.
    """
    # Sharded-mutable: per-generation per-shard owned rows + buffers,
    # tombstone-masked.  (Checked before the static facades because it
    # is not a subclass of either.)
    if hasattr(index, "_owned_rows"):
        alive = index._lsm.alive
        ids_parts: List[np.ndarray] = []
        pts_parts: List[np.ndarray] = []
        for seg in index.segments:
            if seg.points is None:
                return None
            for s in range(index.n_shards):
                ids, pts = index._owned_rows(seg, s)
                keep = alive[ids]
                ids_parts.append(ids[keep])
                pts_parts.append(pts[keep])
        if index._buf_count is not None:
            for s in range(index.n_shards):
                c = int(index._buf_count[s])
                if c == 0:
                    continue
                bids = index._buf_ids[s, :c]
                keep = alive[bids]
                ids_parts.append(bids[keep])
                pts_parts.append(index._buf_pts[s, :c][keep])
        return _cat(ids_parts, pts_parts)

    # Single-device mutable: sealed segments + write buffer, masked.
    if hasattr(index, "_buf_points"):
        alive = index._alive
        ids_parts, pts_parts = [], []
        for seg in index.segments:
            if seg.index.points is None:
                return None
            ids = np.asarray(seg.ids)
            keep = alive[ids]
            ids_parts.append(ids[keep])
            pts_parts.append(np.asarray(seg.index.points)[keep])
        if index._buf_count:
            bids = index._buf_ids[: index._buf_count]
            keep = alive[bids]
            ids_parts.append(bids[keep])
            pts_parts.append(index._buf_points[: index._buf_count][keep])
        return _cat(ids_parts, pts_parts)

    # Sharded static: per-shard valid rows.
    if hasattr(index, "stack"):
        if index.points is None:
            return None
        ids_parts, pts_parts = [], []
        id_map = np.asarray(index.stack.id_map)
        pts = np.asarray(index.points)
        for s in range(id_map.shape[0]):
            nv = int(index.n_valid[s])
            ids_parts.append(id_map[s, :nv])
            pts_parts.append(pts[s, :nv])
        return _cat(ids_parts, pts_parts)

    # Static single-device: row i IS external id i.
    if hasattr(index, "n_points"):
        if index.points is None:
            return None
        pts = np.asarray(index.points)
        return np.arange(pts.shape[0], dtype=np.int64), pts

    return None


def _cat(ids_parts, pts_parts) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    if not ids_parts:
        return None
    ids = np.concatenate(ids_parts).astype(np.int64)
    pts = np.concatenate(pts_parts).astype(np.float32)
    if ids.size == 0:
        return None
    return ids, pts


def exact_topk(queries: np.ndarray, ids: np.ndarray, pts: np.ndarray,
               k: int) -> np.ndarray:
    """Exact L2 top-k ids per query, float64 host math.  (q, k) int64.

    Rows beyond the live count are ``-1`` (matches the facades' padding
    convention).
    """
    q = np.asarray(queries, np.float64)
    p = np.asarray(pts, np.float64)
    # ||q - p||^2 expanded; exact enough in f64 for ranking ground truth
    d2 = (
        (q * q).sum(1)[:, None] - 2.0 * (q @ p.T) + (p * p).sum(1)[None, :]
    )
    kk = min(k, ids.size)
    part = np.argpartition(d2, kk - 1, axis=1)[:, :kk]
    order = np.take_along_axis(d2, part, axis=1).argsort(1, kind="stable")
    top = np.take_along_axis(part, order, axis=1)
    out = np.full((q.shape[0], k), -1, np.int64)
    out[:, :kk] = ids[top]
    return out


def recall_at_k(approx_ids: np.ndarray, exact_ids: np.ndarray) -> np.ndarray:
    """Per-query ``|approx ∩ exact| / k`` (k = exact id columns)."""
    a = np.asarray(approx_ids)
    e = np.asarray(exact_ids)
    k = e.shape[1]
    out = np.zeros((a.shape[0],), np.float64)
    for i in range(a.shape[0]):
        ea = set(int(x) for x in e[i] if x >= 0)
        aa = set(int(x) for x in a[i] if x >= 0)
        out[i] = len(ea & aa) / max(k, 1)
    return out


class RecallProbe:
    """Sampled online recall@k against an exact brute-force shadow."""

    def __init__(self, config: Optional[RecallProbeConfig] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.config = config or RecallProbeConfig()
        reg = registry or default_registry()
        self._rng = np.random.RandomState(self.config.seed)
        self._lock = threading.Lock()
        self._pending: deque = deque()
        self._window: deque = deque(maxlen=self.config.window)
        self._sampled = reg.counter("engine_recall_batches_sampled_total")
        self._dropped = reg.counter("engine_recall_batches_dropped_total")
        self._unscorable = reg.counter("engine_recall_unscorable_total")
        self._samples = reg.counter("engine_recall_samples_total")
        self._gauge = reg.gauge("engine_recall_at_k", fn=self.recall)
        self._pending_gauge = reg.gauge(
            "engine_recall_pending_batches", fn=lambda: len(self._pending)
        )

    def offer(self, queries: np.ndarray, ids: np.ndarray, k: int,
              index: Any) -> bool:
        """Maybe sample a served batch.  Serving-path cost: one RNG draw.

        Call with the index the batch was actually served against (the
        engine passes its checked-out epoch's index).  A kept batch pins
        a zero-copy snapshot when the index supports one — mutable
        layouts keep mutating after we return — and the index itself
        when static (immutable by construction).
        """
        with self._lock:
            if self._rng.random_sample() >= self.config.fraction:
                return False
            if len(self._pending) >= self.config.max_pending:
                self._dropped.inc()
                return False
            shadow = index.snapshot() if hasattr(index, "snapshot") else index
            self._pending.append(
                (np.asarray(queries).copy(), np.asarray(ids).copy(),
                 int(k), shadow)
            )
        self._sampled.inc()
        return True

    def score_pending(self) -> int:
        """Score every pending batch (call OFF the query path).

        Returns the number of per-query recall samples produced.
        """
        scored = 0
        while True:
            with self._lock:
                if not self._pending:
                    return scored
                queries, ids, k, shadow = self._pending.popleft()
            truth = live_points(shadow)
            if truth is None:
                self._unscorable.inc()
                continue
            exact = exact_topk(queries, truth[0], truth[1], k)
            r = recall_at_k(ids, exact)
            with self._lock:
                self._window.extend(float(x) for x in r)
            self._samples.inc(r.size)
            scored += int(r.size)

    def recall(self) -> float:
        """Rolling mean recall@k over the window (nan before any sample)."""
        with self._lock:
            if not self._window:
                return float("nan")
            return float(np.mean(self._window))
