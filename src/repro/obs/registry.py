"""Process-global metrics registry: counters, gauges, latency recorders.

One registry per process (``default_registry()``) collects every metric
the library emits — engine lifecycle counters, per-site dispatch and
recompile counters, LSM gauges, latency recorders — and exports them two
ways: a JSON ``snapshot()`` for programmatic consumers (benchmarks, the
``/metrics.json`` endpoint) and Prometheus text exposition
(``prometheus_text()``) for scraping via ``launch/serve.py
--metrics-port``.

Naming follows Prometheus convention: ``snake_case``, counters end in
``_total``, label sets are written ``name{key="value"}``.  Metrics are
get-or-create: ``registry.counter("x")`` returns the same object on
every call, so instrumentation sites don't coordinate creation order.

``LatencyRecorder`` keeps raw samples (bounded ring) so percentiles are
exact over the retained window rather than histogram-bucketed — tail
latency (p999) is the whole point of the serving engine, so the last
thing the metrics layer should do is quantize it away.  It lives here
(not ``serve/metrics.py``) because core/index instrumentation needs it
without importing the serving layer; ``serve.metrics`` re-exports it.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "percentile_label", "percentiles", "Counter", "Gauge",
    "LatencyRecorder", "MetricsRegistry", "default_registry",
]


def percentile_label(p: float) -> str:
    """Stable metric-key label for a percentile point.

    Integral points keep their value (``50 -> "p50"``); fractional
    points drop the dot so the label stays a valid identifier/JSON key
    with a fixed reading — digits after the implied two-integer-digit
    prefix are fraction digits (``99.9 -> "p999"``, ``99.99 -> "p9999"``,
    ``99.5 -> "p995"``).  This generalizes the old special-cased
    ``"p99.9" -> "p999"`` replace, which collapsed e.g. 9.99 and 99.9
    onto the same label only by luck of the inputs used.
    """
    return f"p{p:g}".replace(".", "")


def percentiles(samples_ms, points=(50.0, 99.0, 99.9)) -> Dict[str, float]:
    """``{"p50": ..., "p99": ..., "p999": ...}`` over a sample list (ms).

    Uses the nearest-rank method on the sorted samples (what a latency SLO
    means operationally); returns an empty dict for no samples.
    """
    s = np.sort(np.asarray(list(samples_ms), np.float64))
    if s.size == 0:
        return {}
    out = {}
    for p in points:
        idx = min(s.size - 1, int(np.ceil(p / 100.0 * s.size)) - 1)
        out[percentile_label(p)] = float(s[max(idx, 0)])
    return out


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{v}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


class Counter:
    """Monotonic integer counter.  ``inc()`` is one locked add."""

    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = dict(labels)
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, by: int = 1) -> None:
        with self._lock:
            self._v += int(by)

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Gauge:
    """Point-in-time value: either set explicitly or computed on read.

    A callback gauge (``fn=``) is evaluated at snapshot time — the right
    shape for values that already live somewhere (queue depth, segment
    count): no write on the hot path, always current at scrape.  A
    callback that raises reports ``nan`` rather than poisoning the
    snapshot (the gauge's owner may have been torn down).
    """

    __slots__ = ("name", "labels", "_v", "_fn", "_lock")

    def __init__(self, name: str, labels: Dict[str, str],
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = dict(labels)
        self._v = 0.0
        self._fn = fn
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._v = float(value)

    @property
    def value(self) -> float:
        fn = self._fn
        if fn is not None:
            try:
                return float(fn())
            except Exception:
                return float("nan")
        with self._lock:
            return self._v


class LatencyRecorder:
    """Bounded ring of latency samples with exact percentile snapshots."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._cap = int(capacity)
        self._buf = np.zeros((self._cap,), np.float64)
        self._n = 0          # total ever recorded
        self._lock = threading.Lock()

    def record(self, latency_ms: float) -> None:
        with self._lock:
            self._buf[self._n % self._cap] = float(latency_ms)
            self._n += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    def _consistent(self) -> Tuple[int, np.ndarray]:
        """One ``(total count, retained window)`` pair under the lock.

        ``snapshot()`` used to read ``self._n`` after ``samples()``
        released the lock — a racing ``record()`` could make the reported
        count disagree with the window it supposedly described.
        """
        with self._lock:
            return self._n, self._buf[: min(self._n, self._cap)].copy()

    def samples(self) -> np.ndarray:
        """Copy of the retained window (oldest-sample order not preserved)."""
        return self._consistent()[1]

    def snapshot(self, points=(50.0, 99.0, 99.9)) -> Dict[str, float]:
        n, s = self._consistent()
        out = percentiles(s, points)
        out["count"] = float(n)
        if s.size:
            out["mean"] = float(s.mean())
            out["max"] = float(s.max())
        return out


class MetricsRegistry:
    """Get-or-create home for every metric in the process.

    Keys are ``(name, sorted label items)``.  Re-registering a callback
    gauge replaces its callback (the newest owner wins — an engine
    restart re-binds ``engine_segments`` to the live engine rather than
    the dead one).  ``snapshot()``/``prometheus_text()`` copy the metric
    map under the lock, then read values lock-free per metric.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple], Any] = {}

    def _key(self, name: str, labels: Dict[str, str]) -> Tuple[str, Tuple]:
        return name, tuple(sorted(labels.items()))

    def counter(self, name: str, **labels: str) -> Counter:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Counter(name, labels)
            elif not isinstance(m, Counter):
                raise TypeError(f"{name}{labels} registered as {type(m).__name__}")
            return m

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None,
              **labels: str) -> Gauge:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Gauge(name, labels, fn)
            elif not isinstance(m, Gauge):
                raise TypeError(f"{name}{labels} registered as {type(m).__name__}")
            elif fn is not None:
                m._fn = fn
            return m

    def latency(self, name: str, capacity: int = 65536,
                **labels: str) -> LatencyRecorder:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = LatencyRecorder(capacity)
                m.name, m.labels = name, dict(labels)  # type: ignore[attr-defined]
            elif not isinstance(m, LatencyRecorder):
                raise TypeError(f"{name}{labels} registered as {type(m).__name__}")
            return m

    def replace_latency(self, name: str, capacity: int = 65536,
                        **labels: str) -> LatencyRecorder:
        """Install a fresh recorder under the key (reset for benchmarks)."""
        key = self._key(name, labels)
        with self._lock:
            m = LatencyRecorder(capacity)
            m.name, m.labels = name, dict(labels)  # type: ignore[attr-defined]
            self._metrics[key] = m
            return m

    def _items(self) -> List[Tuple[Tuple[str, Tuple], Any]]:
        with self._lock:
            return sorted(self._metrics.items(), key=lambda kv: kv[0])

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able view: one entry per metric, labels folded into the key."""
        out: Dict[str, Any] = {}
        for (name, litems), m in self._items():
            key = name + _fmt_labels(dict(litems))
            if isinstance(m, LatencyRecorder):
                out[key] = m.snapshot()
            else:
                out[key] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format, version 0.0.4.

        Latency recorders export as summaries: ``<name>{quantile="0.5"}``
        series plus ``<name>_count`` (no ``_sum`` — the ring holds a
        window, so a cumulative sum would lie).
        """
        lines: List[str] = []
        seen_types: Dict[str, str] = {}

        def header(name: str, mtype: str) -> None:
            if seen_types.get(name) != mtype:
                lines.append(f"# TYPE {name} {mtype}")
                seen_types[name] = mtype

        for (name, litems), m in self._items():
            labels = dict(litems)
            if isinstance(m, Counter):
                header(name, "counter")
                lines.append(f"{name}{_fmt_labels(labels)} {m.value}")
            elif isinstance(m, Gauge):
                header(name, "gauge")
                v = m.value
                val = str(v) if v == v else "NaN"
                lines.append(f"{name}{_fmt_labels(labels)} {val}")
            elif isinstance(m, LatencyRecorder):
                header(name, "summary")
                n, s = m._consistent()
                for q in (0.5, 0.99, 0.999):
                    ql = dict(labels)
                    ql["quantile"] = f"{q:g}"
                    if s.size:
                        idx = min(s.size - 1,
                                  max(0, int(np.ceil(q * s.size)) - 1))
                        v = float(np.partition(s, idx)[idx])
                        lines.append(f"{name}{_fmt_labels(ql)} {v}")
                    else:
                        lines.append(f"{name}{_fmt_labels(ql)} NaN")
                lines.append(f"{name}_count{_fmt_labels(labels)} {n}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry that library instrumentation uses."""
    return _default
