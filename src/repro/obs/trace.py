"""Hierarchical spans: wall time, device time, and Chrome-trace export.

A span is one timed region of work — ``with span("compact"):`` — that
nests: spans opened inside it become its children, across function-call
boundaries, because the current span is carried in a ``contextvars``
context variable.  Each thread starts with no current span, so the
engine's serve thread and maintenance thread naturally build separate
span trees that interleave in the export without corrupting each other's
nesting (a contextvar is per-thread unless a context is explicitly
copied across).

Two clocks per span:

* **wall** — ``time.perf_counter()`` around the body: what the thread
  waited.
* **device** — optional: call ``Span.block(arrays)`` with the dispatch
  result before the body exits and the span additionally records the
  time to ``jax.block_until_ready`` it, i.e. the tail of device work
  still outstanding when the host-side body finished.  On a synchronous
  path the two are nearly equal; a large wall-vs-device gap is the
  signature of host-side overhead (padding, concat, Python).

Completed spans land in a bounded in-memory ring (oldest evicted) owned
by a :class:`Tracer`.  ``Tracer.chrome_trace()`` exports the buffer as
Chrome-trace JSON (``chrome://tracing`` / Perfetto "complete" events,
microsecond timestamps on a common epoch) so a swap timeline or a tail
request can be read as a flame graph rather than a log grep.

Tracing is OFF by default.  A disabled tracer hands out a shared no-op
span, so an instrumented hot path pays one attribute load + one ``if``
per span — measured in ``BENCH_serving.json`` (< 2% on request p50 even
when ON; see docs/OBSERVABILITY.md).
"""

from __future__ import annotations

import contextvars
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "default_tracer", "span", "enable", "disable"]

# Per-context (hence per-thread, absent explicit context propagation)
# innermost open span.  Not shared across threads: threading.Thread
# starts callables in a fresh context.
_current: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)

_ids = itertools.count(1)


class Span:
    """One timed region.  Use via ``Tracer.span`` — not constructed directly.

    Attributes (stable, read by exports and tests):

    * ``name``, ``span_id``, ``parent_id`` (``None`` for a root),
    * ``thread`` — ``threading.get_ident()`` of the opening thread,
    * ``t0`` — start, seconds on the tracer's ``perf_counter`` epoch,
    * ``wall_ms`` — body duration (set at exit),
    * ``device_ms`` — ``block()`` duration, or ``None`` if never called,
    * ``attrs`` — user key/values (``set(**kw)``), exported to the
      Chrome-trace ``args`` field.
    """

    __slots__ = ("name", "span_id", "parent_id", "thread", "t0",
                 "wall_ms", "device_ms", "attrs", "_tracer", "_token")

    def __init__(self, tracer: "Tracer", name: str,
                 parent: Optional["Span"]):
        self.name = name
        self.span_id = next(_ids)
        self.parent_id = parent.span_id if parent is not None else None
        self.thread = threading.get_ident()
        self.t0 = 0.0
        self.wall_ms: Optional[float] = None
        self.device_ms: Optional[float] = None
        self.attrs: Dict[str, Any] = {}
        self._tracer = tracer
        self._token: Optional[contextvars.Token] = None

    def set(self, **kw: Any) -> "Span":
        self.attrs.update(kw)
        return self

    def block(self, arrays: Any) -> Any:
        """``jax.block_until_ready(arrays)``, timing the wait as device_ms.

        Returns ``arrays`` so it drops into an existing expression.
        Accumulates across calls (a span may block on several dispatches).
        """
        import jax

        t = time.perf_counter()
        out = jax.block_until_ready(arrays)
        self.device_ms = (self.device_ms or 0.0) + (
            (time.perf_counter() - t) * 1e3
        )
        return out

    def __enter__(self) -> "Span":
        self._token = _current.set(self)
        self.t0 = time.perf_counter() - self._tracer._epoch
        return self

    def __exit__(self, *exc) -> None:
        self.wall_ms = (
            time.perf_counter() - self._tracer._epoch - self.t0
        ) * 1e3
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        self._tracer._record(self)


class _NoopSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()
    name = ""
    attrs: Dict[str, Any] = {}
    wall_ms = device_ms = None

    def set(self, **kw: Any) -> "_NoopSpan":
        return self

    def block(self, arrays: Any) -> Any:
        return arrays

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NOOP = _NoopSpan()


class Tracer:
    """Bounded buffer of completed spans + the enable/disable switch."""

    def __init__(self, capacity: int = 4096, enabled: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._buf: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self.enabled = bool(enabled)

    def span(self, name: str, **attrs: Any):
        """Open a span as a context manager.  No-op when disabled.

        The enabled check happens at open time: a span already open when
        the tracer is disabled still records at exit (its close must
        balance its open).
        """
        if not self.enabled:
            return _NOOP
        s = Span(self, name, _current.get())
        if attrs:
            s.attrs.update(attrs)
        return s

    def current(self) -> Optional[Span]:
        """The innermost open span in this thread, or ``None``."""
        return _current.get()

    def _record(self, s: Span) -> None:
        with self._lock:
            self._buf.append(s)

    def spans(self) -> List[Span]:
        """Copy of the retained (completed) spans, oldest first."""
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace JSON (``chrome://tracing`` "complete" events).

        Timestamps are microseconds on the tracer's ``perf_counter``
        epoch — monotonic and comparable across threads of this process.
        ``tid`` is the OS thread ident so serve/maintenance threads land
        on separate tracks; device time is exported as an ``args`` field
        (Chrome has no second duration axis).
        """
        events = []
        for s in self.spans():
            args = dict(s.attrs)
            if s.device_ms is not None:
                args["device_ms"] = round(s.device_ms, 3)
            if s.parent_id is not None:
                args["parent"] = s.parent_id
            events.append({
                "name": s.name,
                "ph": "X",
                "ts": round(s.t0 * 1e6, 1),
                "dur": round((s.wall_ms or 0.0) * 1e3, 1),
                "pid": 0,
                "tid": s.thread,
                "args": args,
            })
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


_default = Tracer()


def default_tracer() -> Tracer:
    """The process-global tracer that library instrumentation uses."""
    return _default


def span(name: str, **attrs: Any):
    """Open a span on the default tracer (no-op unless :func:`enable` d)."""
    return _default.span(name, **attrs)


def enable(capacity: Optional[int] = None) -> Tracer:
    """Turn on the default tracer (optionally resizing its buffer)."""
    if capacity is not None and capacity != _default._buf.maxlen:
        with _default._lock:
            _default._buf = deque(_default._buf, maxlen=int(capacity))
    _default.enabled = True
    return _default


def disable() -> None:
    _default.enabled = False
