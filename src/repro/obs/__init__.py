"""repro.obs — repo-wide observability: spans, metrics, recall probes.

The subsystem the rest of the library reports into, and the one place
operators read from:

* :mod:`repro.obs.trace` — hierarchical spans (wall + device time),
  bounded buffer, Chrome-trace export.  Off by default; ``enable()``.
* :mod:`repro.obs.registry` — process-global counters / gauges /
  exact-percentile latency recorders; JSON snapshot + Prometheus text.
* :mod:`repro.obs.dispatch` — per-site dispatch counters and the
  jax.monitoring recompile detector (the pow2-bucket "never recompiles
  in steady state" invariant as a live gauge).
* :mod:`repro.obs.recall` — sampled online recall@k vs. an exact
  brute-force shadow, scored off the query path.
* :mod:`repro.obs.http` — ``/metrics`` (Prometheus), ``/metrics.json``,
  ``/trace`` endpoints on a stdlib HTTP server.

Span taxonomy and the metric catalog are documented in
docs/OBSERVABILITY.md.
"""

from .dispatch import (
    accounting_delta,
    accounting_snapshot,
    compiles_total,
    dispatch_counts,
    dispatch_scope,
    install_compile_listener,
    recompile_counts,
)
from .http import MetricsServer, serve_metrics
from .recall import (
    RecallProbe,
    RecallProbeConfig,
    exact_topk,
    live_points,
    recall_at_k,
)
from .registry import (
    Counter,
    Gauge,
    LatencyRecorder,
    MetricsRegistry,
    default_registry,
    percentile_label,
    percentiles,
)
from .trace import Span, Tracer, default_tracer, disable, enable, span

__all__ = [
    "accounting_delta", "accounting_snapshot", "compiles_total",
    "dispatch_counts", "dispatch_scope",
    "install_compile_listener", "recompile_counts",
    "MetricsServer", "serve_metrics",
    "RecallProbe", "RecallProbeConfig", "exact_topk", "live_points",
    "recall_at_k",
    "Counter", "Gauge", "LatencyRecorder", "MetricsRegistry",
    "default_registry", "percentile_label", "percentiles",
    "Span", "Tracer", "default_tracer", "disable", "enable", "span",
]
