"""Dispatch and recompile accounting for every jitted entry point.

The repo's perf story rests on an invariant: pow2 query-bucket padding
plus shape-stable LSM layouts mean a steady-state process holds at most
~``log2(query_chunk)+1`` traces per jitted site and NEVER recompiles
while serving.  Until now that was a benchmark-only assert; this module
makes it a live counter pair per call site:

* ``index_dispatches_total{site=...}`` — one per jitted call issued;
* ``index_recompiles_total{site=...}`` — how many of those dispatches
  triggered an XLA backend compile (a jit cache miss).  Counted per
  dispatch, not per XLA computation: one fresh trace may compile several
  helper computations, which would otherwise inflate the miss count.

Detection uses ``jax.monitoring``: XLA emits the
``/jax/core/compile/backend_compile_duration`` event exactly when a
computation is actually compiled (cache hits are silent — verified
against the pinned jax 0.4.37).  The listener runs in the thread doing
the compile, so a thread-local count lets :func:`dispatch_scope`
attribute compiles to the site the *current thread* is dispatching even
while the engine's maintenance thread compiles a shadow index
concurrently — the two threads' deltas never mix.

Usage at a call site::

    with dispatch_scope("facade.search"):
        ids, dists = self._search_chunk(...)

The scope is ~two counter bumps when nothing compiles; sites stay
instrumented unconditionally.  ``compiles_total()`` is the process-wide
compile count (warmup included), and the gauge
``index_last_dispatch_recompiled`` is 1 exactly when the most recent
scoped dispatch anywhere in the process compiled — the "are we in steady
state?" light.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator

from .registry import default_registry

__all__ = [
    "install_compile_listener", "compiles_total", "dispatch_scope",
    "dispatch_counts", "recompile_counts", "accounting_snapshot",
    "accounting_delta",
]

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_tls = threading.local()
_install_lock = threading.Lock()
_installed = False
_global_compiles = [0]          # guarded by _install_lock for writes


def _on_event_duration(event: str, duration: float, **kw) -> None:
    if not event.endswith("backend_compile_duration"):
        return
    with _install_lock:
        _global_compiles[0] += 1
    _tls.compiles = getattr(_tls, "compiles", 0) + 1


def install_compile_listener() -> bool:
    """Register the jax.monitoring listener (idempotent).

    Returns False when the running jax has no duration-listener hook
    (the accounting then still counts dispatches, with recompiles
    pinned at 0 — absence of data, not a claim of zero compiles).
    """
    global _installed
    with _install_lock:
        if _installed:
            return True
        try:
            from jax import monitoring
            monitoring.register_event_duration_secs_listener(
                _on_event_duration
            )
        except Exception:
            return False
        _installed = True
        return True


def compiles_total() -> int:
    """Process-wide backend compiles observed since listener install."""
    with _install_lock:
        return _global_compiles[0]


@contextmanager
def dispatch_scope(site: str) -> Iterator[None]:
    """Count one jitted dispatch at ``site``; flag it if it compiled.

    Attribution is by thread-local compile delta across the body, so
    concurrent scopes in other threads (serve vs. maintenance) don't
    steal or leak each other's compiles.  Nested scopes both observe a
    compile that happens in the innermost body — acceptable: outer
    scopes wrap composite operations whose recompile *did* happen on
    their watch.
    """
    install_compile_listener()
    reg = default_registry()
    reg.counter("index_dispatches_total", site=site).inc()
    before = getattr(_tls, "compiles", 0)
    try:
        yield
    finally:
        delta = getattr(_tls, "compiles", 0) - before
        gauge = reg.gauge("index_last_dispatch_recompiled")
        if delta > 0:
            # One scoped dispatch = at most one recompile tick, however
            # many backend computations XLA built for it (a fresh trace
            # compiles helper computations alongside the main one).
            reg.counter("index_recompiles_total", site=site).inc()
            gauge.set(1.0)
        else:
            gauge.set(0.0)


def _by_site(name: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for key, val in default_registry().snapshot().items():
        if key.startswith(name + "{"):
            site = key.split('site="', 1)[1].split('"', 1)[0]
            out[site] = int(val)
    return out


def dispatch_counts() -> Dict[str, int]:
    """``{site: dispatches}`` for every instrumented site so far."""
    return _by_site("index_dispatches_total")


def recompile_counts() -> Dict[str, int]:
    """``{site: recompiles}`` for every instrumented site so far."""
    return _by_site("index_recompiles_total")


def accounting_snapshot() -> Dict[str, object]:
    """The dispatch/recompile accounting as one JSON-able block.

    Benchmarks embed this in their ``BENCH_*.json`` so every artifact
    records how many jitted dispatches the run issued per site and how
    many of them compiled — the pow2-bucket invariant as data.
    """
    return {
        "dispatches_by_site": dispatch_counts(),
        "recompiles_by_site": recompile_counts(),
        "backend_compiles_total": compiles_total(),
    }


def accounting_delta(
    before: Dict[str, object], after: Dict[str, object]
) -> Dict[str, object]:
    """Per-site difference of two :func:`accounting_snapshot` blocks.

    Counters are process-cumulative, so an A/B benchmark that wants "what
    did THIS variant dispatch/compile" snapshots around each variant and
    embeds the delta — e.g. the gather-vs-tree comparison in
    ``benchmarks/sharded_search.py``, where a nonzero recompile delta on
    a warmed variant would invalidate its timings.  Sites absent from
    ``before`` count from zero; zero deltas are dropped.
    """

    def diff(name: str) -> Dict[str, int]:
        b = before.get(name, {}) or {}
        a = after.get(name, {}) or {}
        out = {
            site: int(n) - int(b.get(site, 0)) for site, n in a.items()
        }
        return {site: n for site, n in out.items() if n}

    return {
        "dispatches_by_site": diff("dispatches_by_site"),
        "recompiles_by_site": diff("recompiles_by_site"),
        "backend_compiles_total": (
            int(after.get("backend_compiles_total", 0))
            - int(before.get("backend_compiles_total", 0))
        ),
    }
