"""Logical-axis sharding rules (MaxText-style) for the whole framework.

Tensors are annotated with *logical* axis names; a rules table maps logical
names to mesh axes per deployment.  GSPMD handles uneven dims (e.g. 56 query
heads over a 16-way model axis, or 8 KV heads over 16) by padding — recorded
as waste in the roofline, and a hillclimb lever.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary
#   batch      — global batch            -> ('pod', 'data') / 'data'
#   seq        — sequence                -> None (SP shards it over 'model')
#   d_model    — residual stream         -> None
#   heads      — query heads             -> 'model'
#   kv_heads   — KV heads                -> 'model'
#   head_dim   — per-head dim            -> None
#   mlp        — FFN hidden              -> 'model'
#   vocab      — vocabulary              -> 'model'
#   experts    — MoE experts             -> 'model'
#   capacity   — MoE expert capacity     -> None
#   fsdp       — weight dim sharded over the data axis (ZeRO-3 style)
#   cache_seq  — decode KV-cache seq     -> None ('data' for long-context)
#   frames     — stub frontend frames    -> None
#   state      — SSM state dim           -> None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis -> mesh axis (or None = replicated)."""

    batch: Optional[Tuple[str, ...]] = ("data",)
    seq: Optional[str] = None
    d_model: Optional[str] = None
    heads: Optional[str] = "model"
    kv_heads: Optional[str] = "model"
    head_dim: Optional[str] = None
    kv_head_dim: Optional[str] = None  # 'model' when kv_heads < model size
    mlp: Optional[str] = "model"
    vocab: Optional[str] = "model"
    experts: Optional[str] = "model"
    capacity: Optional[str] = None
    fsdp: Optional[str] = None          # set to "data" for ZeRO-style weights
    cache_seq: Optional[str] = None     # set to "data" for long-context decode
    frames: Optional[str] = None
    state: Optional[str] = None
    # When True, q/k/v/attention-internal activations carry NO explicit
    # constraints — GSPMD propagates from the (sharded) projection weights.
    # For archs whose head counts don't divide the model axis, any explicit
    # head/dim constraint fights propagation and triggers replicate+reslice
    # loops (measured: 163 GB/device collective-permute at gemma3 train_4k).
    attn_unconstrained: bool = False
    # Ambient mesh for shard_map sub-programs (the expert-parallel MoE path
    # needs per-rank control GSPMD cannot express: masked local combine +
    # one psum instead of an E·C·D all-gather).  None = pure-GSPMD paths.
    mesh: Optional[object] = None

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        for name in logical:
            if name is None:
                axes.append(None)
            else:
                axes.append(getattr(self, name))
        return P(*axes)


MULTIPOD_RULES = ShardingRules(batch=("pod", "data"))
SINGLEPOD_RULES = ShardingRules(batch=("data",))


def make_rules(mesh: Mesh, **overrides) -> ShardingRules:
    base = MULTIPOD_RULES if "pod" in mesh.axis_names else SINGLEPOD_RULES
    overrides.setdefault("mesh", mesh)
    return dataclasses.replace(base, **overrides)


def shard(x: jax.Array, rules: ShardingRules, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op outside jit mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(*logical))
    except (ValueError, RuntimeError):
        return x  # no mesh context (single-device tests)


def named_sharding(mesh: Mesh, rules: ShardingRules, *logical) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*logical))
