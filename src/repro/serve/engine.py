"""Async retrieval serving engine: admission, micro-batching, background
maintenance with double-buffered index swap.

The index family is fast per call, but a production deployment is a
*request stream*, not an array: callers arrive raggedly, LSM maintenance
(tier merges, the multi-second full ``compact()``) must never run on the
query path, and the compiled-dispatch cache must be hit by construction.
``RetrievalEngine`` is that serving loop, layered over ANY index layout
(plain / mutable / sharded / sharded-mutable — anything with
``search(queries, params, backend=, query_chunk=)``):

* **Admission + EDF micro-batching** — :meth:`submit` places a request
  in a BOUNDED queue (backpressure: ``block=False`` raises
  :class:`QueueFull` when the deployment is saturated, instead of
  unbounded memory growth).  The serve loop forms micro-batches
  earliest-deadline-first (the pure
  :func:`repro.serve.batching.form_batch` — deadline-less tickets age
  under a fairness horizon, so nothing starves) of up to ``max_batch``
  rows sharing one :class:`SearchParams`, concatenates them into one
  search, and splits results back per request.  Batches cap at the
  facade's ``query_chunk``, whose pow2 bucket padding then guarantees
  at most ``log2(query_chunk)+1`` compiled shapes — the dispatch cache is
  hit by construction, never by luck.
* **Pipelined retrieval** — multi-chunk batches run through
  :func:`repro.serve.pipeline.pipelined_search`: host staging of chunk
  *i+1* overlaps device execution of chunk *i* (double-buffered
  ``device_put``), bit-identical to a direct ``index.search``.
* **Background maintenance + atomic swap** — a maintainer thread watches
  :meth:`maintenance_stats` (generation count, tombstone ratio).  When a
  threshold trips it snapshots the serving index (cheap: sealed segments
  are shared, only buffers/bookkeeping copy), runs the expensive
  ``compact()`` on that SHADOW off the query path, replays the writes that
  arrived meanwhile (id assignment is sequential and deterministic, so
  replayed inserts receive identical external ids), and atomically swaps
  the serving pointer.  An epoch/refcount guard lets in-flight batches
  finish on the OLD index — their results stay bit-equal to a direct
  search on the index version that admitted them — and the swap waits for
  the old epoch's refcount to drain before retiring it.

Determinism for tests: construct with ``start=False`` and drive
:meth:`step` / :meth:`maintain_once` by hand — no threads, same code path
(the serve loop calls exactly these).  See ``docs/SERVING.md``.
"""

from __future__ import annotations

import dataclasses
import itertools
import shutil
import subprocess
import tempfile
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.wal import WalWriteError
from repro.core.types import SearchParams
from repro.index.facade import _pow2_bucket
from repro.obs.recall import RecallProbe, RecallProbeConfig
from repro.obs.registry import default_registry
from repro.obs.trace import span
from repro.serve.batching import form_batch
from repro.serve.compactor import CompactionChildError, compact_in_child
from repro.serve.metrics import EngineMetrics
from repro.serve.pipeline import pipelined_search
from repro.serve.rwlock import ReadWriteLock
from repro.testing.faults import fault_point

__all__ = [
    "CompactionChildError",
    "DeadlineExceeded",
    "EngineClosed",
    "EngineDegraded",
    "MaintenancePolicy",
    "MaintenanceTimeout",
    "QueueFull",
    "RetrievalEngine",
    "SearchTicket",
]


class QueueFull(RuntimeError):
    """Admission queue at capacity: the deployment is saturated (shed load)."""


class EngineClosed(RuntimeError):
    """The engine stopped admitting requests (shutdown in progress/done)."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed while it waited in the queue.

    Expired tickets are dropped at batch-formation time — BEFORE any
    device dispatch — so a saturated deployment sheds stale work instead
    of burning compute on answers nobody is waiting for anymore.
    """


class EngineDegraded(RuntimeError):
    """Writes are refused: the engine is in degraded read-only mode.

    Entered when the index's write-ahead log becomes unwritable
    (:class:`~repro.checkpoint.wal.WalWriteError`): acknowledging writes
    without a durable log would silently reintroduce the crash-loss
    window, so writes fail fast with this error while searches keep
    serving.  ``/healthz`` flips to 503 via the ``engine_degraded``
    gauge; :meth:`RetrievalEngine.reset_degraded` re-admits writes after
    the operator fixes the disk.
    """


class MaintenanceTimeout(RuntimeError):
    """The shadow compact outran the watchdog; the shadow was abandoned."""


@dataclasses.dataclass(frozen=True)
class MaintenancePolicy:
    """When the background maintainer acts, and how often it looks.

    A full ``compact()`` triggers when EITHER threshold trips:
    ``max_segments`` bounds the per-query fan-out cost (every sealed
    generation is an extra search stage — the ~8× p50 creep in
    ``BENCH_sharded_churn.json``), ``max_tombstone_ratio`` bounds wasted
    candidate-pool slots (each segment's k is inflated by its dead count).

    ``max_cycle_s`` is the watchdog: a shadow ``compact()`` that has not
    finished within it is abandoned (the serving index was never touched,
    so nothing is lost but the shadow's work) and the cycle fails with
    :class:`MaintenanceTimeout`.  The maintainer thread then backs off
    exponentially from ``backoff_initial_s`` doubling to at most
    ``backoff_max_s`` between failed cycles, so a persistently failing
    compact (bad disk, poisoned segment) cannot hot-loop the maintainer
    while serving continues.
    """

    max_segments: int = 4          # sealed segments/generations before compact
    max_tombstone_ratio: float = 0.25  # dead/allocated ids before compact
    poll_interval_s: float = 0.05  # maintainer wake period
    max_cycle_s: Optional[float] = 300.0  # shadow-compact watchdog (None=off)
    backoff_initial_s: float = 0.25      # first post-failure delay
    backoff_max_s: float = 30.0          # backoff cap

    def triggered(self, stats: Dict[str, Any]) -> bool:
        if stats.get("n_live", 0) == 0:
            return False
        if stats.get("mergeable_segments", 0) < 1:
            return False  # store_points=False: nothing can be re-sorted
        # rewrite_pressure: segments tombstoned past their candidate pool.
        # The facades used to rewrite these INSIDE search(); the engine's
        # shared-read-lock path suppresses that (reads must not mutate),
        # so the same condition triggers maintenance here instead.
        return (
            int(stats.get("n_segments", 0)) > self.max_segments
            or float(stats.get("tombstone_ratio", 0.0))
            > self.max_tombstone_ratio
            or int(stats.get("rewrite_pressure", 0)) > 0
        )


class SearchTicket:
    """A submitted request's handle: blocks on :meth:`result`.

    ``epoch`` records which index version served the batch (filled at
    completion) — the engine's bit-equality contract is against a direct
    ``search`` on THAT version.  The lifecycle timestamps split a
    request's latency into its operational phases: ``submitted_at`` →
    ``batched_at`` (queue wait) → ``completed_at`` (execution + merge).

    ``deadline`` (a ``time.monotonic()`` instant, or None) marks when the
    caller stops caring: a ticket still queued past it is failed with
    :class:`DeadlineExceeded` at batch-formation time instead of being
    dispatched.  ``submitted_mono`` (same clock) plus ``seq`` (global
    admission order) are what the EDF batcher
    (:func:`repro.serve.batching.form_batch`) schedules on: deadline-less
    tickets age from ``submitted_mono``, and ``seq`` breaks deadline
    ties deterministically.
    """

    _seq_counter = itertools.count()

    def __init__(self, queries: np.ndarray, params: SearchParams,
                 deadline: Optional[float] = None,
                 seq: Optional[int] = None):
        self.queries = queries
        self.params = params
        self.deadline = deadline
        self.seq = next(self._seq_counter) if seq is None else seq
        self.submitted_mono = time.monotonic()
        self.submitted_at = time.perf_counter()
        self.batched_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        self.epoch: Optional[int] = None
        self.ids: Optional[np.ndarray] = None
        self.dists: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def expired(self) -> bool:
        return self.deadline is not None and time.monotonic() > self.deadline

    @property
    def latency_ms(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return 1000.0 * (self.completed_at - self.submitted_at)

    @property
    def queue_wait_ms(self) -> Optional[float]:
        """Admission → batch-formation wait (None until batched)."""
        if self.batched_at is None:
            return None
        return 1000.0 * (self.batched_at - self.submitted_at)

    def result(
        self, timeout: Optional[float] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(ids (m, k), sq-dists (m, k)) — blocks until served.

        Raises ``TimeoutError`` if not served in ``timeout`` seconds, or
        re-raises the serve-side exception (e.g. :class:`EngineClosed` for
        requests failed by a non-draining shutdown).
        """
        if not self._done.wait(timeout):
            raise TimeoutError("search request not served in time")
        if self.error is not None:
            raise self.error
        return self.ids, self.dists

    def _complete(self, ids, dists, epoch) -> None:
        self.ids, self.dists, self.epoch = ids, dists, epoch
        self.completed_at = time.perf_counter()
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self.error = err
        self.completed_at = time.perf_counter()
        self._done.set()


class _Epoch:
    """One serving-index version with a refcount of in-flight batches."""

    def __init__(self, index, epoch: int):
        self.index = index
        self.epoch = epoch
        self.refs = 0
        self._cv = threading.Condition()

    def checkout(self) -> None:
        with self._cv:
            self.refs += 1

    def checkin(self) -> None:
        with self._cv:
            self.refs -= 1
            self._cv.notify_all()

    def wait_drained(self, timeout: Optional[float] = None) -> bool:
        with self._cv:
            return self._cv.wait_for(lambda: self.refs == 0, timeout)


class RetrievalEngine:
    """The async serving loop over one index (any layout).

    Args:
      index: the serving index.  Mutable layouts
        (:class:`~repro.index.MutableHilbertIndex`,
        :class:`~repro.index.ShardedMutableHilbertIndex`) additionally get
        :meth:`insert`/:meth:`delete` routing and background maintenance;
        static layouts serve read-only.
      params: default :class:`SearchParams` for requests that don't carry
        their own.
      max_queue: admission-queue capacity in REQUESTS (backpressure bound).
      max_batch: micro-batch cap in query ROWS (default: the index
        config's ``query_chunk`` — one fused dispatch per batch).
      backend: kernel routing passed through to every search.
      pipeline: double-buffer chunk staging for multi-chunk batches
        (:func:`~repro.serve.pipeline.pipelined_search`).
      maintenance: background-maintenance thresholds; ``None`` disables
        the maintainer thread (maintenance can still be driven manually
        via :meth:`maintain_once`).
      recall: online recall probing — a
        :class:`~repro.obs.recall.RecallProbeConfig` (or a ready
        :class:`~repro.obs.recall.RecallProbe`) samples served batches
        and scores them against an exact shadow OFF the query path: the
        maintainer thread scores between cycles, or call
        :meth:`score_recall` in step mode.  ``None`` (default) disables
        probing entirely.
      compaction: where the shadow ``compact()`` runs.  ``"thread"``
        (default) compacts on a helper thread inside this process;
        ``"subprocess"`` hands the snapshot to a CHILD process via the
        format_version-5 bundles (:mod:`repro.serve.compactor`) so
        maintenance never touches the serving process's cores or GIL —
        the child saves, the parent verifies and reloads, and the
        existing replay + swap protocol continues unchanged.
      compaction_dir: workdir for subprocess compaction bundles
        (default: a fresh temp dir per cycle, removed afterwards).
      edf_horizon_s: fairness horizon for deadline-less requests — their
        effective deadline is submission + this, which bounds how long a
        stream of urgent arrivals can delay them (see
        :mod:`repro.serve.batching`).
      serve_threads: number of serve-loop workers.  More than one lets
        micro-batches EXECUTE concurrently under the shared read lock
        (useful when batches are small and host-bound); results stay
        per-ticket deterministic regardless.
      start: spawn the serve (+ maintainer) threads immediately.  With
        ``start=False`` the engine is in deterministic step mode: drive
        :meth:`step` and :meth:`maintain_once` by hand.

    Index access takes a READER-WRITER lock: searches (and other pure
    reads) share it, while ``insert``/``delete``, the maintenance
    snapshot and the epoch swap hold it exclusively — possible because
    the facades' read paths are mutation-free under concurrency (the
    engine searches with ``allow_rewrite=False``; lazy caches are
    idempotent fills).  The expensive shadow ``compact()`` runs with NO
    lock held (in-thread or in a child process — that is the whole
    point).  Lock hierarchy: state lock < serve-read < serve-write <
    maintenance mutex; see ``docs/SERVING.md``.  Used as a context
    manager, ``__exit__`` performs a draining :meth:`stop`.
    """

    def __init__(
        self,
        index,
        params: Optional[SearchParams] = None,
        *,
        max_queue: int = 256,
        max_batch: Optional[int] = None,
        backend: str = "auto",
        pipeline: bool = True,
        maintenance: Optional[MaintenancePolicy] = MaintenancePolicy(),
        recall: Optional[Any] = None,
        default_deadline_ms: Optional[float] = None,
        compaction: str = "thread",
        compaction_dir: Optional[str] = None,
        edf_horizon_s: float = 60.0,
        serve_threads: int = 1,
        start: bool = False,
    ):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if default_deadline_ms is not None and default_deadline_ms <= 0:
            raise ValueError(
                f"default_deadline_ms must be > 0, got {default_deadline_ms}"
            )
        if compaction not in ("thread", "subprocess"):
            raise ValueError(
                f"compaction must be 'thread' or 'subprocess', "
                f"got {compaction!r}"
            )
        if serve_threads < 1:
            raise ValueError(
                f"serve_threads must be >= 1, got {serve_threads}"
            )
        if edf_horizon_s <= 0:
            raise ValueError(
                f"edf_horizon_s must be > 0, got {edf_horizon_s}"
            )
        self.params = params or SearchParams()
        self.default_deadline_ms = default_deadline_ms
        self.backend = backend
        self.pipeline = pipeline
        self.max_queue = int(max_queue)
        chunk = getattr(getattr(index, "config", None), "query_chunk", 1024)
        self.max_batch = int(max_batch or chunk)
        self.query_chunk = min(chunk, self.max_batch)
        self.maintenance = maintenance
        self.metrics = EngineMetrics()
        if recall is None:
            self.recall_probe: Optional[RecallProbe] = None
        elif isinstance(recall, RecallProbe):
            self.recall_probe = recall
        elif isinstance(recall, RecallProbeConfig):
            self.recall_probe = RecallProbe(recall)
        else:
            raise TypeError(
                "recall must be a RecallProbeConfig or RecallProbe, got "
                f"{type(recall).__name__}"
            )
        self.compaction = compaction
        self.compaction_dir = compaction_dir
        self.edf_horizon_s = float(edf_horizon_s)
        self.serve_threads = int(serve_threads)
        # engine reads must not trigger segment rewrites: searches run
        # under the SHARED lock side, so mutation is off the read path
        # (the rewrite condition surfaces as maintenance `rewrite_pressure`)
        self._search_kwargs = (
            {"allow_rewrite": False}
            if hasattr(index, "rewrite_pressure") else {}
        )
        self.last_swap_timeline: Optional[Dict[str, Any]] = None
        self._register_gauges()

        # Lock hierarchy (acquire left-to-right only):
        #   _state_lock < serve-read < serve-write < _maint_lock
        self._state_lock = threading.Lock()   # epoch pointer + write log
        reg = default_registry()
        _rw_read = reg.latency("engine_rwlock_read_wait_ms", capacity=4096)
        _rw_write = reg.latency("engine_rwlock_write_wait_ms", capacity=4096)
        self._serve_lock = ReadWriteLock(     # searches share, writes exclude
            observer=lambda kind, ms: (
                _rw_write if kind == "write" else _rw_read
            ).record(ms)
        )
        self._maint_lock = threading.Lock()   # one maintenance cycle at a time
        # one representative batch per (params, pow2 dispatch bucket) seen,
        # so maintenance pre-warms the shadow for EVERY bucket live traffic
        # uses, not just the last shape observed.  Bounded by construction:
        # at most log2(query_chunk)+1 buckets per distinct SearchParams.
        self._warm_queries: Dict[
            Tuple[SearchParams, int], np.ndarray
        ] = {}
        self._current = _Epoch(index, 0)
        self._write_log: Optional[List[Tuple[str, Any, Any]]] = None

        self._cv = threading.Condition()
        self._pending: Deque[SearchTicket] = deque()
        self._closed = False
        self._workers: List[threading.Thread] = []
        self._maintainer: Optional[threading.Thread] = None
        self._stop_event = threading.Event()
        self.last_maintenance_error: Optional[BaseException] = None
        self._degraded_reason: Optional[str] = None
        if start:
            self.start()

    def _register_gauges(self) -> None:
        """Bind the ``engine_*`` callback gauges to THIS engine.

        Callback gauges read live state at scrape time — no write on the
        serving path.  Bound through a weakref so the process-global
        registry never keeps a stopped engine alive; a dead engine's
        gauges read ``nan`` until the next engine re-binds them.
        """
        import weakref

        wr = weakref.ref(self)
        reg = default_registry()

        def stat(key: str, default: float = 0.0):
            def read() -> float:
                eng = wr()
                if eng is None:
                    return float("nan")
                return float(eng.maintenance_stats().get(key, default))
            return read

        def attr(fn):
            def read() -> float:
                eng = wr()
                return float("nan") if eng is None else float(fn(eng))
            return read

        reg.gauge("engine_queue_depth", fn=attr(lambda e: e.queue_depth))
        reg.gauge("engine_epoch", fn=attr(lambda e: e.epoch))
        reg.gauge("engine_segments", fn=stat("n_segments"))
        reg.gauge("engine_tombstone_ratio", fn=stat("tombstone_ratio"))
        reg.gauge("engine_live_rows", fn=stat("n_live"))
        reg.gauge("engine_buffered_rows", fn=stat("n_buffered"))

        def buffer_fill() -> float:
            eng = wr()
            if eng is None:
                return float("nan")
            cap = getattr(eng.index, "buffer_capacity", 0)
            if not cap:
                return 0.0
            return float(eng.maintenance_stats().get("n_buffered", 0)) / cap

        reg.gauge("engine_buffer_fill", fn=buffer_fill)
        reg.gauge(
            "engine_degraded",
            fn=attr(lambda e: 1.0 if e._degraded_reason else 0.0),
        )

        def lock_stat(key: str):
            def read() -> float:
                eng = wr()
                lock = getattr(eng, "_serve_lock", None)
                if lock is None:
                    return float("nan")
                return float(lock.stats().get(key, 0.0))
            return read

        # rw-lock contention: live reader count, queued writers, and the
        # cumulative exclusive-hold time (how long writes/swaps actually
        # kept readers out)
        reg.gauge("engine_rwlock_readers", fn=lock_stat("readers"))
        reg.gauge("engine_rwlock_pending_writers",
                  fn=lock_stat("pending_writers"))
        reg.gauge("engine_rwlock_write_held_ms",
                  fn=lock_stat("write_held_ms"))

    # -- introspection -------------------------------------------------------

    @property
    def index(self):
        """The CURRENT serving index (the pointer a swap replaces)."""
        with self._state_lock:
            return self._current.index

    @property
    def epoch(self) -> int:
        """Bumps by one on every background swap."""
        with self._state_lock:
            return self._current.epoch

    @property
    def queue_depth(self) -> int:
        with self._cv:
            return len(self._pending)

    @property
    def running(self) -> bool:
        return any(w.is_alive() for w in self._workers)

    @property
    def degraded(self) -> bool:
        """True when writes are refused (WAL unwritable); reads still serve."""
        return self._degraded_reason is not None

    @property
    def degraded_reason(self) -> Optional[str]:
        return self._degraded_reason

    def reset_degraded(self) -> None:
        """Re-admit writes after the operator has fixed the WAL's disk.

        The next write that fails to log re-enters degraded mode, so
        resetting without fixing the underlying fault is safe — just
        noisy.
        """
        self._degraded_reason = None

    def _enter_degraded(self, reason: str) -> None:
        self._degraded_reason = reason
        self.metrics.bump("degraded_entered")

    # -- admission -----------------------------------------------------------

    def submit(
        self,
        queries,
        params: Optional[SearchParams] = None,
        *,
        block: bool = True,
        timeout: Optional[float] = None,
        deadline_ms: Optional[float] = None,
    ) -> SearchTicket:
        """Admit one request ((m, d) queries) into the bounded queue.

        Returns a :class:`SearchTicket`; ``block=False`` raises
        :class:`QueueFull` instead of waiting for space, and a closed
        engine raises :class:`EngineClosed` (both count as rejections in
        the metrics).

        ``deadline_ms`` (default: the engine's ``default_deadline_ms``)
        bounds how long the ticket may WAIT: if it is still queued when
        the deadline passes it fails with :class:`DeadlineExceeded`
        instead of being dispatched.  A batch already executing is never
        aborted — the deadline sheds queue backlog, not in-flight work.
        """
        q = np.asarray(jax.device_get(queries), np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        req_deadline = (
            None if deadline_ms is None
            else time.monotonic() + deadline_ms / 1000.0
        )
        ticket = SearchTicket(q, params or self.params, deadline=req_deadline)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                if self._closed:
                    self.metrics.bump("rejected")
                    raise EngineClosed("engine is shut down")
                if len(self._pending) < self.max_queue:
                    break
                if not block:
                    self.metrics.bump("rejected")
                    raise QueueFull(
                        f"admission queue at capacity ({self.max_queue})"
                    )
                if deadline is None:
                    self._cv.wait()
                    continue
                # wait against a fixed deadline: wakeups where another
                # submitter won the freed slot must not restart the clock
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    self.metrics.bump("rejected")
                    raise QueueFull(
                        f"admission queue still full after {timeout}s"
                    )
                self._cv.wait(remaining)
            self._pending.append(ticket)
            self.metrics.bump("admitted")
            self._cv.notify_all()
        return ticket

    def search(
        self,
        queries,
        params: Optional[SearchParams] = None,
        *,
        timeout: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Synchronous convenience: submit and wait for the result.

        In step mode (no serve thread) the calling thread pumps
        :meth:`step` itself, so results are produced deterministically with
        zero background threads — the mode the bit-equality tests drive.
        """
        ticket = self.submit(queries, params, timeout=timeout)
        if not self.running:
            while not ticket.done:
                if self.step() == 0 and not ticket.done:
                    raise RuntimeError(
                        "step() made no progress on a pending ticket"
                    )
            return ticket.result(0)
        return ticket.result(timeout)

    # -- writes (routed so the maintainer can log + replay them) -------------

    def insert(self, points, values=None) -> np.ndarray:
        """Stream rows into the serving index; returns stable external ids.

        While a shadow compaction is in flight the write is ALSO appended
        to the replay log: id assignment is sequential, so replaying the
        log on the shadow reproduces identical external ids.

        Raises :class:`EngineDegraded` (fast, before touching the index)
        when the engine is in degraded read-only mode, and ENTERS that
        mode if this write's WAL append fails.

        Writes hold the serve lock EXCLUSIVELY: in-flight searches finish
        first (they share the read side), and no search observes a
        half-applied insert or a mid-seal segment list.
        """
        with self._serve_lock.write_locked():
            index = self.index
            if not hasattr(index, "insert"):
                raise TypeError(
                    f"{type(index).__name__} is immutable — the engine "
                    "serves it read-only"
                )
            self._check_writable()
            pts = np.asarray(jax.device_get(points), np.float32)
            vals = (
                None if values is None
                else np.asarray(jax.device_get(values)).copy()
            )
            try:
                ids = index.insert(pts, vals)
            except WalWriteError as e:
                self._enter_degraded(str(e))
                raise EngineDegraded(
                    f"write-ahead log unwritable, write refused: {e}"
                ) from e
            with self._state_lock:
                if self._write_log is not None:
                    self._write_log.append(("insert", pts.copy(), vals))
            self.metrics.bump("inserts", int(np.atleast_1d(ids).shape[0]))
            return ids

    def delete(self, ids) -> int:
        """Tombstone external ids on the serving index (logged like insert)."""
        with self._serve_lock.write_locked():
            index = self.index
            if not hasattr(index, "delete"):
                raise TypeError(
                    f"{type(index).__name__} is immutable — the engine "
                    "serves it read-only"
                )
            self._check_writable()
            idn = np.asarray(jax.device_get(ids)).copy()
            try:
                n = index.delete(idn)
            except WalWriteError as e:
                self._enter_degraded(str(e))
                raise EngineDegraded(
                    f"write-ahead log unwritable, write refused: {e}"
                ) from e
            with self._state_lock:
                if self._write_log is not None:
                    self._write_log.append(("delete", idn, None))
            self.metrics.bump("deletes", int(n))
            return n

    def _check_writable(self) -> None:
        if self._degraded_reason is not None:
            self.metrics.bump("writes_rejected_degraded")
            raise EngineDegraded(
                "engine is in degraded read-only mode: "
                + self._degraded_reason
            )

    def values_at(self, ids, fill=0):
        """Per-point payload gather on the serving index (kNN-LM tokens).

        A pure read: shares the lock with searches, excludes writes.
        """
        with self._serve_lock.read_locked():
            return self.index.values_at(ids, fill=fill)

    # -- the serve loop ------------------------------------------------------

    def _take_batch_locked(self) -> List[SearchTicket]:
        """Form the next micro-batch earliest-deadline-first.

        Caller holds ``self._cv``.  Scheduling policy lives in the pure
        :func:`repro.serve.batching.form_batch` (the property-tested
        piece); this method owns the side effects: expired tickets are
        failed with :class:`DeadlineExceeded` BEFORE any dispatch, taken
        + shed tickets leave the queue, and submitters blocked on a full
        queue are woken.
        """
        plan = form_batch(
            self._pending,
            max_rows=self.max_batch,
            now=time.monotonic(),
            no_deadline_horizon=self.edf_horizon_s,
        )
        if not plan.batch and not plan.expired:
            return []
        taken = {id(t) for t in plan.batch} | {id(t) for t in plan.expired}
        self._pending = deque(
            t for t in self._pending if id(t) not in taken
        )
        for t in plan.expired:
            # shed BEFORE dispatch: stale work is dropped, not served
            t._fail(DeadlineExceeded(
                "request deadline passed while queued"
            ))
            self.metrics.bump("deadline_expired")
        self._cv.notify_all()  # wake submitters blocked on a full queue
        return list(plan.batch)

    def _execute(self, batch: List[SearchTicket]) -> None:
        with self._state_lock:
            ref = self._current
            ref.checkout()
        now = time.perf_counter()
        for t in batch:
            t.batched_at = now
            self.metrics.queue_wait.record(1000.0 * (now - t.submitted_at))
        try:
            q = np.concatenate([t.queries for t in batch])
            params = batch[0].params
            with span("engine.batch", requests=len(batch),
                      rows=int(q.shape[0]), epoch=ref.epoch):
                m = min(q.shape[0], self.query_chunk)
                warm_key = (params, _pow2_bucket(m, self.query_chunk))
                with self._state_lock:
                    if warm_key not in self._warm_queries:
                        # retained so maintenance can pre-warm the shadow's
                        # compiled dispatches for every dispatch bucket the
                        # live traffic has hit (state-locked: serve workers
                        # run this concurrently)
                        self._warm_queries[warm_key] = q[:m].copy()
                with self._serve_lock.read_locked():
                    # SHARED side: concurrent batches (serve_threads > 1)
                    # search together; writes/snapshot/swap exclude us.
                    # Timed inside the lock: batch_latency is the search
                    # execution itself; queue + lock wait shows up in the
                    # per-ticket latency instead
                    t0 = time.perf_counter()
                    with span("engine.search", rows=int(q.shape[0])):
                        if self.pipeline:
                            ids, dists = pipelined_search(
                                ref.index, q, params, backend=self.backend,
                                query_chunk=self.query_chunk,
                                **self._search_kwargs,
                            )
                        else:
                            ids, dists = ref.index.search(
                                q, params, backend=self.backend,
                                query_chunk=self.query_chunk,
                                **self._search_kwargs,
                            )
                        ids = np.asarray(jax.device_get(ids))
                        dists = np.asarray(jax.device_get(dists))
                    if self.recall_probe is not None:
                        # under the serve lock: snapshot() must not race
                        # concurrent writes to a mutable layout
                        self.recall_probe.offer(q, ids, params.k, ref.index)
            self.metrics.batch_latency.record(
                1000.0 * (time.perf_counter() - t0)
            )
            self.metrics.bump("batches")
            self.metrics.bump("rows_searched", int(q.shape[0]))
            off = 0
            for t in batch:
                m = t.queries.shape[0]
                t._complete(ids[off : off + m], dists[off : off + m],
                            ref.epoch)
                off += m
        except BaseException as e:  # fail the whole batch, keep serving
            for t in batch:
                t._fail(e)
        finally:
            ref.checkin()
        for t in batch:
            if t.latency_ms is not None:
                self.metrics.latency.record(t.latency_ms)
            self.metrics.bump("completed")

    def step(self) -> int:
        """Serve ONE micro-batch synchronously; returns requests served.

        The deterministic single-thread mode: exactly what the serve
        thread runs, minus the waiting.  Returns 0 when the queue is
        empty.
        """
        with self._cv:
            batch = self._take_batch_locked()
        if not batch:
            return 0
        self._execute(batch)
        return len(batch)

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait(0.05)
                if not self._pending and self._closed:
                    return  # drained + closed: clean exit
                batch = self._take_batch_locked()
            if batch:
                self._execute(batch)

    # -- background maintenance + double-buffered swap -----------------------

    def maintenance_stats(self) -> Dict[str, Any]:
        """The serving index's trigger signals (empty for static layouts).

        Adds ``rewrite_pressure`` (segments tombstoned past their
        candidate pool under the engine's default params) — the condition
        the facades used to fix by rewriting inside ``search()``, now a
        maintenance trigger because the engine's read path must not
        mutate (shared read lock).
        """
        with self._serve_lock.read_locked():
            index = self.index
            if not hasattr(index, "maintenance_stats"):
                return {}
            stats = index.maintenance_stats()
            if hasattr(index, "rewrite_pressure"):
                stats["rewrite_pressure"] = index.rewrite_pressure(
                    self.params
                )
            return stats

    def maintain_once(self, force: bool = False) -> bool:
        """One full maintenance cycle; returns True iff an index swap
        happened.

        Protocol (the serve lock is held EXCLUSIVELY only for the cheap
        steps — searches keep flowing through 2 and 3):

        1. snapshot the serving index + open the write replay log  (write lock)
        2. compact the shadow — in-thread or in a child process
           (``compaction="subprocess"``), the expensive part        (NO lock)
        3. catch-up rounds: drain the log so far onto the shadow,
           then pre-warm the shadow's compiled dispatches with the
           batch shapes the serve loop has actually seen            (NO lock)
        4. drain the final log tail, swap the pointer               (write lock)
        5. wait for the old epoch's in-flight refcount to drain

        :attr:`last_swap_timeline` records ``*_locked`` booleans per
        phase — the benchmark asserts from them that the lock was held
        exclusively ONLY at snapshot and swap.

        Step 3 is what keeps the dispatch-cache promise across swaps: a
        compacted index has a different LSM shape (and replayed writes a
        different buffer occupancy), so without it the FIRST post-swap
        query would pay the retrace/compile on the query path — the
        exact stall the shadow copy exists to avoid.  Warming runs after
        each off-lock catch-up round, so by the final locked drain the
        remaining log tail is small and its shapes almost surely
        compiled.

        ``force=True`` skips the threshold check (benchmarks use it).
        Static layouts and layouts whose segments lack stored points
        return False without touching anything.

        Cycles are mutually exclusive: a concurrent caller (the
        maintainer thread vs. a forced ``store.compact()``) blocks on an
        internal mutex until the in-flight cycle finishes, then runs its
        own — two interleaved cycles would clobber each other's replay
        log (silent write loss) and race the epoch swap.
        """
        with self._maint_lock:
            return self._maintain_cycle(force)

    def _maintain_cycle(self, force: bool) -> bool:
        """The body of :meth:`maintain_once`; caller holds ``_maint_lock``.

        Each phase is spanned and timed; the whole cycle's durations land
        in :attr:`last_swap_timeline` (and the registry's
        ``engine_maint_<phase>_ms`` recorders) so a swap can be read as a
        timeline: how long the shadow compact ran, how many logged writes
        each replay round drained, and how long the serve lock was
        actually held for the final tail + pointer swap.
        """
        timeline: Dict[str, Any] = {
            "log_depth": 0,
            "replay_rounds": 0,
            "compaction": self.compaction,
        }

        def clock(phase: str, t0: float) -> None:
            timeline[f"{phase}_ms"] = 1000.0 * (time.perf_counter() - t0)

        cycle = span("maint.cycle")
        cycle.__enter__()
        try:
            t0 = time.perf_counter()
            with self._serve_lock.write_locked(), span("maint.snapshot"):
                # EXCLUSIVE: the snapshot + log-open must be atomic
                # against writes (a write between them would be neither
                # snapshotted nor logged = silently lost on swap)
                timeline["snapshot_locked"] = self._serve_lock.write_held()
                index = self.index
                if not (hasattr(index, "snapshot")
                        and hasattr(index, "compact")):
                    return False
                stats = index.maintenance_stats()
                if hasattr(index, "rewrite_pressure"):
                    stats["rewrite_pressure"] = index.rewrite_pressure(
                        self.params
                    )
                policy = self.maintenance or MaintenancePolicy()
                if not force and not policy.triggered(stats):
                    return False
                if force and stats.get("mergeable_segments", 0) < 1:
                    return False  # nothing compactable (store_points=False)
                shadow = index.snapshot()
                with self._state_lock:
                    self._write_log = []
            clock("snapshot", t0)
            self.metrics.bump("maintenance_runs")
            fault_point("engine.maint.pre_compact")
            t0 = time.perf_counter()
            try:
                # NO serve lock held: serving continues while the shadow
                # compacts (in-thread or in a child process).  Subprocess
                # mode returns a NEW object (the reloaded bundle).
                timeline["compact_locked"] = self._serve_lock.write_held()
                shadow = self._compact_shadow(
                    shadow, policy, int(stats.get("n_segments", 0)),
                    timeline,
                )
            except BaseException:
                with self._state_lock:
                    self._write_log = None
                raise
            clock("compact", t0)
            fault_point("engine.maint.post_compact")

            def apply(log):
                for op, a, b in log:
                    if op == "insert":
                        shadow.insert(a, b)
                    else:
                        shadow.delete(a)

            def warm():
                # compile the post-swap shapes off-path (results
                # discarded); a failure here would fail identically after
                # the swap, so let it propagate and abandon the shadow
                for (p, _bucket), wq in list(self._warm_queries.items()):
                    shadow.search(wq, p, backend=self.backend,
                                  query_chunk=self.query_chunk)

            # catch-up rounds: bounded, so a writer outpacing replay can't
            # starve the swap — the final tail drains under the serve
            # lock.  Any failure abandons the shadow AND closes the replay
            # log, else the write path keeps copying into a log nobody
            # will drain.
            fault_point("engine.maint.pre_replay")
            replay_ms = prewarm_ms = 0.0
            timeline["replay_locked"] = self._serve_lock.write_held()
            try:
                for _ in range(4):
                    with self._state_lock:
                        log, self._write_log = self._write_log, []
                    timeline["log_depth"] += len(log)
                    timeline["replay_rounds"] += 1
                    t0 = time.perf_counter()
                    with span("maint.replay", ops=len(log)):
                        apply(log)
                    replay_ms += 1000.0 * (time.perf_counter() - t0)
                    t0 = time.perf_counter()
                    with span("maint.prewarm",
                              shapes=len(self._warm_queries)):
                        warm()
                    prewarm_ms += 1000.0 * (time.perf_counter() - t0)
                    if not log:
                        break
            except BaseException:
                with self._state_lock:
                    self._write_log = None
                raise
            t0 = time.perf_counter()
            with self._serve_lock.write_locked(), span("maint.swap"):
                timeline["swap_locked"] = self._serve_lock.write_held()
                with self._state_lock:
                    log = self._write_log or []
                    self._write_log = None
                timeline["log_depth"] += len(log)
                timeline["tail_ops"] = len(log)
                apply(log)
                # Transfer the WAL old -> shadow: the shadow deliberately
                # snapshots WITHOUT one (replay must not re-log), and
                # every op applied to it was logged when the old index
                # acknowledged it — the log is exactly as durable for the
                # shadow as it was for the index it replaces.
                if hasattr(index, "detach_wal"):
                    w = index.detach_wal()
                    if w is not None:
                        shadow._wal = w
                fault_point("engine.maint.pre_swap")
                with self._state_lock:
                    old = self._current
                    self._current = _Epoch(shadow, old.epoch + 1)
                self.metrics.bump("swaps")
                fault_point("engine.maint.post_swap")
            clock("swap", t0)
            timeline["replay_ms"] = replay_ms
            timeline["prewarm_ms"] = prewarm_ms
            t0 = time.perf_counter()
            old.wait_drained()  # in-flight batches finish on the old index
            clock("drain", t0)
            timeline["epoch"] = self._current.epoch
            reg = default_registry()
            for phase in ("snapshot", "compact", "replay", "prewarm",
                          "swap", "drain"):
                reg.latency(f"engine_maint_{phase}_ms", capacity=1024).record(
                    timeline.get(f"{phase}_ms", 0.0)
                )
            reg.gauge("engine_maint_last_log_depth").set(
                timeline["log_depth"]
            )
            self.last_swap_timeline = timeline
            return True
        finally:
            cycle.__exit__(None, None, None)

    def _compact_shadow(self, shadow, policy: MaintenancePolicy,
                        n_segments: int,
                        timeline: Optional[Dict[str, Any]] = None):
        """Compact the shadow under the ``max_cycle_s`` watchdog; returns
        the compacted shadow (a NEW object in subprocess mode).

        ``compaction="thread"``: the compact runs on a helper thread so a
        hang (wedged device, pathological merge) can be ABANDONED — the
        serving index was never touched, so dropping the shadow loses
        nothing but the cycle's work.  The orphaned thread finishes (or
        hangs) against an object nobody references anymore.
        ``max_cycle_s=None`` compacts inline.

        ``compaction="subprocess"``: the shadow is saved as a
        format_version-5 bundle and compacted by a CHILD process
        (:func:`repro.serve.compactor.compact_in_child`); the verified
        result bundle is reloaded and returned.  A child that dies, hangs
        past the watchdog, or produces an unverifiable bundle fails ONLY
        this cycle (:class:`CompactionChildError` /
        :class:`MaintenanceTimeout`); the maintainer backs off and
        retries.
        """
        budget = policy.max_cycle_s
        with span("maint.compact", segments=n_segments,
                  mode=self.compaction):
            if self.compaction == "subprocess":
                return self._compact_in_subprocess(shadow, budget, timeline)
            if budget is None:
                shadow.compact()
                return shadow
            err: List[BaseException] = []

            def run() -> None:
                try:
                    shadow.compact()
                except BaseException as e:
                    err.append(e)

            th = threading.Thread(
                target=run, name="maint-compact", daemon=True
            )
            th.start()
            th.join(budget)
            if th.is_alive():
                self.metrics.bump("maintenance_timeouts")
                raise MaintenanceTimeout(
                    f"shadow compact exceeded {budget}s; shadow abandoned"
                )
            if err:
                raise err[0]
            return shadow

    def _compact_in_subprocess(self, shadow, budget: Optional[float],
                               timeline: Optional[Dict[str, Any]]):
        """Hand the shadow to ``python -m repro.serve.compactor``."""
        workdir = self.compaction_dir
        scratch = None
        if workdir is None:
            scratch = tempfile.mkdtemp(prefix="repro-compact-")
            workdir = scratch
        try:
            try:
                compacted, phases = compact_in_child(
                    shadow, workdir, timeout=budget,
                    mesh=getattr(shadow, "mesh", None),
                )
            except subprocess.TimeoutExpired as e:
                self.metrics.bump("maintenance_timeouts")
                raise MaintenanceTimeout(
                    f"compactor child exceeded {budget}s; shadow abandoned"
                ) from e
            reg = default_registry()
            for key in ("save_in_ms", "child_ms", "load_out_ms"):
                reg.latency(f"engine_maint_{key}", capacity=1024).record(
                    float(phases.get(key, 0.0))
                )
            if timeline is not None:
                timeline["compactor_phases"] = phases
            return compacted
        finally:
            if scratch is not None:
                shutil.rmtree(scratch, ignore_errors=True)

    def score_recall(self) -> int:
        """Score pending recall-probe batches (exact shadow, host math).

        Runs on the CALLING thread — the maintainer calls it between
        cycles, so scoring never touches the query path; step-mode
        engines (and engines without a maintainer) call it by hand.
        Returns per-query samples produced (0 when probing is off).
        """
        if self.recall_probe is None:
            return 0
        with span("engine.recall_score"):
            return self.recall_probe.score_pending()

    def _maintenance_loop(self) -> None:
        policy = self.maintenance or MaintenancePolicy()
        backoff_gauge = default_registry().gauge("engine_maint_backoff_s")
        failures = 0
        while not self._stop_event.wait(policy.poll_interval_s):
            try:
                if self.maintenance is not None:
                    self.maintain_once()
                self.score_recall()
                failures = 0
                backoff_gauge.set(0.0)
            except BaseException as e:
                # maintenance must never take serving down; surface the
                # error for operators/tests, back off (capped exponential
                # — a persistently failing compact can't hot-loop the
                # maintainer), and keep the loop alive.
                self.last_maintenance_error = e
                self.metrics.bump("maintenance_failures")
                failures += 1
                delay = min(
                    policy.backoff_max_s,
                    policy.backoff_initial_s * (2 ** (failures - 1)),
                )
                backoff_gauge.set(delay)
                if self._stop_event.wait(delay):
                    return

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "RetrievalEngine":
        """Spawn the serve worker(s) (+ maintainer when a policy is set).

        ``serve_threads`` workers run the same loop over the shared
        queue; with more than one, micro-batches execute concurrently
        under the shared read side of the serve lock.
        """
        if self.running:
            return self
        self._closed = False
        self._stop_event.clear()
        self._workers = [
            threading.Thread(
                target=self._serve_loop,
                name=f"retrieval-serve-{i}", daemon=True,
            )
            for i in range(self.serve_threads)
        ]
        for w in self._workers:
            w.start()
        want_maint = (
            self.maintenance is not None and hasattr(self.index, "snapshot")
        )
        # the maintainer doubles as the recall scorer, so a probe-enabled
        # engine needs the loop even over a static (no-snapshot) layout —
        # maintain_once() is then a cheap immediate no-op
        if want_maint or self.recall_probe is not None:
            self._maintainer = threading.Thread(
                target=self._maintenance_loop, name="retrieval-maintenance",
                daemon=True,
            )
            self._maintainer.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Shut down: close admission, then drain or fail pending requests.

        ``drain=True`` (default) serves everything already admitted before
        the serve thread exits; ``drain=False`` fails pending tickets with
        :class:`EngineClosed`.  Always joins both threads.  Idempotent;
        if a join times out, ``TimeoutError`` is raised with the engine
        partially stopped (admission closed, the stuck thread's handle
        retained) and a later ``stop()`` re-attempts the join and drain.
        """
        with self._cv:
            self._closed = True
            if not drain:
                while self._pending:
                    self._pending.popleft()._fail(
                        EngineClosed("engine stopped without draining")
                    )
            self._cv.notify_all()
        self._stop_event.set()
        # on join timeout the handle is RETAINED (and we raise), so a
        # retrying stop() re-joins the same thread instead of behaving as
        # if shutdown had completed
        if self._maintainer is not None:
            self._maintainer.join(timeout)
            if self._maintainer.is_alive():
                raise TimeoutError(
                    "maintenance thread did not stop in time"
                )
            self._maintainer = None
        for w in self._workers:
            w.join(timeout)
            if w.is_alive():
                raise TimeoutError("serve thread did not drain in time")
        self._workers = []
        # step-mode engines (never started) drain synchronously
        if drain:
            while self.step():
                pass
            self.score_recall()  # don't strand sampled batches unscored
        else:
            with self._cv:
                while self._pending:
                    self._pending.popleft()._fail(
                        EngineClosed("engine stopped without draining")
                    )

    def __enter__(self) -> "RetrievalEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc[0] is None)

    def __repr__(self) -> str:
        return (
            f"RetrievalEngine(index={type(self.index).__name__}, "
            f"epoch={self.epoch}, queue={self.queue_depth}/{self.max_queue}, "
            f"max_batch={self.max_batch}, running={self.running}, "
            f"maintenance={'on' if self.maintenance else 'off'})"
        )
