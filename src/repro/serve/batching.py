"""Deadline-ordered (EDF) micro-batch formation, as a pure function.

PR 6 formed micro-batches FIFO: the queue head led, and a long-deadline
bulk scan arriving first could head-block a short-deadline interactive
request behind it.  :func:`form_batch` replaces that with earliest-
deadline-first selection over the whole queue, while keeping every
invariant the engine's bit-equality tests rely on:

* **EDF order** — the ticket with the earliest *effective* deadline
  leads; the rest of the batch is the EDF-order prefix of the live
  tickets sharing the lead's :class:`SearchParams` that fits the row cap.
* **Expiry shedding** — tickets whose deadline has already passed are
  shed BEFORE dispatch (returned in ``BatchPlan.expired``), never batched.
* **Params homogeneity** — one batch, one ``SearchParams``: heterogeneous
  params cost extra batches, never wrong results.  Unlike FIFO, a
  different-params ticket no longer ends the batch — it simply waits for
  its own class's turn (no head-of-line blocking across params classes).
* **No starvation** — a ticket submitted without a deadline gets the
  effective deadline ``submitted_mono + no_deadline_horizon``: it ages
  like everything else, so a steady stream of fresh urgent tickets can
  delay it by at most the horizon (the fairness bound the property tests
  assert), never forever.

Purity is the point: the function reads ``now`` as an argument, mutates
nothing, and returns a :class:`BatchPlan` partition of its input — the
engine applies the plan under its queue lock, and Hypothesis drives the
function directly with no engine, no clock, no threads.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

__all__ = ["BatchPlan", "effective_deadline", "form_batch"]

#: Effective-deadline horizon (seconds) for tickets submitted without one.
DEFAULT_NO_DEADLINE_HORIZON_S = 60.0


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """The pure output of :func:`form_batch`: a partition of the queue.

    ``batch`` dispatches now (EDF order, params-homogeneous, row-capped);
    ``expired`` is shed before dispatch; every other input ticket stays
    queued.  ``batch + expired + remaining`` is exactly the input — the
    conservation property the tests assert.
    """

    batch: Tuple[Any, ...]
    expired: Tuple[Any, ...]

    @property
    def rows(self) -> int:
        return sum(int(t.queries.shape[0]) for t in self.batch)


def effective_deadline(
    ticket: Any,
    no_deadline_horizon: float = DEFAULT_NO_DEADLINE_HORIZON_S,
) -> float:
    """A ticket's EDF priority instant (monotonic-clock seconds).

    Tickets carrying a real deadline use it.  Deadline-less tickets age
    from their submission instant plus the horizon — still a finite
    instant, so they cannot be starved by an endless stream of
    deadline-bearing arrivals (eventually their effective deadline is the
    earliest in the queue).
    """
    if ticket.deadline is not None:
        return float(ticket.deadline)
    return float(ticket.submitted_mono) + float(no_deadline_horizon)


def form_batch(
    pending: Sequence[Any],
    *,
    max_rows: int,
    now: float,
    no_deadline_horizon: float = DEFAULT_NO_DEADLINE_HORIZON_S,
) -> BatchPlan:
    """Select one EDF micro-batch (and the expired tickets to shed).

    Args:
      pending: queued tickets.  Each needs ``queries.shape[0]`` (rows),
        ``params`` (hashable, equality-comparable), ``deadline`` (a
        monotonic instant or None) and ``submitted_mono`` (monotonic
        submission instant) — the duck-typed subset of
        :class:`~repro.serve.engine.SearchTicket`.  ``seq`` (admission
        order) breaks deadline ties deterministically when present.
      max_rows: micro-batch row cap.  The lead ticket is exempt (a single
        oversized request still dispatches, alone) — the cap bounds
        *batching*, it does not reject admitted work.
      now: the current monotonic instant (passed in: purity).
      no_deadline_horizon: aging horizon for deadline-less tickets.

    Returns a :class:`BatchPlan`; ``plan.batch`` is empty only when every
    pending ticket expired (or ``pending`` itself is empty).
    """
    if max_rows < 1:
        raise ValueError(f"max_rows must be >= 1, got {max_rows}")
    live = []
    expired = []
    for t in pending:
        if t.deadline is not None and now > t.deadline:
            expired.append(t)
        else:
            live.append(t)
    if not live:
        return BatchPlan((), tuple(expired))

    def key(t):
        return (
            effective_deadline(t, no_deadline_horizon),
            getattr(t, "seq", 0),
        )

    order = sorted(live, key=key)
    lead = order[0]
    batch = [lead]
    rows = int(lead.queries.shape[0])
    for t in order[1:]:
        if t.params != lead.params:
            continue  # a different class waits its turn, blocks nothing
        r = int(t.queries.shape[0])
        if rows + r > max_rows:
            # stop at the first same-params ticket that does not fit:
            # taking a LATER-deadline ticket instead would break EDF order
            # within the class
            break
        batch.append(t)
        rows += r
    return BatchPlan(tuple(batch), tuple(expired))
