from repro.serve.engine import (  # noqa: F401
    EngineClosed,
    MaintenancePolicy,
    QueueFull,
    RetrievalEngine,
    SearchTicket,
)
from repro.serve.metrics import (  # noqa: F401
    EngineMetrics,
    LatencyRecorder,
    percentiles,
)
from repro.serve.pipeline import pipelined_search  # noqa: F401
from repro.serve.retrieval import RetrievalStore, knn_lm_mix  # noqa: F401
