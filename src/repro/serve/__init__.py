from repro.serve.retrieval import RetrievalStore, knn_lm_mix  # noqa: F401
