from repro.serve.batching import (  # noqa: F401
    BatchPlan,
    effective_deadline,
    form_batch,
)
from repro.serve.compactor import (  # noqa: F401
    CompactionChildError,
    compact_in_child,
)
from repro.serve.engine import (  # noqa: F401
    DeadlineExceeded,
    EngineClosed,
    EngineDegraded,
    MaintenancePolicy,
    MaintenanceTimeout,
    QueueFull,
    RetrievalEngine,
    SearchTicket,
)
from repro.serve.metrics import (  # noqa: F401
    EngineMetrics,
    LatencyRecorder,
    percentiles,
)
from repro.serve.pipeline import pipelined_search  # noqa: F401
from repro.serve.retrieval import RetrievalStore, knn_lm_mix  # noqa: F401
from repro.serve.rwlock import ReadWriteLock  # noqa: F401
