"""Serving metrics: the engine's view over the process metrics registry.

``LatencyRecorder`` and ``percentiles`` now live in
:mod:`repro.obs.registry` (core/index instrumentation needs them without
importing the serving layer) and are re-exported here unchanged for
compatibility.  ``EngineMetrics`` remains the engine's own view — its
counters and recorders are plain attributes the engine bumps with one
lock each — but every bump is mirrored into the process-global registry
(``engine_<name>_total`` counters, ``engine_request_ms`` /
``engine_search_ms`` / ``engine_queue_wait_ms`` recorders), so the
``/metrics`` endpoint and the JSON snapshot see the engine without the
engine knowing about exporters.

A latency sample is one float append, a counter bump two integer adds
(local + registry) — still cheap enough to record per request on the
serving path.
"""

from __future__ import annotations

from typing import Dict, Optional

from ..obs.registry import (
    LatencyRecorder,
    MetricsRegistry,
    default_registry,
    percentile_label,
    percentiles,
)

__all__ = [
    "LatencyRecorder", "EngineMetrics", "percentiles", "percentile_label",
]


class EngineMetrics:
    """Counters + gauges + latency recorders for one serving engine.

    * ``latency`` — submit→result wall time per request (queue wait
      included: what a caller experiences).
    * ``queue_wait`` — submit→batch-formation wait per request: the
      admission-to-dispatch slice of ``latency``, the first place to
      look when request p99 diverges from search p99.
    * ``batch_latency`` — search execution wall time per micro-batch
      (timed inside the serve lock: the query path proper).
    * counters — requests admitted/rejected/completed, batches executed,
      rows searched, index swaps, maintenance runs, write ops.

    Registered in the process registry at construction: the latest
    engine owns the ``engine_*`` series (an engine restart re-binds
    them — the registry's replace semantics).
    """

    def __init__(self, capacity: int = 65536,
                 registry: Optional[MetricsRegistry] = None):
        self._registry = registry or default_registry()
        self.latency = self._registry.replace_latency(
            "engine_request_ms", capacity
        )
        self.queue_wait = self._registry.replace_latency(
            "engine_queue_wait_ms", capacity
        )
        self._batch_latency = self._registry.replace_latency(
            "engine_search_ms", capacity
        )
        self._counters: Dict[str, "object"] = {}
        for name in ("admitted", "rejected", "completed", "batches",
                     "rows_searched", "inserts", "deletes", "swaps",
                     "maintenance_runs"):
            self._counters[name] = _LocalCounter(
                self._registry.counter(f"engine_{name}_total")
            )

    # ``batch_latency`` stays assignable: benchmarks install a fresh
    # recorder to scope percentiles to a measurement window.  Keep the
    # registry pointing at whichever recorder is current.
    @property
    def batch_latency(self) -> LatencyRecorder:
        return self._batch_latency

    @batch_latency.setter
    def batch_latency(self, rec: LatencyRecorder) -> None:
        self._batch_latency = rec
        key = self._registry._key("engine_search_ms", {})
        with self._registry._lock:
            self._registry._metrics[key] = rec
        rec.name, rec.labels = "engine_search_ms", {}  # type: ignore[attr-defined]

    def bump(self, name: str, by: int = 1) -> None:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = _LocalCounter(
                self._registry.counter(f"engine_{name}_total")
            )
        c.inc(by)

    def counter(self, name: str) -> int:
        c = self._counters.get(name)
        return 0 if c is None else c.value

    def snapshot(self) -> Dict[str, object]:
        counters = {k: v.value for k, v in sorted(self._counters.items())}
        return {
            "counters": counters,
            "latency_ms": self.latency.snapshot(),
            "queue_wait_ms": self.queue_wait.snapshot(),
            "batch_latency_ms": self.batch_latency.snapshot(),
        }


class _LocalCounter:
    """Engine-local count that mirrors into a registry counter.

    The local value is what ``EngineMetrics.counter()`` reports —
    per-engine, resets with the engine — while the registry counter is
    cumulative across engine restarts (Prometheus counters must be
    monotonic).
    """

    __slots__ = ("_local", "_mirror")

    def __init__(self, mirror):
        self._local = 0
        self._mirror = mirror

    def inc(self, by: int = 1) -> None:
        by = int(by)
        with self._mirror._lock:      # one lock keeps both views in step
            self._mirror._v += by
            self._local += by

    @property
    def value(self) -> int:
        with self._mirror._lock:
            return self._local
