"""Serving metrics: latency percentiles, counters, and queue gauges.

The engine's observability surface.  Everything is host-side and lock-free
for readers (snapshots copy under the recorder's lock), cheap enough to
record per request on the serving path: a latency sample is one float
append, a counter bump one integer add.

``LatencyRecorder`` keeps raw samples (bounded ring) so percentiles are
exact over the retained window rather than histogram-bucketed — tail
latency (p999) is the whole point of the serving engine, so the last thing
the metrics layer should do is quantize it away.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

__all__ = ["LatencyRecorder", "EngineMetrics", "percentiles"]


def percentiles(samples_ms, points=(50.0, 99.0, 99.9)) -> Dict[str, float]:
    """``{"p50": ..., "p99": ..., "p999": ...}`` over a sample list (ms).

    Uses the nearest-rank method on the sorted samples (what a latency SLO
    means operationally); returns an empty dict for no samples.
    """
    s = np.sort(np.asarray(list(samples_ms), np.float64))
    if s.size == 0:
        return {}
    out = {}
    for p in points:
        label = f"p{p:g}".replace(".", "")
        idx = min(s.size - 1, int(np.ceil(p / 100.0 * s.size)) - 1)
        out[label] = float(s[max(idx, 0)])
    return out


class LatencyRecorder:
    """Bounded ring of latency samples with exact percentile snapshots."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._cap = int(capacity)
        self._buf = np.zeros((self._cap,), np.float64)
        self._n = 0          # total ever recorded
        self._lock = threading.Lock()

    def record(self, latency_ms: float) -> None:
        with self._lock:
            self._buf[self._n % self._cap] = float(latency_ms)
            self._n += 1

    @property
    def count(self) -> int:
        return self._n

    def samples(self) -> np.ndarray:
        """Copy of the retained window (oldest-sample order not preserved)."""
        with self._lock:
            return self._buf[: min(self._n, self._cap)].copy()

    def snapshot(self, points=(50.0, 99.0, 99.9)) -> Dict[str, float]:
        s = self.samples()
        out = percentiles(s, points)
        out["count"] = float(self._n)
        if s.size:
            out["mean"] = float(s.mean())
            out["max"] = float(s.max())
        return out


class EngineMetrics:
    """Counters + gauges + latency recorders for one serving engine.

    * ``latency`` — submit→result wall time per request (queue wait
      included: what a caller experiences).
    * ``batch_latency`` — device-side wall time per executed micro-batch.
    * counters — requests admitted/rejected/completed, batches executed,
      rows searched, index swaps, maintenance runs, write ops.
    """

    def __init__(self, capacity: int = 65536):
        self.latency = LatencyRecorder(capacity)
        self.batch_latency = LatencyRecorder(capacity)
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {
            "admitted": 0,
            "rejected": 0,
            "completed": 0,
            "batches": 0,
            "rows_searched": 0,
            "inserts": 0,
            "deletes": 0,
            "swaps": 0,
            "maintenance_runs": 0,
        }

    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(by)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            counters = dict(self._counters)
        return {
            "counters": counters,
            "latency_ms": self.latency.snapshot(),
            "batch_latency_ms": self.batch_latency.snapshot(),
        }
