"""Out-of-process LSM compaction: the shadow compacts in a child process.

The in-thread shadow compact (PR 6) kept maintenance off the query
*path*, but on a CPU host — where the "device" IS the host cores — it
still contends with serving for silicon and for the GIL.  This module
moves the expensive step out of the serving process entirely, the way
real LSM stores do, using the format_version-5 bundle machinery as the
handoff (PR 8 made save/load bit-exact and crash-verifiable, which is
what makes this protocol provable rather than hopeful):

parent (serving process)                 child (``python -m repro.serve.compactor``)
------------------------                 ------------------------------------------
snapshot() the serving index
save(workdir/in)           ── spawn ──►  load(workdir/in)
keep serving + logging writes            compact()
                                         save(workdir/out)
                                         atomically commit result marker
load(workdir/out)          ◄── exit ──
verify marker vs loaded state
replay write log, swap epoch  (the engine's existing protocol)

Safety properties, each exercised by the ``compactor`` lane of
``scripts/crash_check.py`` (SIGKILL at every registered fault point in
the child):

* the parent NEVER trusts ``workdir/out`` unless the child exited 0 AND
  the result marker — written atomically, after the bundle — is present
  and matches the reloaded index (a partially-written bundle is
  indistinguishable from a missing one: both fail the cycle);
* a failed/killed/hung child fails ONLY that maintenance cycle: the
  serving index received every write first and stays authoritative, and
  the engine's capped-exponential backoff schedules the retry;
* the snapshot is saved WITHOUT a WAL (snapshots never carry one), so
  nothing is ever double-logged across the process boundary; the live
  WAL transfers old → new index at swap time exactly as before.

Fault-point arming crosses the process boundary via dedicated variables:
``REPRO_COMPACTOR_FAULTS`` / ``REPRO_COMPACTOR_FAULT_TRACE`` in the
parent's environment become the child's ``REPRO_FAULTS`` /
``REPRO_FAULT_TRACE`` (and the parent's own are stripped from the child),
so the crash matrix can kill the child deterministically without the
arming leaking into the serving process.
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import time
from typing import Any, Dict, Optional, Tuple

__all__ = [
    "CompactionChildError",
    "compact_in_child",
    "child_main",
]

_RESULT_MARKER = "compact_result.json"
_MUTABLE_MANIFEST = "mutable_manifest.json"
_SHARDED_MANIFEST = "sharded_mutable_manifest.json"


class CompactionChildError(RuntimeError):
    """The compaction child failed/died/produced an unverifiable bundle.

    Raised in the PARENT; the maintenance cycle fails, the shadow is
    abandoned, and the serving index (which received every write first)
    stays authoritative.  The engine's maintainer backs off and retries.
    """


def _detect_layout(path: str) -> str:
    if os.path.exists(os.path.join(path, _SHARDED_MANIFEST)):
        return "sharded_mutable"
    if os.path.exists(os.path.join(path, _MUTABLE_MANIFEST)):
        return "mutable"
    raise FileNotFoundError(
        f"no mutable/sharded-mutable manifest under {path!r}"
    )


def _summary(index) -> Dict[str, int]:
    """The identity a compaction must preserve: the live set and the id
    cursor.  Compared parent-side against the child's marker AND against
    the reloaded bundle (three-way agreement before a swap is allowed)."""
    stats = index.maintenance_stats()
    return {
        "n_live": int(stats["n_live"]),
        "n_deleted": int(stats["n_deleted"]),
        "next_id": int(index._lsm.next_id),
    }


def _load(path: str, layout: str, mesh=None):
    if layout == "sharded_mutable":
        from repro.index.sharded_mutable import ShardedMutableHilbertIndex

        if mesh is None:
            from repro.launch.mesh import data_mesh

            with open(os.path.join(path, _SHARDED_MANIFEST)) as f:
                mesh = data_mesh(int(json.load(f)["n_shards"]))
        return ShardedMutableHilbertIndex.load(path, mesh=mesh)
    from repro.index.mutable import MutableHilbertIndex

    return MutableHilbertIndex.load(path)


# -- child entry point -------------------------------------------------------


def child_main(argv=None) -> int:
    """``python -m repro.serve.compactor IN_DIR OUT_DIR``: load, compact,
    save, then atomically commit the result marker (the commit point the
    parent keys on — bundle files without a marker are never trusted)."""
    args = list(sys.argv[1:] if argv is None else argv)
    if len(args) != 2:
        print("usage: python -m repro.serve.compactor IN_DIR OUT_DIR",
              file=sys.stderr)
        return 2
    in_dir, out_dir = args
    from repro.checkpoint import atomic_write_json
    from repro.testing.faults import fault_point

    t0 = time.perf_counter()
    layout = _detect_layout(in_dir)
    index = _load(in_dir, layout)
    fault_point("compactor.child.loaded", path=in_dir)
    pre = _summary(index)
    load_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    index.compact()
    fault_point("compactor.child.compacted", path=out_dir)
    compact_s = time.perf_counter() - t1

    t2 = time.perf_counter()
    index.save(out_dir)
    # marker LAST: its atomic rename is the child's commit point.  A kill
    # anywhere above leaves out_dir unmarked (or partial) and the parent
    # refuses it wholesale.
    fault_point("compactor.child.pre_marker", path=out_dir)
    atomic_write_json(os.path.join(out_dir, _RESULT_MARKER), {
        "layout": layout,
        "summary": _summary(index),
        "pre_compact_summary": pre,
        "n_segments": int(index.n_segments),
        "pid": os.getpid(),
        "phases_s": {
            "load": load_s,
            "compact": compact_s,
            "save": time.perf_counter() - t2,
        },
    })
    fault_point("compactor.child.post_marker", path=out_dir)
    return 0


# -- parent-side driver ------------------------------------------------------


def _child_env() -> Dict[str, str]:
    env = dict(os.environ)
    # the parent's own armed faults must not replicate into the child
    # (the serving process's kill plan is the serving process's);
    # REPRO_COMPACTOR_* is the dedicated cross-process arming channel
    env.pop("REPRO_FAULTS", None)
    env.pop("REPRO_FAULT_TRACE", None)
    if "REPRO_COMPACTOR_FAULTS" in env:
        env["REPRO_FAULTS"] = env.pop("REPRO_COMPACTOR_FAULTS")
    if "REPRO_COMPACTOR_FAULT_TRACE" in env:
        env["REPRO_FAULT_TRACE"] = env.pop("REPRO_COMPACTOR_FAULT_TRACE")
    # make `repro` importable in the child regardless of install mode
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)
    )))
    env["PYTHONPATH"] = (
        src_root + os.pathsep + env["PYTHONPATH"]
        if env.get("PYTHONPATH") else src_root
    )
    import jax

    if jax.default_backend() == "cpu":
        env.setdefault("JAX_PLATFORMS", "cpu")
        flags = env.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            # a sharded bundle needs as many child devices as shards
            env["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{jax.device_count()}"
            ).strip()
    return env


def compact_in_child(
    index,
    workdir: str,
    *,
    timeout: Optional[float] = None,
    mesh=None,
) -> Tuple[Any, Dict[str, Any]]:
    """Save ``index`` (the engine's shadow), compact it in a child
    process, and return ``(compacted_index, phase_timings)``.

    Raises :class:`CompactionChildError` if the child exits nonzero, dies
    on a signal, or the result bundle fails three-way verification
    (marker summary vs reloaded state vs the pre-save live set), and
    ``subprocess.TimeoutExpired`` is mapped by the caller's watchdog
    policy.  ``workdir`` is reused across cycles (``in``/``out`` are
    cleared first); callers own its lifetime.
    """
    phases: Dict[str, Any] = {}
    in_dir = os.path.join(workdir, "in")
    out_dir = os.path.join(workdir, "out")
    for d in (in_dir, out_dir):
        shutil.rmtree(d, ignore_errors=True)
    os.makedirs(workdir, exist_ok=True)

    expect = _summary(index)
    t0 = time.perf_counter()
    index.save(in_dir)
    phases["save_in_ms"] = 1000.0 * (time.perf_counter() - t0)

    t1 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "repro.serve.compactor", in_dir, out_dir],
        env=_child_env(), timeout=timeout,
        capture_output=True, text=True,
    )
    phases["child_ms"] = 1000.0 * (time.perf_counter() - t1)
    if proc.returncode != 0:
        tail = (proc.stderr or "").strip().splitlines()[-8:]
        raise CompactionChildError(
            f"compactor child exited {proc.returncode}"
            + (f" (signal {-proc.returncode})" if proc.returncode < 0 else "")
            + (": " + " | ".join(tail) if tail else "")
        )

    marker_path = os.path.join(out_dir, _RESULT_MARKER)
    if not os.path.exists(marker_path):
        raise CompactionChildError(
            "compactor child exited 0 but committed no result marker — "
            "refusing the bundle"
        )
    with open(marker_path) as f:
        marker = json.load(f)

    t2 = time.perf_counter()
    layout = _detect_layout(out_dir)
    compacted = _load(out_dir, layout, mesh=mesh)
    phases["load_out_ms"] = 1000.0 * (time.perf_counter() - t2)
    phases["child_phases_s"] = marker.get("phases_s", {})

    got = _summary(compacted)
    if not (got == marker.get("summary") and got == expect):
        raise CompactionChildError(
            "compacted bundle failed verification: "
            f"expected {expect}, marker {marker.get('summary')}, "
            f"loaded {got} — refusing to swap it in"
        )
    return compacted, phases


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    sys.exit(child_main())
