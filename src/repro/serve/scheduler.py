"""Continuous-batching serve scheduler (vLLM-style slot management).

Static-shape JAX decode steps want a FIXED batch; real traffic is ragged.
The engine multiplexes a stream of requests onto ``n_slots`` persistent
decode lanes:

  * a new request prefills into a free lane (its caches are written at the
    lane index);
  * every engine step decodes ALL lanes in one jitted call (lanes sit at
    DIFFERENT sequence positions — the cache layout is lane-major, every
    lane carries its own ring/pos state, and the step vmaps over lanes);
  * finished lanes (EOS or max_tokens) are freed and refilled immediately —
    no batch drain.

The engine is model-agnostic: it drives the same ``prefill``/``decode_step``
the dry-run lowers, for every arch in the zoo, and composes with the
kNN-LM retrieval mix (pass a ``sample`` closure over mixed logits).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.sharding import ShardingRules


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                 # next position to write
    remaining: int = 0


class ContinuousBatchingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        rules: ShardingRules,
        n_slots: int = 4,
        max_seq: int = 128,
        sample: Optional[Callable[[jax.Array], jax.Array]] = None,
    ):
        self.cfg, self.params, self.rules = cfg, params, rules
        self.n_slots, self.max_seq = n_slots, max_seq
        self.sample = sample or (lambda logits: jnp.argmax(logits, axis=-1))
        self.queue: Deque[Request] = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        # lane-major caches: leaf shape (n_slots, *per-lane-leaf); every lane
        # is a full batch=1 cache with its OWN pos/ring state.
        cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
        one = model.make_decode_caches(cfg, 1, max_seq, dtype=cdt)
        self.caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_slots,) + a.shape).copy(), one
        )
        self.next_tok = jnp.zeros((n_slots,), jnp.int32)
        self.finished: Dict[int, Request] = {}
        self._steps = 0

        def step_fn(params, tokens, positions, caches):
            def lane(tok, pos, cache):
                logits, new_c = model.decode_step(
                    cfg, params, tok[None, None], pos, cache, rules)
                return logits[0], new_c

            return jax.vmap(lane, in_axes=(0, 0, 0))(tokens, positions, caches)

        self._decode = jax.jit(step_fn)
        self._prefill = jax.jit(
            lambda params, tokens: model.prefill(cfg, params, tokens, rules))

    # --- public API ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 1000) -> Dict[int, Request]:
        """Drive the engine until the queue and all lanes drain."""
        while (self.queue or any(s.req for s in self.slots)) and \
                self._steps < max_steps:
            self._admit()
            self._step()
        return self.finished

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    # --- internals ---------------------------------------------------------
    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                self._prefill_into(i, self.queue.popleft())

    def _prefill_into(self, i: int, req: Request) -> None:
        tokens = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, caches1 = self._prefill(self.params, tokens)
        caches1 = model.pad_caches(self.cfg, caches1, self.max_seq)
        self.caches = jax.tree.map(
            lambda full, one: full.at[i].set(one), self.caches, caches1)
        tok = int(self.sample(logits)[0])
        self.next_tok = self.next_tok.at[i].set(tok)
        self.slots[i] = _Slot(req=req, pos=len(req.prompt),
                              remaining=req.max_new_tokens)
        req.output.append(tok)

    def _step(self) -> None:
        if self.active == 0:
            return
        positions = jnp.asarray(
            [s.pos if s.req else 0 for s in self.slots], jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.next_tok, positions, self.caches)
        toks = self.sample(logits).astype(jnp.int32)
        self.next_tok = toks
        self._steps += 1
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            t = int(toks[i])
            slot.req.output.append(t)
            slot.pos += 1
            slot.remaining -= 1
            if (slot.remaining <= 0
                    or (slot.req.eos_id is not None and t == slot.req.eos_id)
                    or slot.pos >= self.max_seq):
                slot.req.done = True
                self.finished[slot.req.uid] = slot.req
                self.slots[i] = _Slot()
