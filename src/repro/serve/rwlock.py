"""Reader-writer lock for the serve path: searches share, writes exclude.

PR 6's engine serialized EVERY index operation on one re-entrant mutex —
correct, but it put concurrent searches behind each other and behind any
in-flight write.  The LSM facades' read paths are now mutation-free under
concurrency (idempotent cache fills only; read-triggered rewrites are
suppressed on the engine path — see ``allow_rewrite`` in
``index/mutable.py``), which is exactly the invariant that lets searches
take a SHARED lock: any number of readers proceed together, while
``insert``/``delete``/seal and the maintenance snapshot + epoch swap take
the lock exclusively.

Semantics:

* **Writer preference** — a waiting writer blocks NEW readers, so a
  steady read stream cannot starve a generation-sealing insert forever.
  Re-entrant readers bypass that gate (a thread already inside a read
  section finishing its work cannot deadlock against a pending writer).
* **Re-entrant writes** — the write holder may re-acquire both the write
  and the read side (the maintenance cycle's snapshot phase calls index
  methods that themselves take the read side through engine helpers).
* **No upgrades** — acquiring the write side while holding only the read
  side raises: two upgrading readers would deadlock symmetrically, so
  the hierarchy is enforced instead of discovered.
* **Observable** — :meth:`stats` exposes acquisition counts, cumulative
  wait and write-hold times; an optional ``observer(kind, wait_ms)``
  callback lets the engine stream contention waits into the metrics
  registry (``engine_rwlock_{read,write}_wait_ms``).

The lock hierarchy this slots into (never acquire leftward while holding
rightward): engine state lock < serve READ < serve WRITE < maintenance
mutex.  See ``docs/SERVING.md``.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, Optional

__all__ = ["ReadWriteLock"]


class ReadWriteLock:
    """Shared/exclusive lock with writer preference and write re-entrancy."""

    def __init__(
        self, observer: Optional[Callable[[str, float], None]] = None
    ):
        self._cv = threading.Condition()
        self._readers = 0          # threads currently inside read sections
        self._writer: Optional[int] = None  # ident of the write holder
        self._write_depth = 0      # write re-entrancy (+ reads under write)
        self._pending_writers = 0  # writers queued: gates NEW readers
        self._local = threading.local()
        self._observer = observer
        # counters (under self._cv)
        self._read_acquisitions = 0
        self._write_acquisitions = 0
        self._read_wait_ms = 0.0
        self._write_wait_ms = 0.0
        self._write_held_ms = 0.0
        self._write_t0 = 0.0

    # -- read side -----------------------------------------------------------

    def acquire_read(self) -> None:
        me = threading.get_ident()
        t0 = time.perf_counter()
        with self._cv:
            if self._writer == me:
                # a read section nested under our own write: already
                # exclusive, count it as write depth so release pairs up
                self._write_depth += 1
                return
            depth = getattr(self._local, "rdepth", 0)
            if depth == 0:
                # writer preference: new readers queue behind a pending
                # writer; RE-ENTRANT readers pass (they must finish for
                # the writer to ever get in)
                while self._writer is not None or self._pending_writers:
                    self._cv.wait()
            self._readers += 1
            self._local.rdepth = depth + 1
            self._read_acquisitions += 1
            waited = 1000.0 * (time.perf_counter() - t0)
            self._read_wait_ms += waited
        if self._observer is not None:
            self._observer("read", waited)

    def release_read(self) -> None:
        me = threading.get_ident()
        with self._cv:
            if self._writer == me:
                self._write_depth -= 1
                return
            depth = getattr(self._local, "rdepth", 0)
            if depth <= 0:
                raise RuntimeError("release_read without acquire_read")
            self._local.rdepth = depth - 1
            self._readers -= 1
            if self._readers == 0:
                self._cv.notify_all()

    # -- write side ----------------------------------------------------------

    def acquire_write(self) -> None:
        me = threading.get_ident()
        t0 = time.perf_counter()
        with self._cv:
            if self._writer == me:
                self._write_depth += 1
                return
            if getattr(self._local, "rdepth", 0):
                raise RuntimeError(
                    "read->write upgrade would deadlock: release the read "
                    "section first (lock hierarchy: serve-read < serve-write)"
                )
            self._pending_writers += 1
            try:
                while self._writer is not None or self._readers:
                    self._cv.wait()
            finally:
                self._pending_writers -= 1
            self._writer = me
            self._write_depth = 1
            self._write_acquisitions += 1
            self._write_t0 = time.perf_counter()
            waited = 1000.0 * (self._write_t0 - t0)
            self._write_wait_ms += waited
        if self._observer is not None:
            self._observer("write", waited)

    def release_write(self) -> None:
        me = threading.get_ident()
        with self._cv:
            if self._writer != me:
                raise RuntimeError("release_write by a non-holder")
            self._write_depth -= 1
            if self._write_depth == 0:
                self._writer = None
                self._write_held_ms += 1000.0 * (
                    time.perf_counter() - self._write_t0
                )
                self._cv.notify_all()

    # -- context managers ----------------------------------------------------

    @contextmanager
    def read_locked(self):
        self.acquire_read()
        try:
            yield self
        finally:
            self.release_read()

    @contextmanager
    def write_locked(self):
        self.acquire_write()
        try:
            yield self
        finally:
            self.release_write()

    # -- introspection -------------------------------------------------------

    def write_held(self) -> bool:
        """True iff the CALLING thread holds the write side."""
        with self._cv:
            return self._writer == threading.get_ident()

    @property
    def readers(self) -> int:
        with self._cv:
            return self._readers

    def stats(self) -> Dict[str, float]:
        """Contention accounting (cumulative since construction)."""
        with self._cv:
            held = self._write_held_ms
            if self._writer is not None:
                held += 1000.0 * (time.perf_counter() - self._write_t0)
            return {
                "readers": float(self._readers),
                "pending_writers": float(self._pending_writers),
                "read_acquisitions": float(self._read_acquisitions),
                "write_acquisitions": float(self._write_acquisitions),
                "read_wait_ms": self._read_wait_ms,
                "write_wait_ms": self._write_wait_ms,
                "write_held_ms": held,
            }

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"ReadWriteLock(readers={int(s['readers'])}, "
            f"pending_writers={int(s['pending_writers'])}, "
            f"writer_held={self._writer is not None})"
        )
