"""Hilbert-forest-backed retrieval for serving (kNN-LM-style).

The paper's index is wired to the model zoo here: a datastore of
(hidden-state -> next-token) pairs is indexed with the Task-1 pipeline
(forest + sketches + 4-bit codes), and at decode time the last hidden state
queries it; retrieved next-token distances form p_knn, mixed with the
model's softmax (Khandelwal et al., 2020):

    p(w) = (1-λ)·p_model(w) + λ·p_knn(w),
    p_knn ∝ Σ_{(h_i,w_i) ∈ kNN} 1[w_i=w]·exp(-d(h, h_i)/T)

The store is a :class:`repro.index.MutableHilbertIndex` carrying next-token
values, so a serving deployment can **grow and shrink while serving**:
:meth:`RetrievalStore.append` absorbs new (hidden, token) pairs into the
write buffer (searchable immediately, sealed into segments as it fills) and
:meth:`RetrievalStore.delete` tombstones stale entries — no offline rebuild.
``save()``/``load()`` still lets one build job feed many serving workers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ForestConfig, SearchParams
from repro.index import (
    IndexConfig,
    MutableHilbertIndex,
    load_index_bundle,
    load_mutable_bundle,
)

_STORE_KIND = "retrieval_store"


@dataclasses.dataclass
class RetrievalStore:
    index: MutableHilbertIndex

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array,
              config: Union[IndexConfig, ForestConfig, None] = None,
              *, buffer_capacity: int = 4096, max_segments: int = 8
              ) -> "RetrievalStore":
        """keys: (n, d) hidden states; values: (n,) next tokens.

        ``config`` may be a full :class:`IndexConfig` or (for one release of
        backward compatibility) a bare ``ForestConfig``.  The initial corpus
        is bulk-loaded into one sealed segment so lookup latency matches a
        static index; later :meth:`append` batches stream through the write
        buffer.

        The default config keeps raw fp32 keys on each segment so
        :meth:`compact` can merge segments and drop tombstones; pass
        ``IndexConfig(store_points=False)`` to reclaim that RAM for
        append-only deployments that never compact.
        """
        if config is None:
            config = IndexConfig()
        elif isinstance(config, ForestConfig):
            config = IndexConfig(forest=config)
        index = MutableHilbertIndex(
            config, buffer_capacity=buffer_capacity, max_segments=max_segments
        )
        index.bulk_load(keys, values)
        return cls(index=index)

    @property
    def values(self) -> jax.Array:
        """Dense next-token array keyed by datastore id (kNN-LM gather)."""
        return self.index.values_dense()

    def append(self, keys: jax.Array, values: jax.Array) -> np.ndarray:
        """Stream new (hidden, token) pairs in while serving; returns ids."""
        return self.index.insert(keys, values)

    def delete(self, ids) -> int:
        """Tombstone datastore entries (stale documents, TTL eviction)."""
        return self.index.delete(ids)

    def compact(self) -> "RetrievalStore":
        """Merge segments / drop tombstones (e.g. in a maintenance window)."""
        self.index.compact()
        return self

    def lookup(self, queries: jax.Array, params: SearchParams
               ) -> Tuple[jax.Array, jax.Array]:
        """(Q, d) hidden states -> (ids (Q,k), sq-dists (Q,k)).

        When fewer than k live entries exist, the tail is id -1 / +inf —
        :func:`knn_lm_mix` masks those slots.  Lookups run the fused
        single-dispatch path over each segment's packed-resident codes, and
        batch sizes are bucketed to powers of two, so interactive decode
        loops with varying batch shapes don't accumulate jit traces.
        """
        return self.index.search(queries, params)

    def memory_report(self) -> dict:
        """Serving-RAM accounting (segments + buffer + values + tombstones).

        Segment codes are resident nibble-packed (0.5 B/dim), so this is
        the number to compare against a deployment's RAM budget — the
        paper-model fields and the actual resident bytes now agree.
        """
        return self.index.memory_report()

    def save(self, path: str) -> str:
        """Persist segments + buffer + values as ONE manifest-committed save.

        Every piece is an atomic ``repro.checkpoint`` bundle and the
        top-level manifest is renamed into place last, so a crash mid-save
        or a concurrent :meth:`load` in another worker can never observe the
        index and its values out of sync.
        """
        return self.index.save(path, kind=_STORE_KIND)

    @classmethod
    def load(cls, path: str) -> "RetrievalStore":
        try:
            index, _ = load_mutable_bundle(path, kind=_STORE_KIND)
        except FileNotFoundError:
            # One release of backward compatibility: checkpoints written by
            # the previous static RetrievalStore (a single HilbertIndex
            # bundle + values sidecar, no mutable manifest) are adopted as a
            # single sealed segment.  Saved with store_points=False, so
            # they serve and absorb appends/deletes but cannot compact.
            static_index, extras, _ = load_index_bundle(path, kind=_STORE_KIND)
            index = MutableHilbertIndex.from_index(
                static_index, values=extras["values"]
            )
        return cls(index=index)


def knn_lm_mix(
    logits: jax.Array,        # (B, V) model logits
    hidden: jax.Array,        # (B, d) final hidden states
    store: RetrievalStore,
    params: SearchParams,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jax.Array:
    """Return log of the mixed distribution (B, V)."""
    ids, d2 = store.lookup(hidden, params)            # (B, k)
    w = jax.nn.softmax(-d2 / temperature, axis=-1)    # (B, k)
    w = jnp.where(ids >= 0, w, 0.0)                   # mask -1 padding slots
    tok = store.index.values_at(ids, fill=0)          # (B, k)
    p_knn = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], tok
    ].add(w)
    p_model = jax.nn.softmax(logits, axis=-1)
    mixed = (1.0 - lam) * p_model + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))
