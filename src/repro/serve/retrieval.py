"""Hilbert-forest-backed retrieval for serving (kNN-LM-style).

The paper's index is wired to the model zoo here: a datastore of
(hidden-state -> next-token) pairs is indexed with the Task-1 pipeline
(forest + sketches + 4-bit codes), and at decode time the last hidden state
queries it; retrieved next-token distances form p_knn, mixed with the
model's softmax (Khandelwal et al., 2020):

    p(w) = (1-λ)·p_model(w) + λ·p_knn(w),
    p_knn ∝ Σ_{(h_i,w_i) ∈ kNN} 1[w_i=w]·exp(-d(h, h_i)/T)

The store is just a :class:`repro.index.HilbertIndex` plus a values array —
the index carries its own config, so ``save()``/``load()`` lets one build
job feed many serving workers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.types import ForestConfig, SearchParams
from repro.index import (
    HilbertIndex,
    IndexConfig,
    load_index_bundle,
    save_index_bundle,
)


@dataclasses.dataclass
class RetrievalStore:
    index: HilbertIndex
    values: jax.Array          # (n,) int32 next-token per datastore entry

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array,
              config: Union[IndexConfig, ForestConfig, None] = None
              ) -> "RetrievalStore":
        """keys: (n, d) hidden states; values: (n,) next tokens.

        ``config`` may be a full :class:`IndexConfig` or (for one release of
        backward compatibility) a bare ``ForestConfig``.  Serving only runs
        Algorithm-1 search, so raw points are not retained.
        """
        if config is None:
            config = IndexConfig(store_points=False)
        elif isinstance(config, ForestConfig):
            config = IndexConfig(forest=config, store_points=False)
        idx = HilbertIndex.build(keys, config)
        return cls(index=idx, values=values)

    def lookup(self, queries: jax.Array, params: SearchParams
               ) -> Tuple[jax.Array, jax.Array]:
        """(Q, d) hidden states -> (ids (Q,k), sq-dists (Q,k))."""
        return self.index.search(queries, params)

    def save(self, path: str) -> str:
        """Persist index + values as ONE atomic checkpoint bundle.

        A crash mid-save or a concurrent :meth:`load` in another worker can
        never observe the index and its values array out of sync.
        """
        return save_index_bundle(
            self.index, path, kind="retrieval_store",
            extra_arrays={"values": self.values},
        )

    @classmethod
    def load(cls, path: str) -> "RetrievalStore":
        index, extras, _ = load_index_bundle(path, kind="retrieval_store")
        return cls(index=index, values=extras["values"])


def knn_lm_mix(
    logits: jax.Array,        # (B, V) model logits
    hidden: jax.Array,        # (B, d) final hidden states
    store: RetrievalStore,
    params: SearchParams,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jax.Array:
    """Return log of the mixed distribution (B, V)."""
    ids, d2 = store.lookup(hidden, params)            # (B, k)
    w = jax.nn.softmax(-d2 / temperature, axis=-1)    # (B, k)
    tok = store.values[ids]                           # (B, k)
    v = logits.shape[-1]
    p_knn = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], tok
    ].add(w)
    p_model = jax.nn.softmax(logits, axis=-1)
    mixed = (1.0 - lam) * p_model + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))
