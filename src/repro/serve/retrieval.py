"""Hilbert-forest-backed retrieval for serving (kNN-LM-style).

The paper's index is wired to the model zoo here: a datastore of
(hidden-state -> next-token) pairs is indexed with the Task-1 pipeline
(forest + sketches + 4-bit codes), and at decode time the last hidden state
queries it; retrieved next-token distances form p_knn, mixed with the
model's softmax (Khandelwal et al., 2020):

    p(w) = (1-λ)·p_model(w) + λ·p_knn(w),
    p_knn ∝ Σ_{(h_i,w_i) ∈ kNN} 1[w_i=w]·exp(-d(h, h_i)/T)

Two backing layouts, one ``lookup`` contract:

* **Mutable (default, single device)** — a
  :class:`repro.index.MutableHilbertIndex` carrying next-token values, so a
  deployment can **grow and shrink while serving**: :meth:`append` absorbs
  new pairs into the write buffer and :meth:`delete` tombstones stale
  entries — no offline rebuild.
* **Sharded (``shards > 1``)** — a
  :class:`repro.index.ShardedHilbertIndex` row-partitioned over the mesh's
  ``data`` axis: datastores larger than one device's RAM serve with kNN-LM
  lookups going through the mesh-wide merged top-k (one jitted dispatch per
  query chunk).  This layout is static — appends/deletes require a rebuild
  (rebuild-and-swap is the intended maintenance path at that scale).

``save()``/``load()`` round-trips both layouts; one build job feeds many
serving workers, and a sharded checkpoint RESHARDS on load if the worker
mesh differs from the build mesh.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core.types import ForestConfig, SearchParams
from repro.index import (
    IndexConfig,
    MutableHilbertIndex,
    ShardedHilbertIndex,
    load_index_bundle,
    load_mutable_bundle,
)

_STORE_KIND = "retrieval_store"
_SHARDED_STORE_KIND = "retrieval_store_sharded"
_VALUES_DIR = "store_values"
_MUTABLE_MANIFEST = "mutable_manifest.json"
_SHARDED_MANIFEST = "sharded_manifest.json"


def _remove_if_exists(path: str) -> None:
    try:
        os.remove(path)
    except FileNotFoundError:
        pass


@dataclasses.dataclass
class RetrievalStore:
    index: Optional[MutableHilbertIndex] = None
    sharded: Optional[ShardedHilbertIndex] = None
    sharded_values: Optional[np.ndarray] = None  # dense by datastore id

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array,
              config: Union[IndexConfig, ForestConfig, None] = None,
              *, buffer_capacity: int = 4096, max_segments: int = 8,
              shards: Optional[int] = None, mesh=None,
              ) -> "RetrievalStore":
        """keys: (n, d) hidden states; values: (n,) next tokens.

        ``config`` may be a full :class:`IndexConfig` or (for one release of
        backward compatibility) a bare ``ForestConfig``.

        ``shards`` (or ``config.shards``, or a ``mesh``) > 1 builds the
        row-partitioned sharded datastore; the default resolves to the
        single-device mutable store.  The mutable path bulk-loads the
        corpus into one sealed segment so lookup latency matches a static
        index; later :meth:`append` batches stream through the write
        buffer.  The default config keeps raw fp32 keys so the mutable
        store can :meth:`compact` (and the sharded store can reshard on
        load); pass ``IndexConfig(store_points=False)`` to reclaim that
        RAM for deployments that never do either.
        """
        if config is None:
            config = IndexConfig()
        elif isinstance(config, ForestConfig):
            config = IndexConfig(forest=config)
        if shards is None:
            shards = (
                int(mesh.shape["data"]) if mesh is not None
                else (config.shards or 1)
            )
        if shards > 1:
            config = dataclasses.replace(config, shards=shards)
            sharded = ShardedHilbertIndex.build(keys, config, mesh=mesh)
            vals = np.asarray(jax.device_get(values))
            if vals.shape[:1] != (sharded.n_points,):
                raise ValueError(
                    f"values must be ({sharded.n_points}, ...), "
                    f"got {vals.shape}"
                )
            return cls(sharded=sharded, sharded_values=vals.copy())
        index = MutableHilbertIndex(
            config, buffer_capacity=buffer_capacity, max_segments=max_segments
        )
        index.bulk_load(keys, values)
        return cls(index=index)

    @property
    def is_sharded(self) -> bool:
        return self.sharded is not None

    @property
    def values(self) -> jax.Array:
        """Dense next-token array keyed by datastore id (kNN-LM gather)."""
        if self.is_sharded:
            return jnp.asarray(self.sharded_values)
        return self.index.values_dense()

    def values_at(self, ids, fill=0) -> jax.Array:
        """Gather per-entry values for search-result ids; -1 slots get fill."""
        if not self.is_sharded:
            return self.index.values_at(ids, fill=fill)
        from repro.index.mutable import dense_values_at

        return dense_values_at(self.sharded_values, ids, fill=fill)

    def _require_mutable(self, op: str) -> MutableHilbertIndex:
        if self.is_sharded:
            raise ValueError(
                f"{op}() is not available on a sharded RetrievalStore: the "
                "row-partitioned layout is static — rebuild-and-swap "
                "(RetrievalStore.build + save/load) to change the corpus"
            )
        return self.index

    def append(self, keys: jax.Array, values: jax.Array) -> np.ndarray:
        """Stream new (hidden, token) pairs in while serving; returns ids."""
        return self._require_mutable("append").insert(keys, values)

    def delete(self, ids) -> int:
        """Tombstone datastore entries (stale documents, TTL eviction)."""
        return self._require_mutable("delete").delete(ids)

    def compact(self) -> "RetrievalStore":
        """Merge segments / drop tombstones (e.g. in a maintenance window)."""
        self._require_mutable("compact").compact()
        return self

    def lookup(self, queries: jax.Array, params: SearchParams
               ) -> Tuple[jax.Array, jax.Array]:
        """(Q, d) hidden states -> (ids (Q,k), sq-dists (Q,k)).

        When fewer than k live entries exist, the tail is id -1 / +inf —
        :func:`knn_lm_mix` masks those slots.  Both layouts run the fused
        single-dispatch path over packed-resident codes (per segment on the
        mutable store; per shard + cross-shard merge on the sharded one),
        and batch sizes are bucketed to powers of two, so interactive
        decode loops with varying batch shapes don't accumulate jit traces.
        """
        if self.is_sharded:
            return self.sharded.search(queries, params)
        return self.index.search(queries, params)

    def memory_report(self) -> dict:
        """Serving-RAM accounting for whichever layout backs the store.

        Mutable: segments + buffer + values + tombstones.  Sharded: the
        partitioned accounting — ``per_device_bytes`` is what each device
        in the mesh actually holds (≈ total / n_shards + the replicated
        quantizer), the number to compare against a PER-DEVICE RAM budget
        instead of the paper's single 16 GB box.
        """
        if self.is_sharded:
            rep = dict(self.sharded.memory_report())
            rep["values_bytes"] = int(self.sharded_values.nbytes)
            rep["total_bytes"] = rep["resident_bytes"] + rep["values_bytes"]
            return rep
        return self.index.memory_report()

    def save(self, path: str) -> str:
        """Persist the store as ONE manifest-committed save.

        Every piece is an atomic ``repro.checkpoint`` bundle and the
        top-level manifest is renamed into place last, so a crash mid-save
        or a concurrent :meth:`load` in another worker can never observe the
        index and its values out of sync.  The sharded path writes the
        values to a FRESH step before its manifest commits (the step a
        previous manifest references is never rewritten; unreferenced
        steps are pruned after the commit, one generation of grace), and a
        save that SWITCHES layout removes the other layout's manifest
        after committing its own — rebuild-and-swap over an old mutable
        save can never leave a loader preferring the stale store.
        """
        if not self.is_sharded:
            out = self.index.save(path, kind=_STORE_KIND)
            _remove_if_exists(os.path.join(path, _SHARDED_MANIFEST))
            return out
        os.makedirs(path, exist_ok=True)
        prev_step = None
        try:
            with open(os.path.join(path, _SHARDED_MANIFEST)) as f:
                prev_step = json.load(f).get("extra_meta", {}).get(
                    "values_step"
                )
        except (OSError, ValueError):
            pass
        vdir = os.path.join(path, _VALUES_DIR)
        vstep = (checkpoint.latest_step(vdir) or 0) + 1
        checkpoint.save(
            vdir, step=vstep, tree={"values": self.sharded_values},
            extra={"kind": _SHARDED_STORE_KIND},
        )
        out = self.sharded.save(
            path, kind=_SHARDED_STORE_KIND,
            extra_meta={"values_step": vstep},
        )
        _remove_if_exists(os.path.join(path, _MUTABLE_MANIFEST))
        keep = {vstep, prev_step}
        for name in os.listdir(vdir):
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and int(name.split("_")[1]) not in keep):
                shutil.rmtree(os.path.join(vdir, name), ignore_errors=True)
        return out

    @classmethod
    def load(cls, path: str, *, mesh=None) -> "RetrievalStore":
        mpath = os.path.join(path, _MUTABLE_MANIFEST)
        spath = os.path.join(path, _SHARDED_MANIFEST)
        has_mut, has_sh = os.path.exists(mpath), os.path.exists(spath)
        if has_mut and has_sh:
            # Only reachable if a layout-switching save crashed between its
            # manifest commit and the stale-manifest cleanup; the newer
            # manifest is the one that committed.
            has_mut = os.path.getmtime(mpath) >= os.path.getmtime(spath)
            has_sh = not has_mut
        if has_mut:
            index, _ = load_mutable_bundle(path, kind=_STORE_KIND)
            return cls(index=index)
        if has_sh:
            from repro.index.mutable import _restore_state_bundle

            with open(spath) as f:
                manifest = json.load(f)
            sharded = ShardedHilbertIndex.load(
                path, mesh=mesh, kind=_SHARDED_STORE_KIND
            )
            # values restore at the manifest-referenced step, with the
            # bundle's own declared dtype (tokens are int32 today)
            state = _restore_state_bundle(
                os.path.join(path, _VALUES_DIR),
                manifest.get("extra_meta", {}).get("values_step"),
            )
            return cls(sharded=sharded, sharded_values=state["values"])
        # One release of backward compatibility: checkpoints written by
        # the PR-1 static RetrievalStore (a single HilbertIndex bundle +
        # values sidecar, no mutable manifest) are adopted as a single
        # sealed segment.  Saved with store_points=False, so they serve
        # and absorb appends/deletes but cannot compact.
        static_index, extras, _ = load_index_bundle(path, kind=_STORE_KIND)
        index = MutableHilbertIndex.from_index(
            static_index, values=extras["values"]
        )
        return cls(index=index)


def knn_lm_mix(
    logits: jax.Array,        # (B, V) model logits
    hidden: jax.Array,        # (B, d) final hidden states
    store: RetrievalStore,
    params: SearchParams,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jax.Array:
    """Return log of the mixed distribution (B, V).

    Layout-agnostic: ``store.lookup`` is the merged top-k whichever layout
    backs the store, so the mix is identical code for a laptop datastore
    and a mesh-wide sharded one.
    """
    ids, d2 = store.lookup(hidden, params)            # (B, k)
    w = jax.nn.softmax(-d2 / temperature, axis=-1)    # (B, k)
    w = jnp.where(ids >= 0, w, 0.0)                   # mask -1 padding slots
    tok = store.values_at(ids, fill=0)                # (B, k)
    p_knn = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], tok
    ].add(w)
    p_model = jax.nn.softmax(logits, axis=-1)
    mixed = (1.0 - lam) * p_model + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))
