"""Hilbert-forest-backed retrieval for serving (kNN-LM-style).

The paper's index is wired to the model zoo here: a datastore of
(hidden-state -> next-token) pairs is indexed with the Task-1 pipeline
(forest + sketches + 4-bit codes), and at decode time the last hidden state
queries it; retrieved next-token distances form p_knn, mixed with the
model's softmax (Khandelwal et al., 2020):

    p(w) = (1-λ)·p_model(w) + λ·p_knn(w),
    p_knn ∝ Σ_{(h_i,w_i) ∈ kNN} 1[w_i=w]·exp(-d(h, h_i)/T)
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import search
from repro.core.types import ForestConfig, SearchParams


@dataclasses.dataclass
class RetrievalStore:
    index: search.HilbertForestIndex
    forest_cfg: ForestConfig
    values: jax.Array          # (n,) int32 next-token per datastore entry

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array,
              forest_cfg: ForestConfig) -> "RetrievalStore":
        """keys: (n, d) hidden states; values: (n,) next tokens."""
        idx = search.build_index(keys, forest_cfg)
        return cls(index=idx, forest_cfg=forest_cfg, values=values)

    def lookup(self, queries: jax.Array, params: SearchParams
               ) -> Tuple[jax.Array, jax.Array]:
        """(Q, d) hidden states -> (ids (Q,k), sq-dists (Q,k))."""
        return search.search(self.index, queries, params, self.forest_cfg)


def knn_lm_mix(
    logits: jax.Array,        # (B, V) model logits
    hidden: jax.Array,        # (B, d) final hidden states
    store: RetrievalStore,
    params: SearchParams,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jax.Array:
    """Return log of the mixed distribution (B, V)."""
    ids, d2 = store.lookup(hidden, params)            # (B, k)
    w = jax.nn.softmax(-d2 / temperature, axis=-1)    # (B, k)
    tok = store.values[ids]                           # (B, k)
    v = logits.shape[-1]
    p_knn = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], tok
    ].add(w)
    p_model = jax.nn.softmax(logits, axis=-1)
    mixed = (1.0 - lam) * p_model + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))
