"""Hilbert-forest-backed retrieval for serving (kNN-LM-style).

The paper's index is wired to the model zoo here: a datastore of
(hidden-state -> next-token) pairs is indexed with the Task-1 pipeline
(forest + sketches + 4-bit codes), and at decode time the last hidden state
queries it; retrieved next-token distances form p_knn, mixed with the
model's softmax (Khandelwal et al., 2020):

    p(w) = (1-λ)·p_model(w) + λ·p_knn(w),
    p_knn ∝ Σ_{(h_i,w_i) ∈ kNN} 1[w_i=w]·exp(-d(h, h_i)/T)

Two backing layouts, one streaming contract:

* **Mutable (default, single device)** — a
  :class:`repro.index.MutableHilbertIndex` carrying next-token values.
* **Sharded-mutable (``shards > 1``)** — a
  :class:`repro.index.ShardedMutableHilbertIndex` row-partitioned over the
  mesh's ``data`` axis: datastores larger than one device's RAM serve with
  kNN-LM lookups going through the mesh-wide merged top-k (one jitted
  dispatch per query chunk), and — since the sharded layout grew LSM writes
  — :meth:`RetrievalStore.append`/:meth:`RetrievalStore.delete` work on
  BOTH layouts: a deployment grows and shrinks while serving with no
  offline rebuild at any scale.  :meth:`RetrievalStore.compact` re-balances
  the sharded partition in a maintenance window.

``save()``/``load()`` round-trips both layouts; one build job feeds many
serving workers, a sharded checkpoint RESHARDS on load if the worker mesh
differs from the build mesh, and pre-PR-5 static sharded store checkpoints
(format_version 3) are adopted into the mutable layout transparently.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ForestConfig, SearchParams
from repro.index import (
    IndexConfig,
    MutableHilbertIndex,
    ShardedHilbertIndex,
    ShardedMutableHilbertIndex,
    load_index_bundle,
    load_mutable_bundle,
    load_sharded_mutable_bundle,
)

_STORE_KIND = "retrieval_store"
_SHARDED_STORE_KIND = "retrieval_store_sharded"
_VALUES_DIR = "store_values"
_MUTABLE_MANIFEST = "mutable_manifest.json"
_SHARDED_MANIFEST = "sharded_manifest.json"
_SHARDED_MUTABLE_MANIFEST = "sharded_mutable_manifest.json"


def _remove_if_exists(path: str) -> None:
    try:
        os.remove(path)
    except FileNotFoundError:
        pass


def _remove_stale_layouts(path: str, keep: str) -> None:
    """Drop the OTHER layouts' manifests AND orphaned payloads post-commit.

    Called after a save's own manifest has committed.  Beyond the stale
    manifests, two payload classes would otherwise leak forever because no
    current writer's pruning pass covers them: the v3 static store's
    ``shards/`` + ``store_values/`` trees (their writer no longer exists),
    and the other mutable layout's segment bundles (``seg_*`` vs ``gen_*``
    prefixes — each saver prunes only its own).  The shared ``state/`` dir
    needs nothing here: every saver prunes it against its own keep-set on
    the next save.
    """
    if keep != "mutable":
        _remove_if_exists(os.path.join(path, _MUTABLE_MANIFEST))
    if keep != "sharded_mutable":
        _remove_if_exists(os.path.join(path, _SHARDED_MUTABLE_MANIFEST))
    _remove_if_exists(os.path.join(path, _SHARDED_MANIFEST))
    shutil.rmtree(os.path.join(path, "shards"), ignore_errors=True)
    shutil.rmtree(os.path.join(path, _VALUES_DIR), ignore_errors=True)
    stale_prefix = "gen_" if keep == "mutable" else "seg_"
    seg_root = os.path.join(path, "segments")
    if os.path.isdir(seg_root):
        for name in os.listdir(seg_root):
            if name.startswith(stale_prefix):
                shutil.rmtree(os.path.join(seg_root, name),
                              ignore_errors=True)


@dataclasses.dataclass
class RetrievalStore:
    """A streaming kNN-LM datastore over either mutable index layout.

    Exactly one of ``index`` (single-device LSM) / ``sharded``
    (row-partitioned LSM) is set; every public method is layout-agnostic.
    """

    index: Optional[MutableHilbertIndex] = None
    sharded: Optional[ShardedMutableHilbertIndex] = None
    engine: Optional["object"] = None  # RetrievalEngine when attached

    @classmethod
    def build(cls, keys: jax.Array, values: jax.Array,
              config: Union[IndexConfig, ForestConfig, None] = None,
              *, buffer_capacity: int = 4096, max_segments: int = 8,
              shards: Optional[int] = None, mesh=None,
              ) -> "RetrievalStore":
        """Build a datastore over (hidden-state, next-token) pairs.

        Args:
          keys: (n, d) fp32 hidden states.
          values: (n,) next tokens (any dense per-entry payload works).
          config: a full :class:`IndexConfig` or (for one release of
            backward compatibility) a bare ``ForestConfig``.
          buffer_capacity: write-buffer rows (per shard when sharded).
          max_segments: sealed-segment cap before tier merging.
          shards: row-partition count; ``shards`` / ``config.shards`` /
            a ``mesh`` > 1 device selects the sharded-mutable layout,
            default is the single-device mutable store.
          mesh: explicit ``('data',)`` mesh for the sharded layout.

        Returns:
          A store whose corpus is one sealed segment (lookup latency
          matches a static index); later :meth:`append` batches stream
          through the write buffer(s).  The default config keeps raw fp32
          keys so both layouts can :meth:`compact` (and the sharded one
          can reshard on load).
        """
        if config is None:
            config = IndexConfig()
        elif isinstance(config, ForestConfig):
            config = IndexConfig(forest=config)
        if shards is None:
            shards = (
                int(mesh.shape["data"]) if mesh is not None
                else (config.shards or 1)
            )
        if shards > 1:
            config = dataclasses.replace(config, shards=shards)
            sharded = ShardedMutableHilbertIndex.build(
                keys, config, mesh=mesh, values=values,
                buffer_capacity=buffer_capacity, max_segments=max_segments,
            )
            return cls(sharded=sharded)
        index = MutableHilbertIndex(
            config, buffer_capacity=buffer_capacity, max_segments=max_segments
        )
        index.bulk_load(keys, values)
        return cls(index=index)

    @property
    def is_sharded(self) -> bool:
        return self.sharded is not None

    @property
    def _impl(self):
        """The backing mutable index, whichever layout it is.

        With a serving engine attached this is the engine's CURRENT index
        — after a background maintenance swap the engine serves the
        compacted copy, not the build-time object the dataclass fields
        still reference.
        """
        if self.engine is not None:
            return self.engine.index
        return self.sharded if self.is_sharded else self.index

    def serving_engine(self, params: Optional[SearchParams] = None,
                       **kwargs) -> "object":
        """Attach a :class:`~repro.serve.engine.RetrievalEngine` over the
        backing index and route ALL store traffic through it.

        After this call :meth:`lookup` goes through the engine's admission
        queue and micro-batcher, :meth:`append`/:meth:`delete` are routed
        writes (logged and replayed across background compactions), and
        :meth:`compact` becomes a forced off-path maintenance cycle with an
        atomic index swap instead of a serving stall.  ``kwargs`` pass
        through to the engine constructor (``start=True`` spawns the serve
        and maintenance threads immediately).

        Calling this again replaces the engine: the previous one is
        drained and stopped first (its serve/maintainer threads would
        otherwise keep running — and keep swapping an index the store no
        longer references), and the new engine wraps the index version
        the old engine was serving at shutdown.
        """
        from repro.serve.engine import RetrievalEngine

        if self.engine is not None:
            self.engine.stop()
        impl = self._impl  # old engine's current (possibly swapped) index
        self.engine = RetrievalEngine(impl, params, **kwargs)
        return self.engine

    @property
    def values(self) -> jax.Array:
        """Dense next-token array keyed by datastore id (kNN-LM gather)."""
        return self._impl.values_dense()

    def values_at(self, ids, fill=0) -> jax.Array:
        """Gather per-entry values for search-result ids; -1 slots get fill."""
        if self.engine is not None:
            return self.engine.values_at(ids, fill=fill)
        return self._impl.values_at(ids, fill=fill)

    def append(self, keys: jax.Array, values: jax.Array) -> np.ndarray:
        """Stream new (hidden, token) pairs in while serving; returns ids.

        Works on BOTH layouts: single-device batches land in the write
        buffer; sharded batches are routed to the shard owning each key's
        curve range and land in that shard's buffer.
        """
        if self.engine is not None:
            return self.engine.insert(keys, values)
        return self._impl.insert(keys, values)

    def delete(self, ids) -> int:
        """Tombstone datastore entries (stale documents, TTL eviction)."""
        if self.engine is not None:
            return self.engine.delete(ids)
        return self._impl.delete(ids)

    def enable_wal(self, path: str, config=None) -> "RetrievalStore":
        """Make acknowledged writes durable: attach a write-ahead log.

        ``path`` is the checkpoint directory this store saves to.  Every
        :meth:`append`/:meth:`delete` is framed + logged BEFORE it is
        applied (fsync batched per the
        :class:`~repro.checkpoint.wal.WalConfig` group-commit policy);
        :meth:`save` truncates the log at its commit point, and
        :meth:`load` replays any tail automatically — a crash at any
        instant recovers bit-equal to never having crashed.  With a
        serving engine attached, a WAL write failure flips the engine
        into degraded read-only mode instead of losing writes silently.
        """
        self._impl.enable_wal(path, config)
        return self

    def compact(self) -> "RetrievalStore":
        """Merge segments / drop tombstones (e.g. in a maintenance window).

        On the sharded layout this also re-runs the global Hilbert
        partition, re-balancing entries across shards.  With a serving
        engine attached this is a forced background-maintenance cycle —
        the compaction runs on a shadow copy and the serving index is
        atomically swapped, so concurrent lookups never stall behind it.
        """
        if self.engine is not None:
            self.engine.maintain_once(force=True)
            return self
        self._impl.compact()
        return self

    def lookup(self, queries: jax.Array, params: SearchParams
               ) -> Tuple[jax.Array, jax.Array]:
        """(Q, d) hidden states -> (ids (Q, k), sq-dists (Q, k)).

        When fewer than k live entries exist, the tail is id -1 / +inf —
        :func:`knn_lm_mix` masks those slots.  Both layouts run the fused
        single-dispatch path over packed-resident codes (per segment on the
        mutable store; per shard per generation + cross-shard merge on the
        sharded one), and batch sizes are bucketed to powers of two, so
        interactive decode loops with varying batch shapes don't accumulate
        jit traces.
        """
        if self.engine is not None:
            return self.engine.search(queries, params)
        return self._impl.search(queries, params)

    def memory_report(self) -> dict:
        """Serving-RAM accounting for whichever layout backs the store.

        Both layouts report segments + buffer + values + tombstones; the
        sharded one additionally splits sharded vs replicated bytes, with
        ``per_device_bytes`` the number to compare against a PER-DEVICE RAM
        budget instead of the paper's single 16 GB box.
        """
        return self._impl.memory_report()

    def save(self, path: str) -> str:
        """Persist the store as ONE manifest-committed save.

        Every piece is an atomic ``repro.checkpoint`` bundle and the
        top-level manifest is renamed into place last, so a crash mid-save
        or a concurrent :meth:`load` in another worker can never observe a
        half-written store.  Values ride inside the index's own state
        sidecar on both layouts.  A save that SWITCHES layout (or upgrades
        a v3 static checkpoint in place) removes the other layouts'
        manifests AND their now-unreachable payload bundles after
        committing its own — rebuild-and-swap over an old save can never
        leave a loader preferring stale data, nor orphaned bundles eating
        disk.
        """
        impl = self._impl  # engine-current index when an engine is attached
        if not self.is_sharded:
            out = impl.save(path, kind=_STORE_KIND)
            _remove_stale_layouts(path, keep="mutable")
            return out
        out = impl.save(path, kind=_SHARDED_STORE_KIND)
        _remove_stale_layouts(path, keep="sharded_mutable")
        return out

    @classmethod
    def load(cls, path: str, *, mesh=None) -> "RetrievalStore":
        """Load any store checkpoint generation onto the current mesh.

        Resolution order (newest manifest wins if a crashed layout-switch
        left two): v4 sharded-mutable store, v1 mutable store, v3 static
        sharded store (adopted into the mutable layout, values sidecar and
        all), then the PR-1 static single-index bundle (adopted as one
        sealed segment).  Sharded checkpoints reshard when ``mesh`` differs
        from the build mesh; resharding onto ONE device yields the
        single-device mutable layout.
        """
        # Newest manifest wins (ns resolution).  Two manifests coexist only
        # when a layout-switching save crashed between its commit and the
        # stale-manifest cleanup.  On an exact mtime tie (coarse-granularity
        # filesystems) prefer the manifest whose referenced state bundle
        # still EXISTS — the crashed switch's committed side pruned the
        # stale side's state step, so validity identifies the committed
        # manifest — then by format generation (a v3 static manifest is
        # never written by current code, so a tied one is always stale).
        def state_ok(manifest_path: str) -> bool:
            try:
                with open(manifest_path) as f:
                    step = json.load(f).get("state_step")
            except (OSError, ValueError):
                return False
            if step is None:
                return True
            return os.path.isdir(
                os.path.join(path, "state", f"step_{int(step):08d}")
            )

        candidates = []
        for priority, (manifest, kind) in enumerate((
            (_SHARDED_MANIFEST, "sharded_static"),
            (_MUTABLE_MANIFEST, "mutable"),
            (_SHARDED_MUTABLE_MANIFEST, "sharded_mutable"),
        )):
            p = os.path.join(path, manifest)
            if os.path.exists(p):
                ok = state_ok(p) if priority else True
                candidates.append((os.stat(p).st_mtime_ns, ok, priority,
                                   kind))
        layout = max(candidates)[3] if candidates else "legacy"
        if layout == "sharded_mutable":
            target = (
                int(mesh.shape["data"]) if mesh is not None
                else jax.device_count()
            )
            if target == 1:
                from repro.index import load_sharded_mutable_as_mutable

                return cls(index=load_sharded_mutable_as_mutable(
                    path, kind=_SHARDED_STORE_KIND
                ))
            sharded, _ = load_sharded_mutable_bundle(
                path, mesh=mesh, kind=_SHARDED_STORE_KIND
            )
            return cls(sharded=sharded)
        if layout == "mutable":
            index, _ = load_mutable_bundle(path, kind=_STORE_KIND)
            return cls(index=index)
        if layout == "sharded_static":
            # Pre-PR-5 static sharded store: index checkpoint + values
            # sidecar at the manifest-referenced step.  Adopt into the
            # mutable layout (single- or multi-shard, mesh decides).
            from repro.index.mutable import _restore_state_bundle

            with open(os.path.join(path, _SHARDED_MANIFEST)) as f:
                manifest = json.load(f)
            base = ShardedHilbertIndex.load(
                path, mesh=mesh, kind=_SHARDED_STORE_KIND
            )
            state = _restore_state_bundle(
                os.path.join(path, _VALUES_DIR),
                manifest.get("extra_meta", {}).get("values_step"),
            )
            values = state["values"]
            if base.single is not None:
                return cls(index=MutableHilbertIndex.from_index(
                    base.single, values=values
                ))
            return cls(sharded=ShardedMutableHilbertIndex.from_sharded(
                base, values=values
            ))
        # One release of backward compatibility: checkpoints written by
        # the PR-1 static RetrievalStore (a single HilbertIndex bundle +
        # values sidecar, no mutable manifest) are adopted as a single
        # sealed segment.  Saved with store_points=False, so they serve
        # and absorb appends/deletes but cannot compact.
        static_index, extras, _ = load_index_bundle(path, kind=_STORE_KIND)
        index = MutableHilbertIndex.from_index(
            static_index, values=extras["values"]
        )
        return cls(index=index)


def knn_lm_mix(
    logits: jax.Array,        # (B, V) model logits
    hidden: jax.Array,        # (B, d) final hidden states
    store: RetrievalStore,
    params: SearchParams,
    lam: float = 0.25,
    temperature: float = 1.0,
) -> jax.Array:
    """Return log of the mixed distribution (B, V).

    Layout-agnostic: ``store.lookup`` is the merged top-k whichever layout
    backs the store, so the mix is identical code for a laptop datastore
    and a mesh-wide sharded one.
    """
    ids, d2 = store.lookup(hidden, params)            # (B, k)
    w = jax.nn.softmax(-d2 / temperature, axis=-1)    # (B, k)
    w = jnp.where(ids >= 0, w, 0.0)                   # mask -1 padding slots
    tok = store.values_at(ids, fill=0)                # (B, k)
    p_knn = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], tok
    ].add(w)
    p_model = jax.nn.softmax(logits, axis=-1)
    mixed = (1.0 - lam) * p_model + lam * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))
