"""Pipelined multi-chunk retrieval: overlap host staging with device search.

Every index facade chunks a large query batch into ``query_chunk``-row
dispatches.  Called naively, each chunk pays its host→device transfer on
the critical path: stage chunk *i*, search chunk *i*, stage chunk *i+1*,
search chunk *i+1*, …  This module double-buffers the staging instead —
chunk *i+1* is ``jax.device_put`` while chunk *i*'s dispatch is still
executing (JAX dispatch is asynchronous: ``search`` returns futures, so the
Python thread is free to stage ahead), and nothing blocks until the caller
touches the results:

    stage(0); search(0); stage(1); search(1); stage(2); ...
              └─ device ─┘└ host ┘ (overlapped)

Results are BIT-IDENTICAL to a direct ``index.search`` over the same batch:
the same per-chunk search runs on the same rows in the same order — the
only change is *when* the host hands each chunk to the device.  Works for
every layout (plain / mutable / sharded / sharded-mutable): each per-chunk
call sets ``query_chunk`` to the staged chunk's row count, so the facade's
own pow2 bucketing and single-dispatch invariants hold unchanged.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import SearchParams

__all__ = ["pipelined_search"]


def pipelined_search(
    index,
    queries,
    params: SearchParams,
    *,
    backend: str = "auto",
    query_chunk: Optional[int] = None,
    device: Optional[jax.Device] = None,
    **search_kwargs,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked search with host staging overlapped against device execution.

    Args:
      index: any facade with ``search(queries, params, backend=,
        query_chunk=)`` — :class:`~repro.index.HilbertIndex` and the
        mutable/sharded/sharded-mutable wrappers all qualify.
      queries: (Q, d) fp32 batch (host or device resident).
      params: Algorithm-1 hyper-parameters, passed through per chunk.
      backend: kernel routing, passed through per chunk.
      query_chunk: rows per staged chunk (default: the index config's
        ``query_chunk``), i.e. the double-buffer granularity.
      device: staging target for plain/mutable layouts (default device when
        ``None``).  Sharded layouts place queries themselves inside their
        search dispatch (replicated), so staging is a host-pinning step.
      **search_kwargs: forwarded verbatim to every per-chunk
        ``index.search`` call (e.g. the serving engine's
        ``allow_rewrite=False`` on mutable layouts — its shared-read-lock
        path must not mutate segments mid-pipeline).

    Returns:
      ``(ids (Q, k), sq_distances (Q, k))`` — bit-identical to
      ``index.search(queries, params)``.
    """
    if query_chunk is None:
        query_chunk = getattr(index, "config").query_chunk
    qn = int(np.asarray(jnp.shape(queries))[0]) if hasattr(
        queries, "shape"
    ) else len(queries)
    if qn == 0 or qn <= query_chunk:
        # One chunk: nothing to overlap, take the direct path.
        return index.search(
            queries, params, backend=backend, query_chunk=query_chunk,
            **search_kwargs,
        )
    q_host = np.asarray(jax.device_get(queries), np.float32)

    def stage(s: int):
        chunk = jnp.asarray(q_host[s : s + query_chunk])
        return jax.device_put(chunk, device) if device is not None else (
            jax.device_put(chunk)
        )

    outs_i, outs_d = [], []
    staged = stage(0)
    for s in range(0, qn, query_chunk):
        nxt = s + query_chunk
        # Dispatch the current chunk's search (async: returns futures) ...
        ids, dists = index.search(
            staged, params, backend=backend, query_chunk=query_chunk,
            **search_kwargs,
        )
        # ... then stage the NEXT chunk while the device works on this one.
        if nxt < qn:
            staged = stage(nxt)
        outs_i.append(ids)
        outs_d.append(dists)
    return jnp.concatenate(outs_i), jnp.concatenate(outs_d)
