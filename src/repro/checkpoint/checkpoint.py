"""Fault-tolerant checkpointing: atomic, self-verifying, async, elastic.

Design (1000+-node posture):
  * **Atomic**: write into ``step_<n>.tmp/``, fsync the payload and the
    manifest, rename to ``step_<n>/``, then fsync the parent directory so
    the rename itself is durable.  A crash mid-write can never corrupt
    the latest restorable step; ``latest_step`` only sees fully renamed
    directories.
  * **Self-verifying** (format_version 5): the manifest records a
    SHA-256 digest and byte size for every array.  ``restore`` verifies
    what it reads; a mismatch quarantines the bundle
    (``step_<n>.quarantine/``) and raises :class:`CorruptBundleError`,
    and resolution helpers fall back to the newest step that *verifies*
    rather than trusting directory listings.
  * **Elastic re-mesh**: checkpoints store *logical* arrays (gathered or
    per-host shards keyed by flat path), never device layouts.  Restore
    device_puts onto whatever mesh/sharding the new job uses — a job
    restarted at a different pod count (e.g. after losing a pod) resumes
    from the same files.
  * **Async**: ``AsyncCheckpointer`` snapshots to host memory on-thread
    (device_get) and writes on a background thread, overlapping I/O with
    the next train steps; ``wait()`` joins before the next save or exit.
  * **Multi-host**: each host writes ``host<k>.npz`` with its addressable
    shards; this container is single-host so k=0 carries everything, but
    the file layout and manifest already carry the host dimension.

Crash-consistency points in the write protocol are addressable through
:func:`repro.testing.faults.fault_point` — the subprocess battery in
``scripts/crash_check.py`` kills the process at each of them and asserts
recovery.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.testing.faults import fault_point

_SEP = "/"

# Bundle-manifest generation.  v5 = per-array SHA-256 digests + byte
# sizes in every manifest ("digests" key); earlier manifests lack the
# key and load without verification.  Orthogonal to the per-kind
# ``extra["format_version"]`` (array-layout versions of the facades).
MANIFEST_VERSION = 5

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CorruptBundleError(IOError):
    """A checkpoint bundle failed integrity verification.

    Carries structured context so operators (and ``fsck_index.py``) can
    report exactly what rotted: the bundle dir, the step, and per-array
    problem strings.  The offending bundle has already been renamed to
    ``*.quarantine/`` when this is raised from a load path.
    """

    def __init__(self, ckpt_dir: str, step: int, problems: List[str],
                 quarantined: Optional[str] = None):
        detail = "; ".join(problems[:4]) + ("..." if len(problems) > 4 else "")
        super().__init__(
            f"corrupt checkpoint bundle {ckpt_dir}/step_{step:08d}: {detail}"
        )
        self.ckpt_dir = ckpt_dir
        self.step = step
        self.problems = problems
        self.quarantined = quarantined


def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-committed rename survives power loss.

    ``os.rename``/``os.replace`` are atomic but not durable: the new
    directory entry lives in the parent, and on ext4 the parent's
    metadata needs its own fsync to be guaranteed on disk.
    """
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _digest(arr: np.ndarray) -> Tuple[str, int]:
    buf = np.ascontiguousarray(arr).tobytes()
    return hashlib.sha256(buf).hexdigest(), len(buf)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic synchronous save. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    npz_path = os.path.join(tmp, "host0.npz")
    np.savez(npz_path, **flat)
    with open(npz_path, "rb") as f:
        os.fsync(f.fileno())
    fault_point("ckpt.npz.post_write", path=npz_path)
    manifest = {
        "format_version": MANIFEST_VERSION,
        "step": step,
        "n_hosts": 1,
        "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        "digests": {k: list(_digest(v)) for k, v in flat.items()},
        "extra": extra or {},
    }
    manifest_path = os.path.join(tmp, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    fault_point("ckpt.manifest.pre_rename", path=manifest_path)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    fault_point("ckpt.manifest.post_rename", path=ckpt_dir)
    _fsync_dir(ckpt_dir)
    return final


def atomic_write_json(path: str, obj: Any) -> str:
    """Write JSON via tmp + fsync + rename + parent-dir fsync — the commit
    point for saves that span several checkpoint bundles (e.g. a
    multi-segment mutable index): write every bundle first, then this
    manifest; a crash in between leaves the previous manifest (and
    whatever bundles it references) intact.
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    fault_point("ckpt.json.pre_rename", path=tmp)
    os.replace(tmp, path)
    fault_point("ckpt.json.post_rename", path=path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))
    return path


def prune_steps(ckpt_dir: str, keep) -> None:
    """Remove ``step_*`` bundles whose step number is not in ``keep``.

    The shared tail of every manifest-committed multi-bundle save (mutable
    index state, retrieval-store values, sharded-mutable buffer sidecars):
    after the new manifest commits, steps referenced by neither the new nor
    the immediately-previous manifest are dropped so repeated saves to one
    path occupy bounded disk.  ``.tmp`` partials, ``.quarantine`` evidence
    and non-step entries are left alone; missing directories are a no-op.
    """
    if not os.path.isdir(ckpt_dir):
        return
    keep = {k for k in keep if k is not None}
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m is None:
            continue
        if int(m.group(1)) not in keep:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def steps_present(ckpt_dir: str) -> List[int]:
    """All fully-renamed steps, newest first (quarantined/.tmp excluded)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m is not None and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            steps.append(int(m.group(1)))
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest fully-written step (ignores .tmp partials + quarantine)."""
    steps = steps_present(ckpt_dir)
    return steps[0] if steps else None


def quarantine_step(ckpt_dir: str, step: int) -> Optional[str]:
    """Move a corrupt bundle aside as ``step_<n>.quarantine`` (kept as
    evidence, invisible to step resolution).  Returns the new path."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(src):
        return None
    dst = src + ".quarantine"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{src}.quarantine.{n}"
    os.rename(src, dst)
    _fsync_dir(ckpt_dir)
    return dst


def verify_step(ckpt_dir: str, step: int) -> List[str]:
    """Scrub one bundle; returns problem strings (empty = verified).

    Checks that the manifest parses, every manifest leaf is present in
    the payload with the declared shape/dtype, and — for digest-bearing
    (v5+) manifests — that each array's SHA-256 and byte size match.
    Pre-v5 bundles pass when structurally sound (absence of digests is
    not evidence of corruption).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    problems: List[str] = []
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return [f"manifest unreadable: {e}"]
    digests = manifest.get("digests", {})
    try:
        data = np.load(os.path.join(d, "host0.npz"))
    except Exception as e:  # BadZipFile, OSError, EOFError...
        return [f"payload unreadable: {e}"]
    try:
        for key, (shape, dtype) in manifest.get("leaves", {}).items():
            try:
                arr = data[key]
            except Exception as e:
                problems.append(f"{key}: missing/unreadable ({e})")
                continue
            if list(arr.shape) != list(shape) or str(arr.dtype) != dtype:
                problems.append(
                    f"{key}: shape/dtype {arr.shape}/{arr.dtype} != "
                    f"manifest {tuple(shape)}/{dtype}"
                )
                continue
            if key in digests:
                want_hex, want_n = digests[key]
                got_hex, got_n = _digest(arr)
                if got_n != want_n or got_hex != want_hex:
                    problems.append(
                        f"{key}: digest mismatch "
                        f"({got_hex[:12]} != {want_hex[:12]})"
                    )
    finally:
        data.close()
    return problems


def latest_verifiable_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose bundle verifies; corrupt steps are quarantined.

    The durable replacement for "newest directory wins": resolution
    degrades past rotted bundles instead of failing on them.
    """
    for step in steps_present(ckpt_dir):
        if not verify_step(ckpt_dir, step):
            return step
        quarantine_step(ckpt_dir, step)
    return None


def restore(
    ckpt_dir: str,
    step: int,
    abstract_tree: Any,
    shardings: Optional[Any] = None,
    verify: bool = True,
) -> Tuple[Any, Dict]:
    """Restore onto the CURRENT mesh (elastic re-mesh).

    ``shardings``: optional pytree of NamedSharding matching abstract_tree;
    when given, leaves are device_put with those shardings (resharding from
    whatever layout the writing job had).

    With ``verify=True`` every array read is checked against the
    manifest digest (v5+ bundles); on mismatch the bundle is quarantined
    and :class:`CorruptBundleError` raised.  Verification is lazy: only
    the leaves this restore actually reads are hashed.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    problems: List[str] = []
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "host0.npz"))
    except (OSError, ValueError, EOFError) as e:
        quarantined = quarantine_step(ckpt_dir, step)
        raise CorruptBundleError(
            ckpt_dir, step, [f"bundle unreadable: {e}"], quarantined
        ) from e
    digests = manifest.get("digests", {}) if verify else {}
    leaves_paths = jax.tree_util.tree_flatten_with_path(abstract_tree)[0]
    treedef = jax.tree_util.tree_structure(abstract_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    try:
        for i, (path, leaf) in enumerate(leaves_paths):
            key = jax.tree_util.keystr(path)
            try:
                arr = data[key]
            except Exception as e:
                problems.append(f"{key}: missing/unreadable ({e})")
                break
            if key in digests:
                want_hex, want_n = digests[key]
                got_hex, got_n = _digest(arr)
                if got_n != want_n or got_hex != want_hex:
                    problems.append(
                        f"{key}: digest mismatch "
                        f"({got_hex[:12]} != {want_hex[:12]})"
                    )
                    break
            want = getattr(leaf, "dtype", None)
            if want is not None and arr.dtype != want:
                arr = arr.astype(want)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.device_put(arr))
    finally:
        data.close()
    if problems:
        quarantined = quarantine_step(ckpt_dir, step)
        raise CorruptBundleError(ckpt_dir, step, problems, quarantined)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training (straggler-free saves)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
