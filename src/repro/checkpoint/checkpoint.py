"""Fault-tolerant checkpointing: atomic, async, elastic across meshes.

Design (1000+-node posture):
  * **Atomic**: write into ``step_<n>.tmp/``, fsync, rename to ``step_<n>/``.
    A crash mid-write can never corrupt the latest restorable step;
    ``latest_step`` only sees fully renamed directories.
  * **Elastic re-mesh**: checkpoints store *logical* arrays (gathered or
    per-host shards keyed by flat path), never device layouts.  Restore
    device_puts onto whatever mesh/sharding the new job uses — a job
    restarted at a different pod count (e.g. after losing a pod) resumes
    from the same files.
  * **Async**: ``AsyncCheckpointer`` snapshots to host memory on-thread
    (device_get) and writes on a background thread, overlapping I/O with
    the next train steps; ``wait()`` joins before the next save or exit.
  * **Multi-host**: each host writes ``host<k>.npz`` with its addressable
    shards; this container is single-host so k=0 carries everything, but
    the file layout and manifest already carry the host dimension.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
    """Atomic synchronous save. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "host0.npz"), **flat)
    manifest = {
        "step": step,
        "n_hosts": 1,
        "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def atomic_write_json(path: str, obj: Any) -> str:
    """Write JSON via tmp + fsync + rename — the commit point for saves that
    span several checkpoint bundles (e.g. a multi-segment mutable index):
    write every bundle first, then this manifest; a crash in between leaves
    the previous manifest (and whatever bundles it references) intact.
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def prune_steps(ckpt_dir: str, keep) -> None:
    """Remove ``step_*`` bundles whose step number is not in ``keep``.

    The shared tail of every manifest-committed multi-bundle save (mutable
    index state, retrieval-store values, sharded-mutable buffer sidecars):
    after the new manifest commits, steps referenced by neither the new nor
    the immediately-previous manifest are dropped so repeated saves to one
    path occupy bounded disk.  ``.tmp`` partials and non-step entries are
    left alone; missing directories are a no-op.
    """
    if not os.path.isdir(ckpt_dir):
        return
    keep = {k for k in keep if k is not None}
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if int(name.split("_")[1]) not in keep:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest fully-written step (ignores .tmp partials)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    abstract_tree: Any,
    shardings: Optional[Any] = None,
) -> Tuple[Any, Dict]:
    """Restore onto the CURRENT mesh (elastic re-mesh).

    ``shardings``: optional pytree of NamedSharding matching abstract_tree;
    when given, leaves are device_put with those shardings (resharding from
    whatever layout the writing job had).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "host0.npz"))
    leaves_paths = jax.tree_util.tree_flatten_with_path(abstract_tree)[0]
    treedef = jax.tree_util.tree_structure(abstract_tree)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (path, leaf) in enumerate(leaves_paths):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training (straggler-free saves)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
