"""Per-index write-ahead log: acknowledged writes survive the process.

The mutable indexes acknowledge ``insert``/``delete`` from an in-memory
write buffer; checkpoints seal that state only when ``save()`` runs.
The WAL closes the gap: every mutation appends one framed record *before*
the in-memory state changes, so a crash at any instant loses nothing
that was acknowledged — ``load()`` replays the tail on top of the last
checkpoint, and the sequential :class:`~repro.index.mutable.LsmIdSpace`
id assignment makes the replay id-exact (recovery is bit-equal to never
having crashed).

Record framing
--------------
The file opens with an 8-byte magic, then repeated frames::

    [u32 payload_len][u32 crc32(payload)][u64 seq][payload]

``payload`` is ``[u32 header_len][json header][array bytes...]`` where
the JSON header carries the op name, a small metadata dict (the
``next_id`` watermark used for replay dedup) and the name/shape/dtype of
each array, in order.  The CRC covers the sequence number and the whole
payload (a corrupted length field changes what the CRC is computed
over), so any single bit flip anywhere in a frame — or a torn tail from
a mid-write power cut — is detected and the log is truncated at the
last intact frame.

Group commit
------------
``append`` acknowledges after ``write()`` returns: the record is in the
OS page cache, which survives a *process* crash (SIGKILL) uncondition-
ally.  ``fsync`` — the power-loss barrier — is batched by
:class:`WalConfig`: every ``sync_every`` records or ``sync_interval_ms``
milliseconds, whichever comes first; ``sync_every=1`` degenerates to
fsync-per-record full durability.  The default trades a bounded
power-loss window (not process-crash window) for an append path whose
overhead stays under 10% — measured by ``benchmarks/durability.py``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.testing.faults import fault_point

__all__ = [
    "WalConfig", "WalError", "WalWriteError", "WalRecord",
    "WriteAheadLog", "read_records", "open_and_recover", "wal_path",
]

_MAGIC = b"RWAL0001"
_FRAME = struct.Struct("<IIQ")          # payload_len, crc32, seq
_MAX_PAYLOAD = 1 << 30                  # sanity bound when scanning


def _crc(seq: int, payload: bytes) -> int:
    return zlib.crc32(payload, zlib.crc32(struct.pack("<Q", seq)))


class WalError(IOError):
    """Structural WAL problem (bad magic, misuse)."""


class WalWriteError(WalError):
    """An append/fsync failed — the mutation was NOT applied.

    The engine treats this as the signal to enter degraded read-only
    mode: without a working log, acknowledging writes would reintroduce
    the silent-loss window the WAL exists to close.
    """


@dataclasses.dataclass(frozen=True)
class WalConfig:
    """Group-commit policy.

    ``sync_every``: fsync after this many unsynced records (1 = every
    record).  ``sync_interval_ms``: also fsync when the oldest unsynced
    record is older than this, so a quiet stream still bounds its
    power-loss window.
    """
    sync_every: int = 32
    sync_interval_ms: float = 50.0


@dataclasses.dataclass(frozen=True)
class WalRecord:
    seq: int
    op: str
    arrays: Dict[str, np.ndarray]
    meta: Dict[str, Any]


def wal_path(ckpt_path: str) -> str:
    """Where the WAL for an index checkpointed at ``ckpt_path`` lives."""
    return os.path.join(ckpt_path, "wal.log")


def _encode(op: str, arrays: Dict[str, np.ndarray],
            meta: Dict[str, Any]) -> bytes:
    bufs = {k: np.ascontiguousarray(v) for k, v in arrays.items()}
    header = {
        "op": op,
        "meta": meta,
        "arrays": [[k, list(v.shape), str(v.dtype)] for k, v in bufs.items()],
    }
    hb = json.dumps(header).encode()
    parts = [struct.pack("<I", len(hb)), hb]
    parts.extend(v.tobytes() for v in bufs.values())
    return b"".join(parts)


def _decode(payload: bytes) -> Tuple[str, Dict[str, np.ndarray], Dict]:
    (hlen,) = struct.unpack_from("<I", payload, 0)
    header = json.loads(payload[4:4 + hlen].decode())
    arrays: Dict[str, np.ndarray] = {}
    off = 4 + hlen
    for name, shape, dtype in header["arrays"]:
        dt = np.dtype(dtype)
        nbytes = dt.itemsize * int(np.prod(shape, dtype=np.int64))
        arrays[name] = np.frombuffer(
            payload[off:off + nbytes], dtype=dt
        ).reshape(shape).copy()
        off += nbytes
    return header["op"], arrays, header.get("meta", {})


class WriteAheadLog:
    """Append-only framed log with batched fsync (see module docstring)."""

    def __init__(self, path: str, config: Optional[WalConfig] = None,
                 *, _start_seq: int = 0, _expect_empty: bool = True):
        self.path = path
        self.config = config or WalConfig()
        existed = os.path.exists(path)
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            size = os.fstat(self._fd).st_size
            if size == 0:
                os.write(self._fd, _MAGIC)
                os.fsync(self._fd)
                if not existed:
                    _dir_fsync(os.path.dirname(os.path.abspath(path)))
            elif _expect_empty and size > len(_MAGIC):
                raise WalError(
                    f"{path} already holds records; load() the index (which "
                    "replays and re-attaches) instead of enable_wal()"
                )
        except Exception:
            os.close(self._fd)
            raise
        self._seq = _start_seq
        self._unsynced = 0
        self._oldest_unsynced_t: Optional[float] = None
        self._closed = False

    # -- write path --------------------------------------------------------
    def append(self, op: str, arrays: Dict[str, np.ndarray],
               meta: Dict[str, Any]) -> int:
        """Frame + write one record; group-commit fsync.  Returns its seq.

        On any OS error the log is poisoned for the caller via
        :class:`WalWriteError`; the record may or may not be on disk, but
        the caller has not mutated state yet (log-then-apply), so either
        outcome is consistent: replay of a record whose apply never
        happened is exactly a replay of the crash case.
        """
        if self._closed:
            raise WalWriteError(f"{self.path}: WAL is closed")
        payload = _encode(op, arrays, meta)
        seq = self._seq
        frame = _FRAME.pack(len(payload), _crc(seq, payload), seq) + payload
        try:
            fault_point("wal.append.pre_write", path=self.path)
            os.write(self._fd, frame)
            fault_point("wal.append.post_write", path=self.path)
        except OSError as e:
            raise WalWriteError(f"{self.path}: append failed: {e}") from e
        self._seq = seq + 1
        self._unsynced += 1
        now = time.monotonic()
        if self._oldest_unsynced_t is None:
            self._oldest_unsynced_t = now
        cfg = self.config
        if (self._unsynced >= max(1, cfg.sync_every)
                or (now - self._oldest_unsynced_t) * 1e3
                >= cfg.sync_interval_ms):
            self.sync()
        return seq

    def sync(self) -> None:
        """Force the power-loss barrier for everything appended so far."""
        if self._closed or self._unsynced == 0:
            return
        try:
            fault_point("wal.fsync.pre", path=self.path)
            os.fsync(self._fd)
        except OSError as e:
            raise WalWriteError(f"{self.path}: fsync failed: {e}") from e
        self._unsynced = 0
        self._oldest_unsynced_t = None

    def truncate(self) -> None:
        """Drop every record: the checkpoint that just committed covers them.

        Called by ``save()`` *after* its manifest commit; a crash between
        the commit and this truncate only means records replay on top of
        state that already contains them — the ``next_id`` watermark in
        each record makes that replay a no-op.
        """
        if self._closed:
            return
        fault_point("wal.truncate.pre", path=self.path)
        os.ftruncate(self._fd, len(_MAGIC))
        os.fsync(self._fd)
        fault_point("wal.truncate.post", path=self.path)
        self._unsynced = 0
        self._oldest_unsynced_t = None

    def close(self) -> None:
        if self._closed:
            return
        try:
            if self._unsynced:
                os.fsync(self._fd)
        except OSError:
            pass
        os.close(self._fd)
        self._closed = True

    @property
    def next_seq(self) -> int:
        return self._seq

    def __repr__(self) -> str:
        return (f"WriteAheadLog({self.path!r}, next_seq={self._seq}, "
                f"sync_every={self.config.sync_every})")


def _dir_fsync(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def read_records(path: str) -> Tuple[List[WalRecord], int, bool]:
    """Scan a WAL file.  Returns ``(records, good_end_offset, torn)``.

    Scanning stops at the first frame whose length field runs past EOF
    or whose CRC fails — a torn tail from a crash mid-write, or a bit
    flip.  Everything before it is intact (each frame is independently
    CRC-framed); everything from it on is discarded by recovery.
    """
    records: List[WalRecord] = []
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:len(_MAGIC)] != _MAGIC:
        raise WalError(f"{path}: bad WAL magic")
    off = len(_MAGIC)
    torn = False
    while off < len(blob):
        if off + _FRAME.size > len(blob):
            torn = True
            break
        plen, crc, seq = _FRAME.unpack_from(blob, off)
        start = off + _FRAME.size
        if plen > _MAX_PAYLOAD or start + plen > len(blob):
            torn = True
            break
        payload = blob[start:start + plen]
        if _crc(seq, payload) != crc:
            torn = True
            break
        try:
            op, arrays, meta = _decode(payload)
        except Exception:
            torn = True
            break
        records.append(WalRecord(seq=seq, op=op, arrays=arrays, meta=meta))
        off = start + plen
    return records, off, torn


def open_and_recover(
    path: str, config: Optional[WalConfig] = None
) -> Tuple[List[WalRecord], "WriteAheadLog"]:
    """Read the intact prefix, truncate any torn tail, re-open for append.

    The returned log continues the sequence numbering after the last
    intact record, so replay-then-keep-serving needs no special casing.
    """
    records, good_end, torn = read_records(path)
    if torn:
        fd = os.open(path, os.O_WRONLY)
        try:
            os.ftruncate(fd, good_end)
            os.fsync(fd)
        finally:
            os.close(fd)
    start_seq = records[-1].seq + 1 if records else 0
    wal = WriteAheadLog(path, config, _start_seq=start_seq,
                        _expect_empty=False)
    return records, wal
