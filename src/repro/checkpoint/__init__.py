from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    atomic_write_json,
    latest_step,
    prune_steps,
    restore,
    save,
)
