from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    CorruptBundleError,
    atomic_write_json,
    latest_step,
    latest_verifiable_step,
    prune_steps,
    quarantine_step,
    restore,
    save,
    steps_present,
    verify_step,
)
from repro.checkpoint.wal import (  # noqa: F401
    WalConfig,
    WalError,
    WalRecord,
    WalWriteError,
    WriteAheadLog,
    open_and_recover,
    read_records,
    wal_path,
)
