"""Mamba-2 SSD (state-space duality) block — chunked matmul formulation.

The SSD algorithm (Dao & Gu, 2024) is the TPU-native way to run selective
SSMs: instead of a length-S sequential scan (VPU-serial), the sequence is
split into chunks of Q tokens; within-chunk interactions are dense Q×Q
matmuls (MXU) and only the nc = S/Q chunk boundary states thread through a
`lax.scan`.  Decode keeps a constant-size state (B, H, N, P) — the reason
``long_500k`` runs for SSM/hybrid archs.

Layout per layer (ngroups=1, shared B/C across heads as in mamba2-780m):
  in_proj : (D, 2·di + 2·N + H)   -> z, x, B, C, dt
  conv1d  : depthwise causal width-4 over [x, B, C] channels
  A_log, D̂, dt_bias : (H,)
  norm    : gated RMSNorm scale (di,)
  out_proj: (di, D)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.config import ModelConfig
from repro.sharding import ShardingRules, shard

Params = Dict[str, Any]


def ssm_init(cfg: ModelConfig, key: jax.Array) -> Params:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.ssm_conv_width
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * n + h), jnp.float32)
        / np.sqrt(d),
        "conv_w": jax.random.normal(ks[1], (cw, conv_ch), jnp.float32) / np.sqrt(cw),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, float(h), h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[3], (di, d), jnp.float32) / np.sqrt(di),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: xbc (B,S,C), w (cw,C) -> (B,S,C)."""
    cw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(cw):  # cw = 4: unrolled shifts beat a conv call on TPU
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt


def _gated_norm(p: Params, y: jax.Array, z: jax.Array) -> jax.Array:
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * lax.rsqrt(ms + 1e-6) * p["norm_scale"]).astype(y.dtype)


def ssm_forward(
    cfg: ModelConfig, p: Params, x: jax.Array, rules: ShardingRules
) -> jax.Array:
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D)."""
    bsz, s, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = cfg.ssm_chunk
    dtype = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    z = shard(z, rules, "batch", None, "mlp")
    xbc = _causal_conv(xbc, p["conv_w"].astype(dtype), p["conv_b"].astype(dtype))
    xbc = jax.nn.silu(xbc)
    xin = shard(xbc[..., :di], rules, "batch", None, "mlp")
    bmat = xbc[..., di : di + n]
    cmat = xbc[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,) < 0
    da = dt * a[None, None, :]  # (B,S,H) log-decay, <= 0

    # pad S to chunk multiple (dt=0 on pad -> identity decay, zero input)
    pad = (-s) % q
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q

    xh = xin.reshape(bsz, nc, q, h, pd)
    bc_ = bmat.reshape(bsz, nc, q, n)
    cc_ = cmat.reshape(bsz, nc, q, n)
    dtc = dt.reshape(bsz, nc, q, h)
    dac = da.reshape(bsz, nc, q, h).astype(jnp.float32)

    cum = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H) inclusive
    xbar = xh * dtc[..., None].astype(dtype)  # dt-scaled input

    # --- intra-chunk: (L ⊙ C Bᵀ) x̄ ---
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: in the upper triangle li > 0 can overflow to inf and
    # inf·0 => NaN cotangents through jnp.where's backward.
    li = jnp.where(tri[None, None, :, :, None], li, -1e30)
    decay = jnp.exp(li).astype(dtype)
    cb = jnp.einsum("bcin,bcjn->bcij", cc_, bc_)  # (B,nc,Q,Q)
    att = cb[..., None] * decay  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xbar)

    # --- chunk states + inter-chunk scan ---
    cum_end = cum[:, :, -1:, :]  # (B,nc,1,H)
    seg = jnp.exp((cum_end - cum)).astype(dtype)  # decay from j to chunk end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc_, seg, xbar)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum_end[:, :, 0, :]).astype(dtype)  # (B,nc,H)

    def scan_body(hprev, inputs):
        st, dk = inputs  # (B,H,N,P), (B,H)
        hnew = hprev * dk[:, :, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, pd), dtype)
    _, hprevs = lax.scan(
        scan_body,
        h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    hprevs = jnp.moveaxis(hprevs, 0, 1)  # (B,nc,H,N,P) state entering chunk

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", cc_, hprevs, jnp.exp(cum).astype(dtype))
    y = y_intra + y_inter  # (B,nc,Q,H,P)
    y = y.reshape(bsz, nc * q, h, pd)[:, :s]
    y = y + xin.reshape(bsz, nc * q, h, pd)[:, :s] * p["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = shard(y, rules, "batch", None, "mlp")

    y = _gated_norm(p, y, z[:, :s])
    out = y @ p["out_proj"].astype(dtype)
    return shard(out, rules, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# Decode: constant-size state update
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, h, n, pd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype),
    }


def ssm_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,  # (B, 1, D)
    cache: Dict[str, Any],
    rules: ShardingRules,
) -> Tuple[jax.Array, Dict[str, Any]]:
    bsz = x.shape[0]
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dtype = x.dtype

    zxbcdt = x[:, 0, :] @ p["in_proj"].astype(dtype)  # (B, ...)
    z, xbc_new, dt_raw = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([cache["conv"].astype(dtype), xbc_new[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(dtype))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dtype))
    new_conv = window[:, 1:, :]

    xin = conv_out[:, :di].reshape(bsz, h, pd)
    bvec = conv_out[:, di : di + n]
    cvec = conv_out[:, di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])  # (B,H)

    hstate = cache["h"]
    xbar = xin.astype(jnp.float32) * dt[:, :, None]
    hnew = hstate * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", bvec.astype(jnp.float32), xbar
    )
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), hnew).astype(dtype)
    y = y + xin * p["d_skip"].astype(dtype)[None, :, None]
    y = y.reshape(bsz, 1, di)
    y = _gated_norm(p, y, z[:, None, :])
    out = y @ p["out_proj"].astype(dtype)
    out = shard(out, rules, "batch", "seq", "d_model")
    return out, {"h": hnew, "conv": new_conv.astype(cache["conv"].dtype)}
