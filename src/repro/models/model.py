"""Config-driven model: init / train-forward / prefill / single-token decode.

The stack is ``n_blocks`` repetitions of ``cfg.pattern`` (scanned — one
statically-specialized pattern body in the HLO regardless of depth) plus an
unrolled remainder.  The same layer code serves all 10 assigned archs; per-
layer heterogeneity (local/global windows, MoE interleave, mamba mixers,
cross-attention) is resolved statically from the pattern at trace time.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers, moe, ssm
from repro.models.config import ATTN, DENSE, MAMBA, MOE, NONE, LayerSpec, ModelConfig
from repro.sharding import ShardingRules, shard

Params = Dict[str, Any]
ENC_SPEC = LayerSpec(mixer=ATTN, ffn=DENSE)


@jax.custom_vjp
def _pinned(tree):
    """``optimization_barrier`` with an identity VJP.

    The barrier has no differentiation rule, and its purpose here is purely
    a scheduling pin — mathematically it IS the identity — so the custom
    rule passes cotangents straight through (the surrounding casts' own
    transposes restore f32 where needed).
    """
    return jax.lax.optimization_barrier(tree)


def _pinned_fwd(tree):
    return _pinned(tree), None


def _pinned_bwd(_, ct):
    return (ct,)


_pinned.defvjp(_pinned_fwd, _pinned_bwd)


def _bf16_params(cfg: ModelConfig, params: Params) -> Params:
    """Pre-cast big (>1M elem) f32 weights to bf16 once per step.

    The cast must happen BEFORE the per-layer use sites: otherwise XLA
    all-gathers FSDP-sharded weights in f32 and converts after — 2× the
    collective bytes (measured: yi-34b train collective term 55s -> 29s).
    Small leaves (norm scales, a_log, dt_bias) stay f32 for numerics.
    """
    if cfg.compute_dtype != "bfloat16":
        return params

    def cast(a):
        if hasattr(a, "dtype") and a.dtype == jnp.float32 and a.size > 1_000_000:
            return a.astype(jnp.bfloat16)
        return a

    # The barrier pins the converts: without it GSPMD hoists the FSDP
    # all-gather BEFORE the convert and moves f32 weights over the wire
    # (nemotron: 4.2 TB/device of f32[18432,18432] gathers).
    return _pinned(jax.tree.map(cast, params))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4)
    lp: Params = {"norm1": layers.norm_init(cfg, cfg.d_model)}
    if spec.mixer == ATTN:
        lp["mixer"] = layers.attn_init(cfg, ks[0])
    elif spec.mixer == MAMBA:
        lp["mixer"] = ssm.ssm_init(cfg, ks[0])
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        lp["norm_cross"] = layers.norm_init(cfg, cfg.d_model)
        lp["cross"] = layers.attn_init(cfg, ks[1], cross=True)
    if spec.ffn != NONE:
        lp["norm2"] = layers.norm_init(cfg, cfg.d_model)
        lp["ffn"] = (
            layers.ffn_init(cfg, ks[2]) if spec.ffn == DENSE else moe.moe_init(cfg, ks[2])
        )
    return lp


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": layers.embed_init(cfg, keys[0]),
        "final_norm": layers.norm_init(cfg, cfg.d_model),
    }
    p_len = cfg.pattern_len
    blocks = []
    for s in range(p_len):
        per_block = [
            _layer_init(cfg, cfg.pattern[s], jax.random.fold_in(keys[1], b * p_len + s))
            for b in range(cfg.n_blocks)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block))
    params["blocks"] = blocks
    params["tail"] = [
        _layer_init(cfg, spec, jax.random.fold_in(keys[2], j))
        for j, spec in enumerate(cfg.tail_specs)
    ]
    if cfg.is_encdec:
        enc_layers = [
            _layer_init(cfg, ENC_SPEC, jax.random.fold_in(keys[3], j))
            for j in range(cfg.n_enc_layers)
        ]
        params["enc"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "final_norm": layers.norm_init(cfg, cfg.d_model),
        }
    if cfg.n_patches:
        params["patch_proj"] = (
            jax.random.normal(keys[4], (cfg.patch_dim, cfg.d_model), jnp.float32) * 0.02
        )
    return params


def abstract_params(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree (for dry-run lowering without allocation)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


# ---------------------------------------------------------------------------
# Remat
# ---------------------------------------------------------------------------


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full": save only layer inputs


# ---------------------------------------------------------------------------
# Forward (train / encoder)
# ---------------------------------------------------------------------------


def _layer_forward(
    cfg, spec, lp, x, positions, rules, enc_out=None, causal=True, emit_cache=False
):
    h = layers.apply_norm(cfg, lp["norm1"], x)
    cache: Dict[str, Any] = {}
    if spec.mixer == ATTN:
        a, c = layers.attn_forward(
            cfg, spec, lp["mixer"], h, positions, rules,
            causal=causal, emit_cache=emit_cache,
        )
        if emit_cache:
            cache["mixer"] = _ring_compress(cfg, spec, c)
    else:
        if emit_cache:
            a, cache["mixer"] = ssm_forward_with_cache(cfg, lp["mixer"], h, rules)
        else:
            a = ssm.ssm_forward(cfg, lp["mixer"], h, rules)
    x = x + a
    if spec.cross_attn:
        h = layers.apply_norm(cfg, lp["norm_cross"], x)
        a, c = layers.attn_forward(
            cfg, spec, lp["cross"], h, positions, rules,
            causal=False, x_kv=enc_out, emit_cache=emit_cache,
        )
        if emit_cache:
            cache["cross"] = c
        x = x + a
    elif emit_cache:
        cache["cross"] = ()
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != NONE:
        h = layers.apply_norm(cfg, lp["norm2"], x)
        if spec.ffn == DENSE:
            f = layers.ffn_forward(cfg, lp["ffn"], h, rules)
        else:
            f, aux = moe.moe_forward(cfg, lp["ffn"], h, rules)
        x = x + f
    return x, aux, (cache if emit_cache else None)


def _ring_compress(cfg, spec, c: layers.AttnCache) -> layers.AttnCache:
    """Convert a full prefill cache to the layer's ring-buffer layout."""
    s = c.k.shape[1]
    if spec.window <= 0 or s <= spec.window:
        return c
    w = spec.window
    keep_pos = jnp.arange(s - w, s, dtype=jnp.int32)
    slots = keep_pos % w
    k = jnp.zeros((c.k.shape[0], w) + c.k.shape[2:], c.k.dtype).at[:, slots].set(
        c.k[:, s - w :]
    )
    v = jnp.zeros((c.v.shape[0], w) + c.v.shape[2:], c.v.dtype).at[:, slots].set(
        c.v[:, s - w :]
    )
    pos = jnp.full((w,), -1, jnp.int32).at[slots].set(keep_pos)
    return layers.AttnCache(k=k, v=v, pos=pos)


def ssm_forward_with_cache(cfg, lp, h, rules):
    """SSD forward that also returns the decode cache (state + conv tail)."""
    out = ssm.ssm_forward(cfg, lp, h, rules)
    # Recompute the tail conv inputs and final state cheaply via decode math
    # would be wasteful; instead run the full forward's state path once more
    # on the last chunk only is complex — we take the simple exact route:
    # final state via a full pass of the recurrence at chunk granularity.
    cache = _ssm_final_state(cfg, lp, h, rules)
    return out, cache


def _ssm_final_state(cfg, lp, x, rules):
    bsz, s, _ = x.shape
    di, n, h_, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = cfg.ssm_chunk
    dtype = x.dtype
    zxbcdt = x @ lp["in_proj"].astype(dtype)
    _, xbc_raw, dt_raw = ssm._split_proj(cfg, zxbcdt)
    conv_tail = xbc_raw[:, max(0, s - (cfg.ssm_conv_width - 1)) :, :]
    pad_c = cfg.ssm_conv_width - 1 - conv_tail.shape[1]
    if pad_c > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad_c, 0), (0, 0)))
    xbc = ssm._causal_conv(xbc_raw, lp["conv_w"].astype(dtype), lp["conv_b"].astype(dtype))
    xbc = jax.nn.silu(xbc)
    xin, bmat = xbc[..., :di], xbc[..., di : di + n]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    da = dt * a[None, None, :]
    pad = (-s) % q
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    xh = xin.reshape(bsz, nc, q, h_, pd)
    bc_ = bmat.reshape(bsz, nc, q, n)
    dtc = dt.reshape(bsz, nc, q, h_)
    dac = da.reshape(bsz, nc, q, h_).astype(jnp.float32)
    cum = jnp.cumsum(dac, axis=2)
    xbar = xh * dtc[..., None].astype(dtype)
    cum_end = cum[:, :, -1:, :]
    seg = jnp.exp(cum_end - cum).astype(dtype)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc_, seg, xbar)
    chunk_decay = jnp.exp(cum_end[:, :, 0, :]).astype(dtype)

    def scan_body(hprev, inputs):
        st, dk = inputs
        return hprev * dk[:, :, None, None] + st, None

    h0 = jnp.zeros((bsz, h_, n, pd), jnp.float32)
    hfinal, _ = lax.scan(
        scan_body, h0,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)),
    )
    return {"h": hfinal, "conv": conv_tail.astype(jnp.bfloat16)}


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array, rules) -> jax.Array:
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))
    x = frames + layers.sinusoidal_positions(positions, cfg.d_model).astype(frames.dtype)
    x = shard(x, rules, "batch", "frames", "d_model")

    def body(carry, bp):
        x = carry
        x, _, _ = _layer_forward(cfg, ENC_SPEC, bp, x, positions, rules, causal=False)
        return x, None

    x, _ = lax.scan(_maybe_remat(cfg, body), x, params["enc"]["blocks"])
    return layers.apply_norm(cfg, params["enc"]["final_norm"], x)


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                     # (B, S)
    rules: ShardingRules,
    *,
    patches: Optional[jax.Array] = None,   # (B, n_patches, patch_dim)
    frames: Optional[jax.Array] = None,    # (B, enc_frames, d_model)
    emit_caches: bool = False,
    last_only: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Returns (logits (B,S,Vp) | hidden, moe_aux, caches-or-None).

    ``last_only`` unembeds just the final position (prefill: the (B,S,V)
    logits tensor would dominate memory); ``return_hidden`` skips the
    unembed entirely (training uses the chunked loss instead).
    """
    b, s = tokens.shape
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    params = _bf16_params(cfg, params)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = layers.embed_tokens(cfg, params["embed"], tokens, rules).astype(dtype)
    if cfg.n_patches and patches is not None:
        pe = (patches.astype(dtype) @ params["patch_proj"].astype(dtype))
        x = lax.dynamic_update_slice(x, pe, (0, 0, 0))
    enc_out = None
    if cfg.is_encdec:
        assert frames is not None, "enc-dec arch needs stub frames"
        enc_out = _encode(cfg, params, frames.astype(dtype), rules)
        x = x + layers.sinusoidal_positions(positions, cfg.d_model).astype(dtype)

    # NOTE: the scan carry is x ONLY (bf16).  A mixed-dtype (bf16, f32)
    # carry tuple made XLA store the remat-saved x stack in f32 — a 43 GB
    # materialization at granite-3-8b train_4k (2× the bf16 stack).  The
    # per-block aux (MoE load-balance loss) rides in the scan ys instead.
    def body(x, bp):
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for si, spec in enumerate(cfg.pattern):
            x, a, c = _layer_forward(
                cfg, spec, bp[si], x, positions, rules, enc_out, True, emit_caches
            )
            aux = aux + a
            caches.append(c)
        ys = (aux, tuple(caches)) if emit_caches else aux
        return x, ys

    block_caches = None
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_blocks:
        if emit_caches:
            x, (aux_blocks, block_caches) = lax.scan(
                _maybe_remat(cfg, body), x, params["blocks"]
            )
        else:
            x, aux_blocks = lax.scan(_maybe_remat(cfg, body), x, params["blocks"])
        aux = jnp.sum(aux_blocks)

    tail_caches = []
    for j, spec in enumerate(cfg.tail_specs):
        x, a, c = _layer_forward(
            cfg, spec, params["tail"][j], x, positions, rules, enc_out, True, emit_caches
        )
        aux = aux + a
        tail_caches.append(c)

    x = layers.apply_norm(cfg, params["final_norm"], x)
    caches = None
    if emit_caches:
        caches = {"blocks": block_caches, "tail": tail_caches}
    if return_hidden:
        return x, aux, caches
    if last_only:
        logits = layers.unembed(cfg, params["embed"], x[:, -1:], rules)
    else:
        logits = layers.unembed(cfg, params["embed"], x, rules)
    return logits, aux, caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
            mask: jax.Array) -> jax.Array:
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.padded_vocab, dtype=jnp.float32)
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = (logz - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _loss_chunk_size(s: int, cap: int = 512) -> int:
    """Largest divisor of s that is <= cap (full s if s <= cap)."""
    if s <= cap:
        return s
    for c in range(cap, 0, -1):
        if s % c == 0:
            return c
    return s


def chunked_lm_loss(
    cfg: ModelConfig,
    params: Params,
    x: jax.Array,            # (B, S, D) final hidden states (pre-norm applied)
    labels: jax.Array,
    mask: jax.Array,
    rules: ShardingRules,
) -> jax.Array:
    """Softmax x-ent without materializing (B, S, V) logits.

    The (B,S,V) logits tensor is the single largest transient at train_4k
    (gemma3: 520 GB global); scanning the unembed+loss over sequence chunks
    with per-chunk remat keeps only (B, C, V) live.
    """
    b, s, _ = x.shape
    c = _loss_chunk_size(s)
    nc = s // c
    xc = x.reshape(b, nc, c, x.shape[-1]).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_fn(carry, inp):
        nll_sum, m_sum = carry
        xi, li, mi = inp
        logits = layers.unembed(cfg, params["embed"], xi, rules)
        lf = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(li, cfg.padded_vocab, dtype=jnp.float32)
        ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
        nll = (logz - ll) * mi
        return (nll_sum + nll.sum(), m_sum + mi.sum()), None

    (nll_sum, m_sum), _ = lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc),
    )
    return nll_sum / jnp.maximum(m_sum, 1.0)


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
               rules: ShardingRules) -> jax.Array:
    x, aux, _ = forward(
        cfg, params, batch["tokens"], rules,
        patches=batch.get("patches"), frames=batch.get("frames"),
        return_hidden=True,
    )
    loss = chunked_lm_loss(cfg, params, x, batch["labels"], batch["mask"], rules)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(cfg, params, tokens, rules, *, patches=None, frames=None):
    """Run the full prompt; returns (last-token logits, caches)."""
    logits, _, caches = forward(
        cfg, params, tokens, rules, patches=patches, frames=frames,
        emit_caches=True, last_only=True,
    )
    return logits[:, -1], caches


def _layer_decode(cfg, spec, lp, cache, x, idx, rules, enc_out=None):
    h = layers.apply_norm(cfg, lp["norm1"], x)
    newc: Dict[str, Any] = {}
    if spec.mixer == ATTN:
        a, newc["mixer"] = layers.attn_decode(cfg, spec, lp["mixer"], h, idx,
                                              cache["mixer"], rules)
    else:
        a, newc["mixer"] = ssm.ssm_decode(cfg, lp["mixer"], h, cache["mixer"], rules)
    x = x + a
    if spec.cross_attn:
        h = layers.apply_norm(cfg, lp["norm_cross"], x)
        a, _ = layers.attn_decode(
            cfg, spec, lp["cross"], h, idx, cache["cross"], rules, is_cross=True
        )
        newc["cross"] = cache["cross"]
        x = x + a
    else:
        newc["cross"] = cache.get("cross", ())
    if spec.ffn != NONE:
        h = layers.apply_norm(cfg, lp["norm2"], x)
        if spec.ffn == DENSE:
            f = layers.ffn_forward(cfg, lp["ffn"], h, rules)
        else:
            f, _ = moe.moe_forward(cfg, lp["ffn"], h, rules)
        x = x + f
    return x, newc


def decode_step(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,     # (B, 1) the token generated at position idx-1
    idx: jax.Array,        # scalar int32: position to write/attend
    caches: Dict[str, Any],
    rules: ShardingRules,
    *,
    with_hidden: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step for the whole batch. Returns (logits, caches)
    — or (logits, caches, hidden (B,D)) with ``with_hidden`` (the retrieval
    path queries the Hilbert forest with the pre-unembed hidden state)."""
    b = tokens.shape[0]
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    params = _bf16_params(cfg, params)
    x = layers.embed_tokens(cfg, params["embed"], tokens, rules).astype(dtype)
    if cfg.is_encdec:
        pos = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
        x = x + layers.sinusoidal_positions(pos, cfg.d_model).astype(dtype)

    def body(x, xs):
        bp, bc = xs
        newc = []
        for si, spec in enumerate(cfg.pattern):
            x, c = _layer_decode(cfg, spec, bp[si], bc[si], x, idx, rules)
            newc.append(c)
        return x, tuple(newc)

    new_block_caches = caches["blocks"]
    if cfg.n_blocks:
        x, new_block_caches = lax.scan(body, x, (params["blocks"], caches["blocks"]))
    new_tail = []
    for j, spec in enumerate(cfg.tail_specs):
        x, c = _layer_decode(cfg, spec, params["tail"][j], caches["tail"][j], x, idx, rules)
        new_tail.append(c)

    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, rules)
    new_caches = {"blocks": new_block_caches, "tail": new_tail}
    if with_hidden:
        return logits[:, 0], new_caches, x[:, 0]
    return logits[:, 0], new_caches


def make_decode_caches(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> Dict[str, Any]:
    """Zero-initialized decode caches (ring-sized for windowed layers)."""

    def one(spec: LayerSpec) -> Dict[str, Any]:
        c: Dict[str, Any] = {}
        if spec.mixer == ATTN:
            c["mixer"] = layers.init_attn_cache(cfg, spec, batch, max_seq, dtype)
        else:
            c["mixer"] = ssm.init_ssm_cache(cfg, batch, dtype)
        if spec.cross_attn:
            c["cross"] = layers.AttnCache(
                k=jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype),
                v=jnp.zeros((batch, cfg.enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype),
                pos=jnp.arange(cfg.enc_frames, dtype=jnp.int32),
            )
        else:
            c["cross"] = ()
        return c

    if cfg.n_blocks:
        blocks = tuple(
            jax.tree.map(
                lambda *xs: jnp.stack(xs), *[one(s) for _ in range(cfg.n_blocks)]
            )
            for s in cfg.pattern
        )
    else:
        blocks = ()
    tail = [one(spec) for spec in cfg.tail_specs]
    return {"blocks": blocks, "tail": tail}


def pad_caches(cfg: ModelConfig, caches: Dict[str, Any], max_seq: int):
    """Grow prefill-emitted full-attention caches to ``max_seq`` slots.

    Windowed (ring) and SSM caches are already final-sized; full-attention
    caches from a length-S prefill have S slots and must be padded (pos=-1)
    before decoding past S.
    """

    def grow(c):
        if not isinstance(c, layers.AttnCache):
            return c
        # stacked block caches carry a leading n_blocks dim on k/v/pos
        seq_axis = c.k.ndim - 3
        cur = c.k.shape[seq_axis]
        if cur >= max_seq:
            return c
        # ring caches (windowed layers) are smaller than the prefill length
        # by construction and must not be grown; detect via pos capacity:
        # full caches have pos.shape[-1] == cur == prefill length.
        padw = [(0, 0)] * c.k.ndim
        padw[seq_axis] = (0, max_seq - cur)
        pos_pad = [(0, 0)] * (c.pos.ndim - 1) + [(0, max_seq - cur)]
        return layers.AttnCache(
            k=jnp.pad(c.k, padw),
            v=jnp.pad(c.v, padw),
            pos=jnp.pad(c.pos, pos_pad, constant_values=-1),
        )

    def walk(tree, spec):
        out = dict(tree)
        # only full-attention self-caches grow; ring (windowed), SSM, and
        # cross-attention (fixed enc_frames) caches are already final-sized.
        if spec.mixer == ATTN and spec.window == 0:
            out["mixer"] = grow(tree["mixer"])
        return out

    blocks = caches["blocks"]
    if blocks is not None:
        blocks = tuple(
            walk(blocks[si], spec) for si, spec in enumerate(cfg.pattern)
        )
    tail = [walk(c, spec) for c, spec in zip(caches["tail"], cfg.tail_specs)]
    return {"blocks": blocks, "tail": tail}


def abstract_decode_caches(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(make_decode_caches, cfg, batch, max_seq, dtype)
    )
