"""Composable transformer building blocks (pure JAX pytrees, no flax).

Every activation is annotated with logical sharding axes (repro.sharding);
the same code lowers on 1 CPU device and on the (pod, data, model) production
mesh.  Attention covers full/local/SWA via a dynamic window scalar (identical
HLO), GQA via head grouping, and three execution modes: train (full-seq),
prefill (full-seq + cache emit), decode (single step + ring-buffer cache).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.config import LayerSpec, ModelConfig
from repro.sharding import ShardingRules, shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    # Moment statistics accumulate in f32, but the normalizing multiply
    # stays in x.dtype: upcasting x itself makes XLA hoist the bf16->f32
    # convert of the remat-saved layer-input stack out of the backward
    # while-loop — a 43 GB materialization at granite-3-8b train_4k.
    if cfg.norm == "layernorm":
        mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
        var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
        inv = lax.rsqrt(var + 1e-5).astype(x.dtype)
        out = (x - mu.astype(x.dtype)) * inv * p["scale"].astype(x.dtype)
        return out + p["bias"].astype(x.dtype)
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = lax.rsqrt(ms + 1e-6).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh), positions: (B, S) -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, :, None].astype(jnp.float32) * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)  # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Parameter-free position encoding (whisper stub; any length)."""
    half = d // 2
    freqs = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA; full / local / SWA via window scalar)
# ---------------------------------------------------------------------------


def _init_linear(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def attn_init(cfg: ModelConfig, key: jax.Array, cross: bool = False) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _init_linear(ks[0], (d, hq * dh)),
        "wk": _init_linear(ks[1], (d, hkv * dh)),
        "wv": _init_linear(ks[2], (d, hkv * dh)),
        "wo": _init_linear(ks[3], (hq * dh, d)),
    }


@dataclasses.dataclass(frozen=True)
class AttnCache:
    """Decode-time KV cache; ``length`` slots (= window for local layers)."""

    k: jax.Array          # (B, L, Hkv, Dh)
    v: jax.Array          # (B, L, Hkv, Dh)
    pos: jax.Array        # (L,) int32 absolute positions stored (-1 = empty)


jax.tree_util.register_dataclass(
    AttnCache, data_fields=["k", "v", "pos"], meta_fields=[]
)


def _project_qkv(cfg, p, x, x_kv, positions, kv_positions, spec, rules, is_cross):
    b, s, _ = x.shape
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (x_kv @ p["wk"].astype(dtype)).reshape(
        b, x_kv.shape[1], cfg.n_kv_heads, cfg.head_dim
    )
    v = (x_kv @ p["wv"].astype(dtype)).reshape(
        b, x_kv.shape[1], cfg.n_kv_heads, cfg.head_dim
    )
    if not is_cross and not cfg.is_encdec:
        q = rope(q, positions, spec.rope_theta)
        k = rope(k, kv_positions, spec.rope_theta)
    if not rules.attn_unconstrained:
        # internal constraints use seq=None: under sequence parallelism the
        # residual stream is seq-sharded only BETWEEN layers; inside the
        # mixer seq is gathered and heads carry the model axis (Ulysses).
        q = shard(q, rules, "batch", None, "heads", "head_dim")
        # full-sequence attention: kv_heads shard when they cover the model
        # axis, else REPLICATE (dh-sharding k/v here made GSPMD gather K/V
        # to global batch in f32 — 2.7 TB/step at granite train_4k).  The
        # dh-sharded layout is for CACHES only (decode memory), applied at
        # the cache emit boundary.
        k = shard(k, rules, "batch", None, "kv_heads", None)
        v = shard(v, rules, "batch", None, "kv_heads", None)
    return q, k, v


def _attend(cfg, q, k, v, mask, rules, cache_sharded=False):
    """q: (B,Sq,Hq,Dh), k/v: (B,Sk,Hkv,Dh), mask: (1,1,1,Sq,Sk) or None."""
    b, sq, hq, dh = q.shape
    hkv = cfg.n_kv_heads
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / np.sqrt(dh)
    # scores: (B, Hkv, G, Sq, Sk).  Deliberately NOT sharding-constrained:
    # forcing kv_heads onto the 16-way model axis when kv ∈ {1, 8} made
    # GSPMD insert involuntary full rematerializations (replicate+reslice)
    # around the attention transposes; propagation from q/k/v is strictly
    # better in every measured cell (EXPERIMENTS.md §Perf baseline notes).
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    out = out.reshape(b, sq, hq * dh)
    if rules.attn_unconstrained:
        return out
    return shard(out, rules, "batch", None, "heads")


def _chunk_divisor(s: int, cap: int = 512) -> int:
    if s <= cap:
        return s
    for c in range(cap, 0, -1):
        if s % c == 0:
            return c
    return s


def _attend_qchunked(cfg, q, k, v, qpos_row, kpos_row, spec, causal, rules):
    """Exact attention scanned over query chunks (flash-style memory).

    Full (B,h,g,Sq,Sk) probs at train_4k/prefill_32k are the dominant
    transient (gemma3 train: 4.3 GB/layer/device in f32).  Each chunk's
    softmax axis (Sk) is complete, so chunking the QUERY dim is exact — no
    online-softmax state needed; jax.checkpoint makes the backward
    recompute per chunk.  qpos_row/kpos_row are (Sq,)/(Sk,) single rows —
    masks must NOT be materialized per batch row.
    """
    b, sq, hq, dh = q.shape
    c = _chunk_divisor(sq)
    nc = sq // c
    if nc == 1:
        mask = None
        if causal:
            mask = (kpos_row[None, :] <= qpos_row[:, None])[None, None, None]
            if spec.window > 0:
                mask &= (kpos_row[None, :] > qpos_row[:, None] - spec.window)[
                    None, None, None
                ]
        return _attend(cfg, q, k, v, mask, rules)

    qc = q.reshape(b, nc, c, hq, dh).transpose(1, 0, 2, 3, 4)
    pc = qpos_row.reshape(nc, c)

    @jax.checkpoint
    def chunk_fn(_, inp):
        qi, pi = inp  # (B,C,H,Dh), (C,)
        mask = None
        if causal:
            mask = (kpos_row[None, :] <= pi[:, None])[None, None, None]
            if spec.window > 0:
                mask &= (kpos_row[None, :] > pi[:, None] - spec.window)[
                    None, None, None
                ]
        return None, _attend(cfg, qi, k, v, mask, rules)

    _, out = lax.scan(chunk_fn, None, (qc, pc))  # (nc, B, C, H*Dh)
    out = out.transpose(1, 0, 2, 3).reshape(b, sq, hq * dh)
    if rules.attn_unconstrained:
        return out
    return shard(out, rules, "batch", None, "heads")


def attn_forward(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    rules: ShardingRules,
    *,
    causal: bool = True,
    x_kv: Optional[jax.Array] = None,
    emit_cache: bool = False,
) -> Tuple[jax.Array, Optional[AttnCache]]:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    is_cross = x_kv is not None
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if not is_cross else jnp.broadcast_to(
        jnp.arange(x_kv.shape[1], dtype=jnp.int32)[None], (x.shape[0], x_kv.shape[1])
    )
    q, k, v = _project_qkv(
        cfg, p, x, x_kv, positions, kv_positions, spec, rules, is_cross
    )
    # positions are uniform across the batch everywhere in this framework;
    # masks are built from single (S,) rows so they broadcast (B,S,S) masks
    # were a 1 GB/layer s32 transient at train_4k.
    out = _attend_qchunked(
        cfg, q, k, v, positions[0], kv_positions[0], spec, causal and not is_cross,
        rules,
    )
    out = out @ p["wo"].astype(x.dtype)
    out = shard(out, rules, "batch", "seq", "d_model")
    cache = None
    if emit_cache:
        kc = shard(k, rules, "batch", "cache_seq", "kv_heads", "kv_head_dim")
        vc = shard(v, rules, "batch", "cache_seq", "kv_heads", "kv_head_dim")
        cache = AttnCache(k=kc, v=vc, pos=kv_positions[0].astype(jnp.int32))
    return out, cache


def attn_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Params,
    x: jax.Array,           # (B, 1, D)
    idx: jax.Array,          # scalar int32: absolute position being generated
    cache: AttnCache,
    rules: ShardingRules,
    *,
    is_cross: bool = False,
) -> Tuple[jax.Array, AttnCache]:
    """Single-token decode with ring-buffer KV cache (windowed layers).

    ``is_cross`` marks this call as the cross-attention sub-block (static
    cache, no causal mask) — distinct from ``spec.cross_attn`` which merely
    says the layer *has* such a sub-block.
    """
    b = x.shape[0]
    dtype = x.dtype
    positions = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    if is_cross:
        # Static cross-attention cache: no update, attend over all frames.
        q = (x @ p["wq"].astype(dtype)).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        q = shard(q, rules, "batch", "seq", "heads", "head_dim")
        out = _attend(cfg, q, cache.k, cache.v, None, rules, cache_sharded=True)
        out = out @ p["wo"].astype(dtype)
        return shard(out, rules, "batch", "seq", "d_model"), cache

    q = (x @ p["wq"].astype(dtype)).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k_new = (x @ p["wk"].astype(dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v_new = (x @ p["wv"].astype(dtype)).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    if not cfg.is_encdec:  # enc-dec (whisper) uses sinusoidal-only positions
        q = rope(q, positions, spec.rope_theta)
        k_new = rope(k_new, positions, spec.rope_theta)
    # The new KV slice MUST match the cache layout before the in-place
    # update: an unconstrained (model-sharded) slice makes GSPMD reshard
    # the ENTIRE 32k-token cache every step (measured 3.2 GB/step f32
    # gathers at gemma3 decode_32k = 75% of the step's collectives).
    k_new = shard(k_new, rules, "batch", None, "kv_heads", "kv_head_dim")
    v_new = shard(v_new, rules, "batch", None, "kv_heads", "kv_head_dim")
    if rules.attn_unconstrained:
        # decode: align q's head_dim with the cache's kv_head_dim sharding
        # so the score contraction is local per dh-shard + a tiny psum —
        # the cache reads then split 16-way across the model axis instead
        # of being replicated (memory term 33.7 -> ~4 ms/token) or
        # re-gathered (2.15 GB/step).  EXPERIMENTS.md §Perf hillclimb C.
        q = shard(q, rules, "batch", None, None, "kv_head_dim")
    else:
        q = shard(q, rules, "batch", "seq", "heads", "head_dim")

    cache_len = cache.k.shape[1]
    slot = (idx % cache_len).astype(jnp.int32)
    k = lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, 1)
    v = lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, 1)
    pos = lax.dynamic_update_slice_in_dim(
        cache.pos, idx[None].astype(jnp.int32), slot, 0
    )
    if not rules.attn_unconstrained:
        k = shard(k, rules, "batch", "cache_seq", "kv_heads", "kv_head_dim")
        v = shard(v, rules, "batch", "cache_seq", "kv_heads", "kv_head_dim")

    valid = (pos >= 0) & (pos <= idx)
    if spec.window > 0:
        valid &= pos > idx - spec.window
    mask = valid[None, None, None, None, :]  # (1,1,1,1,L)
    out = _attend(cfg, q, k.astype(dtype), v.astype(dtype), mask, rules,
                  cache_sharded=True)
    out = out @ p["wo"].astype(dtype)
    out = shard(out, rules, "batch", "seq", "d_model")
    return out, AttnCache(k=k, v=v, pos=pos)


def init_attn_cache(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> AttnCache:
    length = min(spec.window, max_seq) if spec.window > 0 else max_seq
    return AttnCache(
        k=jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        pos=jnp.full((length,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# Dense FFN: SwiGLU / GeGLU / squared-ReLU / plain GELU
# ---------------------------------------------------------------------------


def ffn_init(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": _init_linear(ks[0], (d, f)),
        "w2": _init_linear(ks[1], (f, d)),
    }
    if cfg.act in ("silu", "gelu"):
        p["w3"] = _init_linear(ks[2], (d, f))
    return p


def ffn_forward(cfg: ModelConfig, p: Params, x: jax.Array, rules) -> jax.Array:
    dtype = x.dtype
    h = x @ p["w1"].astype(dtype)
    h = shard(h, rules, "batch", None, "mlp")
    if cfg.act == "silu":
        h = jax.nn.silu(h) * (x @ p["w3"].astype(dtype))
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h) * (x @ p["w3"].astype(dtype))
    elif cfg.act == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    elif cfg.act == "gelu_plain":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(cfg.act)
    h = shard(h, rules, "batch", None, "mlp")
    out = h @ p["w2"].astype(dtype)
    return shard(out, rules, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(cfg: ModelConfig, key: jax.Array) -> Params:
    vp, d = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"embed": jax.random.normal(ks[0], (vp, d), jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(ks[1], (d, vp), jnp.float32) * 0.02
    return p


def embed_tokens(cfg, p, tokens, rules) -> jax.Array:
    emb = p["embed"].astype(jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32)
    x = jnp.take(emb, tokens, axis=0)
    return shard(x, rules, "batch", "seq", "d_model")


def unembed(cfg, p, x, rules) -> jax.Array:
    dtype = x.dtype
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = x @ w.astype(dtype)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    # seq=None: logits live inside the chunked loss (seq is a chunk there),
    # and under SP 'seq' maps to the same axis as 'vocab'.
    return shard(logits, rules, "batch", None, "vocab")
