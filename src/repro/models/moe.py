"""Top-k MoE with sort-based dispatch, expert-parallel over the model axis.

TPU adaptation notes (DESIGN.md §Arch): GShard's dense one-hot dispatch
einsum is O(T·E·C·D) — prohibitive.  We dispatch with a per-batch-row
argsort: the sort axis (S·k) is unsharded, so under GSPMD every device sorts
its local rows with **zero collectives**.  Expert weights and the dispatch
buffer shard over 'model' (EP == TP on the expert axis); the combine gather
re-shards expert outputs back to token order (an all-gather of cf·k× the
activation bytes over 'model' — visible in the collective roofline and a
§Perf hillclimb lever).

Tokens beyond an expert's capacity C = cf·S·k/E are dropped (standard
GShard semantics); the router carries a switch-style load-balance aux loss.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding import ShardingRules, shard

Params = Dict[str, Any]


def moe_init(cfg: ModelConfig, key: jax.Array) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale,
        "w1": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "w2": jax.random.normal(ks[2], (e, f, d), jnp.float32) / np.sqrt(f),
    }
    if cfg.act in ("silu", "gelu"):
        p["w3"] = jax.random.normal(ks[3], (e, d, f), jnp.float32) * scale
    return p


def expert_capacity(cfg: ModelConfig, seq: int) -> int:
    c = int(np.ceil(cfg.capacity_factor * seq * cfg.topk_experts / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # sublane-align


def moe_forward_ep(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                 # (B, S, D)
    rules: ShardingRules,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via shard_map: zero-collective dispatch + masked
    local combine + ONE psum(B,S,D) per layer.

    The GSPMD combine gathers the (B, E·C, D) expert-output buffer over
    'model' — cf·k ≈ 10-20× the activation bytes (granite-moe train_4k:
    85 s collective term).  Per-rank control makes each model rank gather
    only from its LOCAL experts and contribute a partial sum; the psum
    moves exactly activation-sized bytes, like a dense TP FFN.
    """
    e, k = cfg.n_experts, cfg.topk_experts
    mesh = rules.mesh
    msize = mesh.shape["model"]
    e_loc = e // msize
    cap = expert_capacity(cfg, x.shape[1])
    fsdp = rules.fsdp

    def body(xl, router, w1, w2, w3):
        # xl (B_l, S, D) — identical across model ranks; w* (E_loc, ...)
        if fsdp is not None:
            # w1/w3 are (E,D,F) sharded on D (axis 1); w2 is (E,F,D)
            # sharded on D (axis 2).
            w1 = lax.all_gather(w1, fsdp, axis=1, tiled=True)
            w2 = lax.all_gather(w2, fsdp, axis=2, tiled=True)
            if w3 is not None:
                w3 = lax.all_gather(w3, fsdp, axis=1, tiled=True)
        b, s, d = xl.shape
        t = s * k
        dtype = xl.dtype
        rank = lax.axis_index("model")
        e0 = rank * e_loc

        logits = (xl @ router.astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=(0, 1))
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32),
                              axis=2), axis=(0, 1)) / k
        aux = e * jnp.sum(me * ce)

        flat_e = eidx.reshape(b, t)
        sort_i = jnp.argsort(flat_e, axis=1)
        sorted_e = jnp.take_along_axis(flat_e, sort_i, axis=1)
        counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1)
        starts = jnp.cumsum(counts, axis=1) - counts
        pos_in_e = (jnp.arange(t, dtype=jnp.int32)[None, :]
                    - jnp.take_along_axis(starts, sorted_e, axis=1))
        keep = pos_in_e < cap
        slot_sorted = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
        slot = jnp.zeros((b, t), jnp.int32).at[
            jnp.arange(b, dtype=jnp.int32)[:, None], sort_i
        ].set(slot_sorted)

        # local expert range [e0·cap, (e0+e_loc)·cap)
        slot_loc = slot - e0 * cap
        in_range = (slot_loc >= 0) & (slot_loc < e_loc * cap)
        slot_loc = jnp.where(in_range, slot_loc, e_loc * cap)

        tok_of_flat = jnp.arange(t, dtype=jnp.int32) // k
        xk = jnp.take(xl, tok_of_flat, axis=1)                     # (B,T,D)
        buf = jnp.zeros((b, e_loc * cap + 1, d), dtype)
        buf = buf.at[jnp.arange(b, dtype=jnp.int32)[:, None], slot_loc].set(
            jnp.where(in_range[:, :, None], xk, 0))
        buf = buf[:, : e_loc * cap].reshape(b, e_loc, cap, d)

        h = jnp.einsum("becd,edf->becf", buf, w1.astype(dtype))
        if cfg.act == "silu":
            h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, w3.astype(dtype))
        elif cfg.act == "gelu":
            h = jax.nn.gelu(h) * jnp.einsum("becd,edf->becf", buf, w3.astype(dtype))
        elif cfg.act == "relu2":
            r = jax.nn.relu(h)
            h = r * r
        else:
            raise ValueError(cfg.act)
        y = jnp.einsum("becf,efd->becd", h, w2.astype(dtype))

        y_flat = jnp.concatenate(
            [y.reshape(b, e_loc * cap, d), jnp.zeros((b, 1, d), dtype)], axis=1)
        gath = jnp.take_along_axis(y_flat, slot_loc[:, :, None], axis=1)
        gath = gath.reshape(b, s, k, d)
        partial = jnp.sum(gath * gate[..., None].astype(dtype), axis=2)
        out = lax.psum(partial, "model")
        return out, aux

    w3 = p.get("w3")
    wspec = P("model", fsdp, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(rules.batch, None, None), P(None, None), wspec,
                  P("model", None, fsdp), (wspec if w3 is not None else P())),
        out_specs=(P(rules.batch, None, None), P()),
        check_rep=False,
    )
    out, aux = fn(x, p["router"],
                  p["w1"], p["w2"], w3 if w3 is not None else jnp.zeros(()))
    return out, aux


def moe_forward(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                 # (B, S, D)
    rules: ShardingRules,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar)."""
    if (rules.mesh is not None and rules.experts == "model"
            and cfg.n_experts % rules.mesh.shape["model"] == 0):
        return moe_forward_ep(cfg, p, x, rules)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.topk_experts
    t = s * k
    cap = expert_capacity(cfg, s)
    dtype = x.dtype

    logits = (x @ p["router"].astype(dtype)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                          # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * p_e  (f = token fraction, p = prob mass)
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / k
    aux = e * jnp.sum(me * ce)

    # --- dispatch: per-row sort by expert id (local under batch sharding) ---
    flat_e = eidx.reshape(b, t)
    sort_i = jnp.argsort(flat_e, axis=1)                          # (B,T)
    sorted_e = jnp.take_along_axis(flat_e, sort_i, axis=1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1)  # (B,E)
    starts = jnp.cumsum(counts, axis=1) - counts                  # exclusive
    pos_in_e = (
        jnp.arange(t, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=1)
    )
    keep = pos_in_e < cap
    slot_sorted = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop -> sink
    # unsort: slot per (token, k)
    slot = jnp.zeros((b, t), jnp.int32).at[
        jnp.arange(b, dtype=jnp.int32)[:, None], sort_i
    ].set(slot_sorted)

    tok_of_flat = jnp.arange(t, dtype=jnp.int32)[None, :] // k      # (1,T)
    xk = jnp.take(x, tok_of_flat[0], axis=1)                        # (B,T,D)

    buf = jnp.zeros((b, e * cap + 1, d), dtype)
    buf = buf.at[jnp.arange(b, dtype=jnp.int32)[:, None], slot].set(xk)
    buf = buf[:, : e * cap, :].reshape(b, e, cap, d)
    buf = shard(buf, rules, "batch", "experts", "capacity", "d_model")

    # --- expert FFN (experts sharded over 'model') ---
    # (B,E,C,F): EP shards the expert axis; when E doesn't divide the
    # model axis (mixtral 8e/16) rules.experts is None and F carries the
    # model axis instead (intra-expert TP) — never both on one tensor.
    h = jnp.einsum("becd,edf->becf", buf, p["w1"].astype(dtype))
    h = shard(h, rules, "batch", "experts", "capacity",
              None if rules.experts else "mlp")
    if cfg.act == "silu":
        h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(dtype))
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h) * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(dtype))
    elif cfg.act == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    else:
        raise ValueError(cfg.act)
    y = jnp.einsum("becf,efd->becd", h, p["w2"].astype(dtype))
    y = shard(y, rules, "batch", "experts", "capacity", "d_model")

    # --- combine: gather each token's k expert outputs, weighted sum ---
    y_flat = y.reshape(b, e * cap, d)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((b, 1, d), dtype)], axis=1)
    gath = jnp.take_along_axis(y_flat, slot[:, :, None], axis=1)    # (B,T,D)
    gath = gath.reshape(b, s, k, d)
    out = jnp.sum(gath * gate[..., None].astype(dtype), axis=2)
    return shard(out, rules, "batch", "seq", "d_model"), aux
