"""Model configuration: one dataclass covers all 10 assigned architectures.

Heterogeneous stacks (gemma3's 5 local:1 global, jamba's 1 attn:7 mamba with
alternating MoE) are expressed as a repeating **layer pattern**: a tuple of
``LayerSpec`` of length p.  The model scans ``n_layers // p`` pattern blocks
(one ``lax.scan`` with a statically-specialized p-layer body — small HLO even
for 96-layer stacks) and unrolls the ``n_layers % p`` remainder.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Mixer kinds
ATTN = "attn"
MAMBA = "mamba"
# FFN kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's shape within the repeating pattern."""

    mixer: str = ATTN            # "attn" | "mamba"
    ffn: str = DENSE             # "dense" | "moe" | "none"
    window: int = 0              # 0 = full attention; >0 = local/SWA window
    rope_theta: float = 10_000.0
    cross_attn: bool = False     # decoder layers attending to encoder output


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # --- MoE ---
    n_experts: int = 0
    topk_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # --- misc ---
    act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU) | relu2 (squared ReLU)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500       # stub audio frontend frames

    # --- VLM (llava) ---
    n_patches: int = 0           # stub vision frontend patch count
    patch_dim: int = 1024        # raw patch-embedding dim before projection

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- training-time knobs (overridable per run) ---
    remat_policy: str = "minimal"  # none | minimal | full
    scan_blocks: bool = True

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so the 'vocab' axis shards evenly at TP=16."""
        return -(-self.vocab_size // 256) * 256

    @property
    def pattern_len(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def tail_specs(self) -> Tuple[LayerSpec, ...]:
        return self.pattern[: self.n_layers % self.pattern_len]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stacks); used for 6ND."""
        d = self.d_model
        total = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        specs = list(self.pattern) * self.n_blocks + list(self.tail_specs)
        for s in specs:
            total += self.layer_params(s)
        if self.is_encdec:
            enc = LayerSpec(mixer=ATTN, ffn=DENSE)
            total += self.n_enc_layers * self.layer_params(enc)
        if self.n_patches:
            total += self.patch_dim * d
        total += d  # final norm
        return total

    def layer_params(self, s: LayerSpec) -> int:
        d = self.d_model
        n = 0
        if s.mixer == ATTN:
            n += d * self.n_heads * self.head_dim  # wq
            n += 2 * d * self.n_kv_heads * self.head_dim  # wk, wv
            n += self.n_heads * self.head_dim * d  # wo
            n += d  # norm
            if s.cross_attn:
                n += d * self.n_heads * self.head_dim
                n += 2 * d * self.n_kv_heads * self.head_dim
                n += self.n_heads * self.head_dim * d
                n += d
        elif s.mixer == MAMBA:
            di, ns, hs = self.d_inner, self.ssm_state, self.ssm_heads
            n += d * (2 * di + 2 * ns + hs)  # in_proj (z, x, B, C, dt)
            n += self.ssm_conv_width * (di + 2 * ns)  # depthwise conv
            n += 2 * hs  # A_log, D
            n += di * d  # out_proj
            n += d + di  # pre-norm + gated rmsnorm
        if s.ffn == DENSE:
            mult = 3 if self.act in ("silu", "gelu") else 2
            n += mult * d * self.d_ff + d
        elif s.ffn == MOE:
            mult = 3 if self.act in ("silu", "gelu") else 2
            n += self.n_experts * mult * d * self.d_ff
            n += d * self.n_experts  # router
            n += d
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k of E experts) for 6·N_active·D."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.act in ("silu", "gelu") else 2
        per_expert = mult * d * self.d_ff
        total = self.param_count()
        specs = list(self.pattern) * self.n_blocks + list(self.tail_specs)
        n_moe = sum(1 for s in specs if s.ffn == MOE)
        total -= n_moe * (self.n_experts - self.topk_experts) * per_expert
        return total
