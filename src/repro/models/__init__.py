"""Model zoo: decoder/enc-dec transformer configs + forward/decode paths."""
