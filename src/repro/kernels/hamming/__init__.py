from repro.kernels.hamming.ops import hamming_matrix, hamming_rows  # noqa: F401
from repro.kernels.hamming.ref import hamming_matrix_ref  # noqa: F401
