"""Pure-jnp oracle for the Hamming kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def hamming_matrix_ref(queries: jax.Array, candidates: jax.Array) -> jax.Array:
    """(Q, W) × (C, W) packed uint32 -> (Q, C) int32 Hamming distances."""
    x = jnp.bitwise_xor(queries[:, None, :], candidates[None, :, :])
    return jnp.sum(lax.population_count(x).astype(jnp.int32), axis=-1)
