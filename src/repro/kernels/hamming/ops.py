"""Jit'd public wrapper: pads to tile multiples, dispatches kernel/oracle.

On this container (CPU) the Pallas kernel runs in interpret mode, which is
Python-slow; the default path on CPU is therefore the jnp oracle, with
``use_kernel=True`` (interpret) reserved for correctness tests.  On TPU the
kernel path is the default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.hamming.kernel import (BC, BQ, hamming_matrix_kernel,
                                          hamming_rows_kernel)
from repro.kernels.hamming.ref import hamming_matrix_ref


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def hamming_matrix(
    queries: jax.Array,
    candidates: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Batched Hamming distances between packed uint32 sketch matrices.

    Args:
      queries: (Q, W) uint32.
      candidates: (C, W) uint32.
      use_kernel: route through the Pallas kernel (TPU target; interpret on
        CPU) instead of the jnp oracle.

    Returns:
      (Q, C) int32.
    """
    if not use_kernel:
        return hamming_matrix_ref(queries, candidates)
    qn, cn = queries.shape[0], candidates.shape[0]
    qp = _pad_to(queries, BQ, 0)
    cp = _pad_to(candidates, BC, 0)
    out = hamming_matrix_kernel(qp, cp, interpret=interpret)
    return out[:qn, :cn]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def hamming_rows(
    queries: jax.Array,
    candidates: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """(Q, W) vs per-query (Q, K, W) packed sketches -> (Q, K) int32."""
    if not use_kernel:
        import jax.numpy as _jnp
        from jax import lax as _lax

        x = _jnp.bitwise_xor(queries[:, None, :], candidates)
        return _jnp.sum(_lax.population_count(x).astype(_jnp.int32), axis=-1)
    qn = queries.shape[0]
    qp = _pad_to(queries, BQ, 0)
    cp = _pad_to(candidates, BQ, 0)
    out = hamming_rows_kernel(qp, cp, interpret=interpret)
    return out[:qn]
