"""Pallas TPU kernel: batched Hamming distance over packed binary sketches.

Stage-2 of the paper's Task-1 pipeline is a (Q queries × C candidates)
Hamming-distance filter over 384-bit sketches (12 uint32 words).  At
challenge scale this touches 23M × 48 B = 1.1 GB of sketch data per query
batch — memory-bound, so the kernel's job is to stream sketch tiles through
VMEM once while every query tile in VMEM is scored against them.

Tiling: grid (Q/BQ, C/BC); per step the kernel holds a (BQ, W) query tile
and a (BC, W) candidate tile in VMEM and emits a (BQ, BC) int32 tile.  The
XOR+popcount runs on the VPU; popcount is SWAR bit-twiddling (portable to
interpret mode and Mosaic alike).  W (words per sketch) stays un-tiled: it
is ≤ 16 for every config we ship (512-bit sketches).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: (8, 128) is the fp32/int32 minimum tile; 128×128
# output tiles keep the VMEM working set at
#   BQ·W + BC·W + BQ·BC words  ≈  128·16·2·4B + 64KB ≈ 320 KB  « 16 MB VMEM.
BQ = 128
BC = 128


def _popcount32(v: jax.Array) -> jax.Array:
    """SWAR popcount of a uint32 vector (Hacker's Delight 5-2)."""
    v = v - ((v >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    v = (v + (v >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (v * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def _hamming_kernel(q_ref, c_ref, out_ref):
    q = q_ref[...]  # (BQ, W) uint32
    c = c_ref[...]  # (BC, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], c[None, :, :])  # (BQ, BC, W)
    out_ref[...] = jnp.sum(_popcount32(x), axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "bq", "bc"))
def hamming_matrix_kernel(
    queries: jax.Array,
    candidates: jax.Array,
    *,
    interpret: bool = False,
    bq: int = BQ,
    bc: int = BC,
) -> jax.Array:
    """(Q, W) × (C, W) packed uint32 sketches -> (Q, C) int32 Hamming.

    Q and C must be multiples of the tile sizes (ops.py pads).
    """
    qn, w = queries.shape
    cn, _ = candidates.shape
    grid = (qn // bq, cn // bc)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, cn), jnp.int32),
        interpret=interpret,
    )(queries, candidates)


def _hamming_rows_kernel(q_ref, c_ref, out_ref):
    q = q_ref[...]  # (BQ, W)
    c = c_ref[...]  # (BQ, K, W)
    x = jnp.bitwise_xor(q[:, None, :], c)
    out_ref[...] = jnp.sum(_popcount32(x), axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "bq"))
def hamming_rows_kernel(
    queries: jax.Array,      # (Q, W) uint32
    candidates: jax.Array,   # (Q, K, W) uint32 — per-query gathered sets
    *,
    interpret: bool = False,
    bq: int = BQ,
) -> jax.Array:
    """Row-wise Hamming: each query scored against ITS OWN K candidates —
    the exact stage-1 access pattern of Algorithm 1 (forest windows are
    per-query).  Q must be a multiple of bq (ops.py pads)."""
    qn, w = queries.shape
    k = candidates.shape[1]
    grid = (qn // bq,)
    return pl.pallas_call(
        _hamming_rows_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, w), lambda i: (i, 0)),
            pl.BlockSpec((bq, k, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qn, k), jnp.int32),
        interpret=interpret,
    )(queries, candidates)
