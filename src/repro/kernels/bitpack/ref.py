"""Pure-jnp oracle for the bitpack kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def pack_bits_ref(bits: jax.Array) -> jax.Array:
    """(N, K) {0,1} -> (N, K/32) uint32, bit 31 of word 0 = column 0."""
    n, k = bits.shape
    w = k // 32
    b3 = bits.reshape(n, w, 32).astype(jnp.uint32)
    shifts = (31 - jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(b3 << shifts[None, None, :], axis=-1, dtype=jnp.uint32)
