"""Jit'd wrapper: pads to tile multiples, dispatches kernel/oracle."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bitpack.kernel import BN, BW, pack_bits_kernel
from repro.kernels.bitpack.ref import pack_bits_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def pack_bits(
    bits: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Pack a (N, K) {0,1} matrix into (N, ceil(K/32)) uint32 (MSB-first)."""
    n, k = bits.shape
    kp = -(-k // (32 * BW)) * (32 * BW)
    np_ = -(-n // BN) * BN
    if not use_kernel:
        padded = jnp.pad(bits, ((0, 0), (0, kp - k)))
        return pack_bits_ref(padded)[:, : -(-k // 32)]
    padded = jnp.pad(bits, ((0, np_ - n), (0, kp - k)))
    out = pack_bits_kernel(padded, interpret=interpret)
    return out[:n, : -(-k // 32)]
