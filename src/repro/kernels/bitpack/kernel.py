"""Pallas TPU kernel: bit-plane packing for Hilbert keys / sketches.

Key generation ends by packing a (n, K) {0,1} bit matrix into (n, K/32)
uint32 words (MSB-first).  The jnp path materializes an (n, W, 32) uint32
intermediate (32× write amplification before the reduce); the kernel keeps
a (BN, 32·BW) bit tile in VMEM and emits the packed (BN, BW) tile directly
— pure VPU shifts+adds, HBM traffic = bits-in (1 B/bit as u8) + words-out.

Grid (n/BN, W/BW); weights the popcount/qdist kernels read downstream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256
BW = 4  # words per tile -> 128 bit-columns, one lane register


def _pack_kernel(bits_ref, out_ref):
    bits = bits_ref[...].astype(jnp.uint32)       # (BN, BW*32)
    bn, total = bits.shape
    w = total // 32
    b3 = bits.reshape(bn, w, 32)
    shifts = (31 - jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2))
    out_ref[...] = jnp.sum(b3 << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret", "bn", "bw"))
def pack_bits_kernel(
    bits: jax.Array,          # (N, K) uint8/bool in {0,1}; K % (32*bw) == 0
    *,
    interpret: bool = False,
    bn: int = BN,
    bw: int = BW,
) -> jax.Array:
    n, k = bits.shape
    w = k // 32
    grid = (n // bn, w // bw)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bw * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, w), jnp.uint32),
        interpret=interpret,
    )(bits.astype(jnp.uint8))
