from repro.kernels.bitpack.ops import pack_bits  # noqa: F401
from repro.kernels.bitpack.ref import pack_bits_ref  # noqa: F401
