"""Pure-jnp oracle for the qdist kernel (both code layouts)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def qdist_u8_ref(
    queries: jax.Array, codes: jax.Array, centroids: jax.Array
) -> jax.Array:
    """(Q, D) f32 × (C, D) uint8 × (D, L) centroids -> (Q, C) f32 squared L2."""
    recon = jnp.take_along_axis(
        centroids[None, :, :],
        codes[:, :, None].astype(jnp.int32),
        axis=2,
    )[:, :, 0]  # (C, D)
    diff = queries[:, None, :] - recon[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


@functools.partial(jax.jit, static_argnames=("d",))
def qdist_packed_ref(
    queries: jax.Array, packed: jax.Array, centroids: jax.Array, *, d: int
) -> jax.Array:
    """Packed-nibble variant of the oracle (unpacks, then qdist_u8_ref)."""
    shifts = jnp.arange(8, dtype=jnp.uint32) * 4
    codes = ((packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(0xF))
    codes = codes.reshape(packed.shape[0], -1)[:, :d].astype(jnp.uint8)
    return qdist_u8_ref(queries, codes, centroids)


@functools.partial(jax.jit, static_argnames=("d",))
def qdist_packed_windows_ref(
    queries: jax.Array, packed_windows: jax.Array, centroids: jax.Array, *, d: int
) -> jax.Array:
    """Per-query windows oracle: (Q, D) × (Q, C, W) packed -> (Q, C)."""
    return jax.vmap(
        lambda q, p: qdist_packed_ref(q[None], p, centroids, d=d)[0]
    )(queries, packed_windows)
