"""Pallas TPU kernel: fused 4-bit dequantization + asymmetric L2 distance.

Final-stage ranking (paper §3.1): fp32 queries against 4-bit-quantized
database vectors.  The CPU implementation gathers per-dim LUT entries —
serial scalar work.  The TPU formulation reconstructs the candidate tile
with 16 vectorized selects (one per code level; no gathers) and computes

    d²(q, r) = ‖q‖² − 2·q·rᵀ + ‖r‖²

with the cross term on the MXU — this is the deliberate CPU→TPU algorithm
change recorded in DESIGN.md §2.

Three variants:
  * ``qdist_u8_kernel``    — codes arrive as (C, d) uint8 (VMEM feed 1 B/dim).
  * ``qdist_packed_kernel``— codes arrive nibble-packed (C, d//8) uint32
    (VMEM/HBM feed 0.5 B/dim — the memory-roofline winner at 23M
    candidates).  Dims are processed in nibble-extraction order
    (j = 8·w + s scanned s-major), so queries/centroids must be permuted by
    ``packed_dim_order`` first; distance is order-invariant so the result
    is identical.  The cross term becomes 8 accumulated (BQ,W)@(W,BC)
    matmuls.
  * ``qdist_packed_windows_kernel`` — the stage-2 serving shape: every query
    brings its OWN candidate set (Q, C, d//8) uint32 (the ±h master-order
    windows gathered by the fused search path), so the grid walks one query
    row per program and the cross term is a (1,W)@(W,BC) row-matmul per
    nibble.  Same packed feed, same permuted dim order.

Tiling: grid (Q/BQ, C/BC); VMEM per step ≈ BQ·d·4 + BC·d (+ recon BC·d·4)
+ BQ·BC·4 ≈ 0.6 MB at (128, 128, d=384) — well inside 16 MB VMEM, sized so
the MXU K-dim (=d) is a multiple of 128 after ops.py padding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BQ = 128
BC = 128


def _reconstruct(codes_i32: jax.Array, cents: jax.Array, levels: int) -> jax.Array:
    """Dequantize (BC, D) int32 codes against (D, L) centroids, no gathers."""
    recon = jnp.zeros(codes_i32.shape, jnp.float32)
    for l in range(levels):
        recon = jnp.where(codes_i32 == l, cents[None, :, l], recon)
    return recon


def _qdist_u8_kernel(q_ref, c_ref, cent_ref, out_ref, *, levels: int):
    q = q_ref[...]                      # (BQ, D) f32
    codes = c_ref[...].astype(jnp.int32)  # (BC, D)
    cents = cent_ref[...]               # (D, L) f32
    recon = _reconstruct(codes, cents, levels)
    cross = jax.lax.dot_general(
        q, recon, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BQ, BC)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)         # (BQ, 1)
    rsq = jnp.sum(recon * recon, axis=1, keepdims=True)  # (BC, 1)
    out_ref[...] = qsq - 2.0 * cross + rsq.T


def _qdist_packed_kernel(q_ref, c_ref, cent_ref, out_ref, *, levels: int):
    q = q_ref[...]                       # (BQ, 8W) f32, permuted dim order
    packed = c_ref[...]                  # (BC, W) uint32
    cents = cent_ref[...]                # (8W, L) f32, permuted dim order
    w = packed.shape[1]
    acc = jnp.zeros((q.shape[0], packed.shape[0]), jnp.float32)
    rsq = jnp.zeros((packed.shape[0], 1), jnp.float32)
    for s in range(8):
        nib = ((packed >> jnp.uint32(4 * s)) & jnp.uint32(0xF)).astype(jnp.int32)
        cent_s = jax.lax.dynamic_slice_in_dim(cents, s * w, w, axis=0)  # (W, L)
        recon = _reconstruct(nib, cent_s, levels)  # (BC, W)
        q_s = jax.lax.dynamic_slice_in_dim(q, s * w, w, axis=1)  # (BQ, W)
        acc += jax.lax.dot_general(
            q_s, recon, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        rsq += jnp.sum(recon * recon, axis=1, keepdims=True)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)
    out_ref[...] = qsq - 2.0 * acc + rsq.T


def _qdist_packed_windows_kernel(q_ref, c_ref, cent_ref, out_ref, *, levels: int):
    q = q_ref[...]                       # (1, 8W) f32, permuted dim order
    packed = c_ref[...][0]               # (1, BC, W) uint32 -> (BC, W)
    cents = cent_ref[...]                # (8W, L) f32, permuted dim order
    w = packed.shape[1]
    acc = jnp.zeros((1, packed.shape[0]), jnp.float32)
    rsq = jnp.zeros((packed.shape[0], 1), jnp.float32)
    for s in range(8):
        nib = ((packed >> jnp.uint32(4 * s)) & jnp.uint32(0xF)).astype(jnp.int32)
        cent_s = jax.lax.dynamic_slice_in_dim(cents, s * w, w, axis=0)  # (W, L)
        recon = _reconstruct(nib, cent_s, levels)  # (BC, W)
        q_s = jax.lax.dynamic_slice_in_dim(q, s * w, w, axis=1)  # (1, W)
        acc += jax.lax.dot_general(
            q_s, recon, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        rsq += jnp.sum(recon * recon, axis=1, keepdims=True)
    qsq = jnp.sum(q * q, axis=1, keepdims=True)
    out_ref[...] = qsq - 2.0 * acc + rsq.T


def packed_dim_order(d: int) -> np.ndarray:
    """Dim permutation matching nibble-extraction order (s-major, w-minor).

    ``pack_codes`` puts original dim j = 8·w + s into nibble s of word w;
    the packed kernel scans s = 0..7 emitting all words per s, i.e. column
    j' = s·W + w corresponds to original dim 8·w + s.
    """
    w = d // 8
    s, ww = np.divmod(np.arange(d), w)
    return (8 * ww + s).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("levels", "interpret", "bq", "bc"))
def qdist_u8_kernel(
    queries: jax.Array,
    codes: jax.Array,
    centroids: jax.Array,
    *,
    levels: int = 16,
    interpret: bool = False,
    bq: int = BQ,
    bc: int = BC,
) -> jax.Array:
    """(Q, D) f32 × (C, D) uint8 codes × (D, L) centroids -> (Q, C) f32 d²."""
    qn, d = queries.shape
    cn = codes.shape[0]
    grid = (qn // bq, cn // bc)
    return pl.pallas_call(
        functools.partial(_qdist_u8_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d, levels), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, cn), jnp.float32),
        interpret=interpret,
    )(queries, codes, centroids)


@functools.partial(jax.jit, static_argnames=("levels", "interpret", "bq", "bc"))
def qdist_packed_kernel(
    queries_perm: jax.Array,
    packed: jax.Array,
    centroids_perm: jax.Array,
    *,
    levels: int = 16,
    interpret: bool = False,
    bq: int = BQ,
    bc: int = BC,
) -> jax.Array:
    """Packed variant; queries/centroids pre-permuted by packed_dim_order."""
    qn, d = queries_perm.shape
    cn, w = packed.shape
    assert d == 8 * w, (d, w)
    grid = (qn // bq, cn // bc)
    return pl.pallas_call(
        functools.partial(_qdist_packed_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, w), lambda i, j: (j, 0)),
            pl.BlockSpec((d, levels), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, cn), jnp.float32),
        interpret=interpret,
    )(queries_perm, packed, centroids_perm)


@functools.partial(jax.jit, static_argnames=("levels", "interpret", "bc"))
def qdist_packed_windows_kernel(
    queries_perm: jax.Array,
    packed_windows: jax.Array,
    centroids_perm: jax.Array,
    *,
    levels: int = 16,
    interpret: bool = False,
    bc: int = BC,
) -> jax.Array:
    """Per-query candidate windows: (Q, 8W) f32 × (Q, C, W) uint32 -> (Q, C).

    Grid walks (query, candidate-tile); queries/centroids pre-permuted by
    ``packed_dim_order`` like :func:`qdist_packed_kernel`.
    """
    qn, d = queries_perm.shape
    _, cn, w = packed_windows.shape
    assert d == 8 * w, (d, w)
    grid = (qn, cn // bc)
    return pl.pallas_call(
        functools.partial(_qdist_packed_windows_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bc, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, levels), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, cn), jnp.float32),
        interpret=interpret,
    )(queries_perm, packed_windows, centroids_perm)
