from repro.kernels.qdist.ops import (  # noqa: F401
    qdist,
    qdist_from_packed,
    qdist_windows_from_packed,
)
from repro.kernels.qdist.ref import (  # noqa: F401
    qdist_packed_ref,
    qdist_packed_windows_ref,
    qdist_u8_ref,
)
