from repro.kernels.qdist.ops import qdist, qdist_from_packed  # noqa: F401
from repro.kernels.qdist.ref import qdist_packed_ref, qdist_u8_ref  # noqa: F401
