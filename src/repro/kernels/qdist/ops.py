"""Jit'd public wrappers for the qdist kernels: pad, permute, dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qdist.kernel import (
    BC,
    BQ,
    packed_dim_order,
    qdist_packed_kernel,
    qdist_packed_windows_kernel,
    qdist_u8_kernel,
)
from repro.kernels.qdist.ref import (
    qdist_packed_ref,
    qdist_packed_windows_ref,
    qdist_u8_ref,
)


def _pad_axis(x: jax.Array, m: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def qdist(
    queries: jax.Array,
    codes: jax.Array,
    centroids: jax.Array,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Asymmetric squared-L2: fp32 queries vs uint8-coded database rows.

    Args:
      queries: (Q, D) float32.
      codes: (C, D) uint8 in [0, L).
      centroids: (D, L) float32 per-dim reconstruction table.

    Returns: (Q, C) float32 squared distances.
    """
    if not use_kernel:
        return qdist_u8_ref(queries, codes, centroids)
    qn, d = queries.shape
    cn = codes.shape[0]
    # Pad D to a lane multiple with zero query/centroid columns (code 0 then
    # reconstructs to 0.0 — zero contribution to the distance).
    dp = -(-d // 128) * 128
    q = jnp.pad(queries, ((0, (-qn) % BQ), (0, dp - d)))
    c = jnp.pad(codes, ((0, (-cn) % BC), (0, dp - d)))
    cent = jnp.pad(centroids, ((0, dp - d), (0, 0)))
    out = qdist_u8_kernel(q, c, cent, levels=centroids.shape[1], interpret=interpret)
    return out[:qn, :cn]


@functools.partial(jax.jit, static_argnames=("d", "use_kernel", "interpret"))
def qdist_from_packed(
    queries: jax.Array,
    packed: jax.Array,
    centroids: jax.Array,
    *,
    d: int,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Packed-nibble codes variant — 0.5 B/dim HBM traffic on TPU.

    Args:
      queries: (Q, D) float32.
      packed: (C, ceil(D/8)) uint32, nibble-packed 4-bit codes.
      centroids: (D, 16) float32.
      d: original dimensionality.
    """
    if not use_kernel:
        return qdist_packed_ref(queries, packed, centroids, d=d)
    qn = queries.shape[0]
    cn, w = packed.shape
    # Pad packed width so 8·W is a lane multiple; nibble 0 + zero centroid
    # columns contribute nothing.
    wp = -(-w // 16) * 16
    dp = 8 * wp
    q = jnp.pad(queries, ((0, (-qn) % BQ), (0, dp - d)))
    p = jnp.pad(packed, ((0, (-cn) % BC), (0, wp - w)))
    cent = jnp.pad(centroids, ((0, dp - d), (0, 0)))
    order = jnp.asarray(packed_dim_order(dp))
    out = qdist_packed_kernel(
        q[:, order], p, cent[order], levels=centroids.shape[1], interpret=interpret
    )
    return out[:qn, :cn]


@functools.partial(jax.jit, static_argnames=("d", "use_kernel", "interpret"))
def qdist_windows_from_packed(
    queries: jax.Array,
    packed_windows: jax.Array,
    centroids: jax.Array,
    *,
    d: int,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Per-query packed candidate sets — the fused stage-2 serving shape.

    Args:
      queries: (Q, D) float32.
      packed_windows: (Q, C, ceil(D/8)) uint32 — each query's own candidate
        codes (the ±h master-order windows), nibble-packed.
      centroids: (D, 16) float32.
      d: original dimensionality.

    Returns: (Q, C) float32 squared distances.
    """
    if not use_kernel:
        return qdist_packed_windows_ref(queries, packed_windows, centroids, d=d)
    qn = queries.shape[0]
    _, cn, w = packed_windows.shape
    # Pad packed width so 8·W is a lane multiple; nibble 0 + zero centroid
    # columns contribute nothing.  Candidate tiles pad with all-zero rows
    # whose (finite) distances are sliced away below.
    wp = -(-w // 16) * 16
    dp = 8 * wp
    q = jnp.pad(queries, ((0, 0), (0, dp - d)))
    p = jnp.pad(packed_windows, ((0, 0), (0, (-cn) % BC), (0, wp - w)))
    cent = jnp.pad(centroids, ((0, dp - d), (0, 0)))
    order = jnp.asarray(packed_dim_order(dp))
    out = qdist_packed_windows_kernel(
        q[:, order], p, cent[order], levels=centroids.shape[1], interpret=interpret
    )
    return out[:, :cn]
