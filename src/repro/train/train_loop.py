"""Train step: microbatch gradient accumulation + AdamW, GSPMD-ready.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function.  Gradient accumulation runs as a ``lax.scan`` over microbatches
(bounding live activation memory — the lever that fits nemotron-4-340b
train_4k); accumulation dtype is configurable (bf16 accumulate = the DP
collective moves half the bytes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig, apply_updates, init_opt_state
from repro.sharding import ShardingRules

TrainState = Dict[str, Any]  # {"params", "opt", "step"}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    accum_dtype: str = "float32"   # "bfloat16" halves DP all-reduce bytes
    optimizer: OptimizerConfig = OptimizerConfig()


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> TrainState:
    params = model.init_params(cfg, key)
    return {
        "params": params,
        "opt": init_opt_state(params, tcfg.optimizer),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    return jax.eval_shape(
        functools.partial(init_train_state, cfg, tcfg), jax.random.key(0)
    )


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def r(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])

    return {k: r(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, rules: ShardingRules,
                    param_pspecs=None):
    adt = jnp.bfloat16 if tcfg.accum_dtype == "bfloat16" else jnp.float32

    def loss_fn(params, mb):
        # Pre-cast big weights to bf16 AND pin them with a sharding
        # constraint: the constraint is what stops GSPMD from hoisting the
        # FSDP all-gather above the convert (f32 wire traffic; XLA strips
        # bare optimization_barriers).  model._bf16_params then no-ops.
        if param_pspecs is not None:
            leaves, treedef = jax.tree_util.tree_flatten(params)
            cast = [
                jax.lax.with_sharding_constraint(
                    a.astype(jnp.bfloat16), s)
                if a.dtype == jnp.float32 and a.size > 1_000_000 else a
                for a, s in zip(leaves, _spec_leaves)
            ]
            params = jax.tree_util.tree_unflatten(treedef, cast)
        return model.train_loss(cfg, params, mb, rules)

    if param_pspecs is not None:
        from jax.sharding import PartitionSpec as _P

        _spec_leaves = jax.tree_util.tree_flatten(
            param_pspecs, is_leaf=lambda x: isinstance(x, _P))[0]

    def constrain(tree):
        # The accumulated gradients MUST carry the params' shardings: an
        # unconstrained scan carry lets GSPMD replicate gsum, all-gathering
        # every per-microbatch gradient in f32 (nemotron-4-340b train_4k:
        # 4.2 TB/device of f32 weight-shaped gathers — §Perf hillclimb B).
        if param_pspecs is None:
            return tree
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = [jax.lax.with_sharding_constraint(a, s)
               for a, s in zip(leaves, _spec_leaves)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        n = tcfg.n_microbatches
        if n == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain(grads)
        else:
            micro = _split_micro(batch, n)

            def body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(adt), gsum, g)
                return (constrain(gsum), lsum + l), None

            g0 = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params))
            (gsum, lsum), _ = lax.scan(body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / n).astype(jnp.float32), gsum)
            loss = lsum / n

        new_params, new_opt, metrics = apply_updates(
            params, grads, state["opt"], tcfg.optimizer
        )
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step
