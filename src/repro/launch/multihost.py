"""Multi-host cluster bootstrap (SLURM / GKE-TPU / manual).

One entrypoint per host process calls :func:`bootstrap` before any jax use;
it resolves the coordinator and host topology from the environment and
initializes ``jax.distributed`` so the SAME ``make_production_mesh()`` and
launch scripts run unchanged from 1 host to a 2-pod 512-chip job.

Environment resolution order (first match wins):
  1. explicit kwargs,
  2. SLURM (SLURM_PROCID / SLURM_NTASKS / SLURM_STEP_NODELIST),
  3. GKE/Cloud-TPU (TPU_WORKER_ID / TPU_WORKER_HOSTNAMES),
  4. single-host fallback (no-op init).

Data loading uses :func:`host_batch_slice`: the step-indexed pipeline lets
every host materialize exactly its rows of any global batch with zero
coordination (see repro/data/pipeline.py).
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class HostTopology:
    host_id: int
    n_hosts: int
    coordinator: str         # "host:port"
    source: str              # slurm | gke | manual | single


def resolve_topology(
    coordinator: Optional[str] = None,
    host_id: Optional[int] = None,
    n_hosts: Optional[int] = None,
    env: Optional[dict] = None,
) -> HostTopology:
    env = os.environ if env is None else env
    if coordinator is not None and host_id is not None and n_hosts is not None:
        return HostTopology(host_id, n_hosts, coordinator, "manual")

    if "SLURM_PROCID" in env:
        hid = int(env["SLURM_PROCID"])
        n = int(env.get("SLURM_NTASKS", "1"))
        nodelist = env.get("SLURM_STEP_NODELIST", env.get("SLURM_NODELIST", ""))
        head = _first_slurm_node(nodelist)
        port = env.get("REPRO_COORD_PORT", "12321")
        return HostTopology(hid, n, f"{head}:{port}", "slurm")

    if "TPU_WORKER_ID" in env:
        hid = int(env["TPU_WORKER_ID"])
        hosts = [h for h in env.get("TPU_WORKER_HOSTNAMES", "").split(",") if h]
        n = len(hosts) or int(env.get("TPU_WORKER_COUNT", "1"))
        head = hosts[0] if hosts else "localhost"
        port = env.get("REPRO_COORD_PORT", "8476")
        return HostTopology(hid, n, f"{head}:{port}", "gke")

    return HostTopology(0, 1, "localhost:0", "single")


def _first_slurm_node(nodelist: str) -> str:
    """'node[003-010,012],other' -> 'node003' (minimal SLURM range parser)."""
    if not nodelist:
        return "localhost"
    head = nodelist.split(",")[0]
    m = re.match(r"([^\[]+)\[(\d+)", head)
    if m:
        prefix, first = m.group(1), m.group(2)
        return f"{prefix}{first}"
    return head


def bootstrap(**kwargs) -> HostTopology:
    """Initialize jax.distributed per the resolved topology (no-op single)."""
    topo = resolve_topology(**kwargs)
    if topo.n_hosts > 1:
        import jax

        jax.distributed.initialize(
            coordinator_address=topo.coordinator,
            num_processes=topo.n_hosts,
            process_id=topo.host_id,
        )
    return topo


def host_batch_slice(global_batch: int, topo: HostTopology) -> Tuple[int, int]:
    """[start, stop) rows of the global batch owned by this host."""
    per = global_batch // topo.n_hosts
    return topo.host_id * per, (topo.host_id + 1) * per
