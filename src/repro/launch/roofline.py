"""Roofline aggregation: dry-run JSONs -> EXPERIMENTS.md §Roofline table.

Per (arch × shape), single-pod mesh: the three terms in seconds
(compute = FLOPs/(chips·197T), memory = bytes/(chips·819G),
collective = coll_bytes/(chips·50G) — all numerators are per-device, so the
chip count divides out), the dominant term, MODEL_FLOPS/HLO_FLOPS, and a
one-line "what would move the dominant term".

    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun
"""

import argparse
import glob
import json
import os
from typing import Dict

MOVE_HINTS = {
    "compute_term_s": "reduce redundant/padded compute (remat policy, head padding)",
    "memory_term_s": "cut activation traffic: fuse, larger microbatch locality, bf16 stores",
    "collective_term_s": "re-shard to kill resharding collectives / overlap with compute",
}


def load(dir_: str) -> Dict:
    recs = {}
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_row(r) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | "
                f"{r['reason'][:58]} |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | — "
                f"| see json |")
    c, m = r["compute_term_s"], r["memory_term_s"]
    k = r["collective_term_s"]
    kc = r.get("collective_term_corrected_s", k)
    dom = r["dominant_term"]
    ratio = r["useful_flops_ratio"]
    hint = MOVE_HINTS[dom]
    return (f"| {r['arch']} | {r['shape']} | {c:.3g} | {m:.3g} | {k:.3g} "
            f"| {kc:.3g} | {dom.split('_')[0]} | {ratio:.2f} | {hint[:48]} |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir)
    print("| arch | shape | compute s | memory s | coll s (raw) | coll s "
          "(bf16-corr) | dominant | useful-FLOP ratio | lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    shown = set()
    for (a, s, m), r in sorted(recs.items()):
        if m != args.mesh:
            continue
        print(fmt_row(r))
        shown.add((a, s))
    # multi-pod pass/fail summary
    n_ok = sum(1 for (a, s, m), r in recs.items()
               if m == "multi" and r["status"] == "ok")
    n_skip = sum(1 for (a, s, m), r in recs.items()
                 if m == "multi" and r["status"] == "skipped")
    n_err = sum(1 for (a, s, m), r in recs.items()
                if m == "multi" and r["status"] not in ("ok", "skipped"))
    print(f"\nmulti-pod (2×16×16): {n_ok} compiled ok, {n_skip} skipped "
          f"(inapplicable), {n_err} errors")


if __name__ == "__main__":
    main()
