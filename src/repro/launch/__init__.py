"""Entry-point drivers: train, serve, dry-run, multihost, roofline."""
