"""End-to-end training driver.

CPU smoke:   PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b \
                 --smoke --steps 60 --batch 8 --seq 64
Cluster:     same entrypoint; full configs + the production mesh activate
             with --mesh prod (the dry-run proves those lower; real devices
             execute them).

Fault tolerance: atomic checkpoints every --ckpt-every steps via the async
checkpointer; on start, the latest complete step is discovered and training
resumes from it (bit-exact: the data pipeline is step-indexed).  Straggler
mitigation: per-step wall times are monitored and slow steps logged with a
p50-relative factor (on multi-host deployments this feeds the controller's
restart policy; here it is observability).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules
from repro.train.train_loop import (
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "topk"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    rules = ShardingRules()
    tcfg = TrainConfig(
        n_microbatches=args.microbatches,
        optimizer=OptimizerConfig(
            lr=args.lr, warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps, compression=args.compression,
        ),
    )
    pipe = TokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, global_batch=args.batch,
        seq_len=args.seq, seed=args.seed,
    ))
    step_fn = jax.jit(make_train_step(cfg, tcfg, rules))

    start = 0
    if args.ckpt and (ls := latest_step(args.ckpt)) is not None:
        abstract = abstract_train_state(cfg, tcfg)
        state, _ = restore(args.ckpt, ls, abstract)
        start = ls
        print(f"[resume] restored step {ls} from {args.ckpt}")
    else:
        state = init_train_state(cfg, tcfg, jax.random.key(args.seed))
        print(f"[init] {cfg.name}: {cfg.param_count():,} params "
              f"({'smoke' if args.smoke else 'full'})")

    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None
    times = []
    for s in range(start, args.steps):
        t0 = time.time()
        state, m = step_fn(state, pipe.jax_batch(s))
        loss = float(m["loss"])
        dt = time.time() - t0
        times.append(dt)
        if len(times) > 5:
            p50 = float(np.median(times[3:]))
            if dt > 2.5 * p50:
                print(f"[straggler] step {s} took {dt:.2f}s ({dt/p50:.1f}x p50)")
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:5d}  loss {loss:.4f}  gnorm {float(m['grad_norm']):.3f} "
                  f" lr {float(m['lr']):.2e}  {dt:.2f}s")
        if ckpt and (s + 1) % args.ckpt_every == 0:
            ckpt.save(s + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
        print(f"[ckpt] final state at {ckpt.last_path}")
    print(f"[done] median step {np.median(times):.2f}s")


if __name__ == "__main__":
    main()
