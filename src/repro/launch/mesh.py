"""Production mesh construction (a FUNCTION — importing never touches jax
device state; jax locks the device count on first backend init)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(model_axis: int = 1):
    """Whatever this host has (tests / CPU smoke): (n_dev/model, model)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_mesh(n: int | None = None):
    """1-D ``('data',)`` mesh over ``n`` devices (default: all local).

    The ONE way row-partitioned index work builds its mesh — the sample
    sort in ``core/distributed.py``, the sharded facade in
    ``index/sharded.py``, and the distributed self-checks all call this
    instead of hand-rolling ``Mesh``/``make_mesh`` shapes, so the axis
    name and device order can never drift between build and serve.
    """
    devs = jax.devices()
    if n is None:
        n = len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(f"data_mesh(n={n}): host has {len(devs)} devices")
    return jax.make_mesh((n,), ("data",), devices=devs[:n])
