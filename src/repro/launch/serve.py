"""Batched serving driver: prefill + autoregressive decode (+ retrieval).

CPU smoke:  PYTHONPATH=src python -m repro.launch.serve --arch gemma3_1b \
                --smoke --batch 4 --prompt-len 24 --gen 16 [--retrieval]

The decode loop is the same ``decode_step`` the dry-run lowers for the
decode_32k/long_500k cells; --retrieval augments each step with a
Hilbert-forest kNN-LM lookup (the paper's index as a first-class serving
feature).  ``--shards N`` row-partitions the datastore over N devices of
the ``data`` mesh (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a CPU smoke):
lookups then go through the sharded index's mesh-wide merged top-k.
``--churn`` exercises the streaming write path mid-decode — every few
steps the datastore absorbs an append and a delete while serving, on
either layout (the sharded store routes appends to the shard owning each
key's curve range; no rebuild-and-swap).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.types import ForestConfig, SearchParams
from repro.index import IndexConfig
from repro.models import model
from repro.serve.engine import MaintenancePolicy
from repro.serve.retrieval import RetrievalStore, knn_lm_mix
from repro.sharding import ShardingRules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--shards", type=int, default=1,
                    help="row-partition the retrieval datastore over this "
                         "many devices (1 = single-device mutable store)")
    ap.add_argument("--churn", action="store_true",
                    help="append/delete datastore entries while decoding "
                         "(streaming writes on either layout)")
    ap.add_argument("--engine", action="store_true",
                    help="serve the datastore through the RetrievalEngine: "
                         "lookups go through the admission queue and "
                         "micro-batcher, and LSM maintenance (tier merges, "
                         "compaction) runs on a background thread with an "
                         "atomic index swap instead of stalling decode")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="attach a write-ahead log under PATH (the store's "
                         "checkpoint directory): appends/deletes are framed "
                         "+ logged before they are acknowledged, so a crash "
                         "at any instant recovers bit-equal.  See "
                         "docs/DURABILITY.md")
    ap.add_argument("--wal-sync-every", type=int, default=32,
                    help="fsync the WAL every N records (1 = every record "
                         "= full power-loss durability; the default group-"
                         "commits for <10%% append-path overhead)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="with --engine: per-request queue deadline — "
                         "requests still queued past it are failed with "
                         "DeadlineExceeded instead of dispatched")
    ap.add_argument("--lam", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus), /metrics.json and "
                         "/trace on this port (0 = ephemeral; the bound "
                         "port is printed).  See docs/OBSERVABILITY.md")
    ap.add_argument("--trace-export", default=None, metavar="PATH",
                    help="enable span tracing and write a Chrome-trace "
                         "JSON (chrome://tracing / Perfetto) on exit")
    ap.add_argument("--recall-probe", type=float, default=None,
                    metavar="FRACTION",
                    help="with --engine: sample this fraction of served "
                         "batches and score online recall@k against an "
                         "exact shadow off the query path")
    ap.add_argument("--linger", type=float, default=0.0, metavar="SECONDS",
                    help="keep the process (and --metrics-port endpoint) "
                         "alive this long after the workload finishes, so "
                         "an external scraper can read final counters")
    args = ap.parse_args()

    metrics_server = None
    if args.metrics_port is not None:
        from repro.obs.http import serve_metrics

        metrics_server = serve_metrics(args.metrics_port)
        print(f"[obs] metrics endpoint at {metrics_server.url}/metrics "
              f"(also /metrics.json, /trace)", flush=True)
    if args.trace_export:
        from repro import obs

        obs.enable()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    rules = ShardingRules()
    rng = np.random.default_rng(args.seed)
    params = model.init_params(cfg, jax.random.key(args.seed))

    b, sp = args.batch, args.prompt_len
    total = sp + args.gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, sp)), jnp.int32)
    extra = {}
    if cfg.is_encdec:
        extra["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_frames, cfg.d_model)), jnp.float32)
    if cfg.n_patches:
        extra["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.patch_dim)), jnp.float32)

    store = None
    if args.retrieval:
        # datastore: hidden states of a reference corpus through this model
        corpus = jnp.asarray(rng.integers(0, cfg.vocab_size, (16, 64)), jnp.int32)
        cextra = {}
        if cfg.is_encdec:
            cextra["frames"] = jnp.asarray(
                rng.normal(size=(16, cfg.enc_frames, cfg.d_model)), jnp.float32)
        if cfg.n_patches:
            cextra["patches"] = jnp.asarray(
                rng.normal(size=(16, cfg.n_patches, cfg.patch_dim)), jnp.float32)
        hid, _, _ = model.forward(cfg, params, corpus, rules,
                                  return_hidden=True, **cextra)
        keys = hid[:, :-1].reshape(-1, cfg.d_model).astype(jnp.float32)
        vals = corpus[:, 1:].reshape(-1)
        fc = ForestConfig(n_trees=8, bits=4, key_bits=min(256, cfg.d_model * 4),
                          leaf_size=32)
        mesh = None
        if args.shards > 1:
            from repro.launch.mesh import data_mesh

            mesh = data_mesh(args.shards)
        # Compaction re-sorts raw keys, so the churn demo keeps them
        # resident; otherwise store_points=False serves RAM-lean (appends
        # and deletes still work on both layouts).
        store_points = args.churn
        store = RetrievalStore.build(
            keys, vals, IndexConfig(forest=fc, store_points=store_points),
            mesh=mesh, shards=args.shards,
        )
        layout = (f"sharded-mutable x{args.shards}" if store.is_sharded
                  else "mutable (single device)")
        print(f"[retrieval] datastore: {keys.shape[0]} entries, {layout}")
        if args.wal:
            from repro.checkpoint import WalConfig

            store.enable_wal(
                args.wal, WalConfig(sync_every=args.wal_sync_every)
            )
            print(f"[wal] durable writes -> {args.wal}/wal.log "
                  f"(sync_every={args.wal_sync_every})")
        if args.engine:
            # Background maintenance only makes sense when segments keep
            # their raw points (store_points tracks --churn above).
            recall_cfg = None
            if args.recall_probe:
                from repro.obs.recall import RecallProbeConfig

                recall_cfg = RecallProbeConfig(
                    fraction=args.recall_probe, seed=args.seed
                )
            engine = store.serving_engine(
                SearchParams(k1=32, k2=64, h=1, k=8),
                maintenance=MaintenancePolicy() if store_points else None,
                recall=recall_cfg,
                default_deadline_ms=args.deadline_ms,
                start=True,
            )
            print(f"[engine] {engine!r}")

    t0 = time.time()
    logits, caches = model.prefill(cfg, params, prompts, rules, **extra)
    caches = model.pad_caches(cfg, caches, total)
    print(f"[prefill] {b}x{sp} in {time.time()-t0:.2f}s")

    decode = jax.jit(
        lambda p, t, i, c: model.decode_step(cfg, p, t, i, c, rules,
                                             with_hidden=True))
    sp_params = SearchParams(k1=32, k2=64, h=1, k=8)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    churned: list = []
    t0 = time.time()
    for t in range(sp, total):
        logits_t, caches, hid = decode(params, tok, jnp.int32(t), caches)
        if store is not None:
            logp = knn_lm_mix(logits_t.astype(jnp.float32),
                              hid.astype(jnp.float32), store, sp_params,
                              lam=args.lam)
            tok = jnp.argmax(logp, axis=-1)[:, None].astype(jnp.int32)
            if args.churn and (t - sp) % 4 == 0:
                # streaming writes while serving: the decoded (hidden ->
                # token) pairs join the datastore; the previous churn
                # batch is evicted (a rolling-window datastore)
                new_ids = store.append(hid.astype(jnp.float32), tok[:, 0])
                if churned:
                    store.delete(churned.pop())
                churned.append(new_ids)
        else:
            tok = jnp.argmax(logits_t, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    if store is not None and store.engine is not None:
        store.engine.stop(drain=True)
        snap = store.engine.metrics.snapshot()
        lat = snap["latency_ms"]
        print(f"[engine] {snap['counters']['batches']} batches / "
              f"{snap['counters']['rows_searched']} rows, "
              f"p50={lat.get('p50', 0):.1f}ms p99={lat.get('p99', 0):.1f}ms, "
              f"swaps={snap['counters']['swaps']} "
              f"(maintenance runs={snap['counters']['maintenance_runs']})")
    if store is not None and args.churn:
        rep = store.memory_report()
        print(f"[churn] live={rep['n_live']} deleted={rep['n_deleted']} "
              f"buffered={rep['n_buffered']} segments={rep['n_segments']}")
        store.compact()
        print(f"[churn] compacted -> segments={store.memory_report()['n_segments']}")
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[decode] {args.gen} steps x batch {b}: {1000*dt/args.gen:.0f} ms/step")
    print("[tokens]", gen[0][:16], "...")
    if args.trace_export:
        from repro import obs

        obs.default_tracer().dump(args.trace_export)
        print(f"[obs] wrote Chrome trace to {args.trace_export}", flush=True)
    if args.linger > 0:
        print(f"[obs] lingering {args.linger:.0f}s for scrapers", flush=True)
        time.sleep(args.linger)
    if metrics_server is not None:
        metrics_server.close()


if __name__ == "__main__":
    main()
