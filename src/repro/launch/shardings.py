"""Per-leaf PartitionSpecs for params / optimizer state / caches / batches.

Leaves are matched by their pytree key path (MaxText-style logical rules,
resolved here by name because params are plain dicts).  Weight matrices
shard their contraction-output dim over 'model' (TP) and, when
``rules.fsdp`` is set, the other dim over 'data' (ZeRO-3); GSPMD pads
uneven dims (56 heads / 16, 8 kv heads / 16) — the padding waste is visible
in the roofline and is a §Perf lever.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding import ShardingRules

# last-key -> (spec for base ndim without the stacked-blocks lead dim)
_MATRIX_RULES = [
    (re.compile(r"w[qkv]$"), lambda r: (r.fsdp, r.heads)),
    (re.compile(r"wo$"), lambda r: (r.heads, r.fsdp)),
    (re.compile(r"in_proj$"), lambda r: (r.fsdp, r.mlp)),
    (re.compile(r"out_proj$"), lambda r: (r.mlp, r.fsdp)),
    (re.compile(r"router$"), lambda r: (r.fsdp, None)),
    (re.compile(r"conv_w$"), lambda r: (None, r.mlp)),
    (re.compile(r"patch_proj$"), lambda r: (None, None)),
    (re.compile(r"embed$"), lambda r: (r.vocab, r.fsdp)),
    (re.compile(r"unembed$"), lambda r: (r.fsdp, r.vocab)),
]


def _leaf_spec(key: str, ndim: int, rules: ShardingRules) -> P:
    in_blocks = "blocks" in key
    lead = (None,) if in_blocks else ()
    base_ndim = ndim - len(lead)
    m = re.search(r"(\w+)[\]'\.]*$", key)  # dict keys ['wq'] AND dataclass .k
    last = m.group(1) if m else key

    # --- caches ---
    if last in ("k", "v"):
        spec = (rules.batch, rules.cache_seq, rules.kv_heads, rules.kv_head_dim)[:base_ndim]
        return P(*lead, *spec)
    if last == "pos":
        return P(*lead, *([None] * base_ndim))
    if last == "h" and base_ndim == 4:  # SSM state (B,H,N,P)
        return P(*lead, rules.batch, rules.heads, None, None)
    if last == "conv" and base_ndim == 3:  # SSM conv state (B,cw-1,C)
        return P(*lead, rules.batch, None, rules.mlp)

    # --- weights ---
    for pat, fn in _MATRIX_RULES:
        if pat.search(last):
            spec = fn(rules)
            if base_ndim == len(spec):
                return P(*lead, *spec)
    if last in ("w1", "w3"):
        if base_ndim == 3:  # MoE (E, D, F): EP when E divides the model
            # axis, else intra-expert TP (F sharded, experts replicated)
            ftp = rules.mlp if rules.experts is None else None
            return P(*lead, rules.experts, rules.fsdp, ftp)
        return P(*lead, rules.fsdp, rules.mlp)
    if last == "w2":
        if base_ndim == 3:  # MoE (E, F, D)
            ftp = rules.mlp if rules.experts is None else None
            return P(*lead, rules.experts, ftp, rules.fsdp)
        return P(*lead, rules.mlp, rules.fsdp)
    # vectors / scalars (norm scales, biases, a_log, step, ...): replicate
    return P(*lead, *([None] * base_ndim)) if ndim else P()


def tree_specs(tree: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree matching ``tree`` (params/opt state/caches)."""
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    specs = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        specs.append(_leaf_spec(key, leaf.ndim, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _validate_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes that don't divide the dim (jit input shardings must divide;
    with_sharding_constraint tolerates padding but arguments do not)."""
    new = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            new.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        new.append(entry if shape[i] % size == 0 else None)
    return P(*new)


def tree_shardings(tree: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    out = []
    for path, leaf in paths:
        key = jax.tree_util.keystr(path)
        spec = _validate_spec(_leaf_spec(key, leaf.ndim, rules), leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(batch: Any, rules: ShardingRules) -> Any:
    """Input batches: shard dim 0 over the batch axes, replicate the rest."""
    return jax.tree.map(
        lambda leaf: P(rules.batch, *([None] * (leaf.ndim - 1))), batch
    )


def wants_fsdp(cfg: ModelConfig) -> bool:
    """ZeRO-3 weight sharding pays off above ~5B params."""
    return cfg.param_count() > 5e9
