import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware: 512
placeholder host devices build the production meshes, inputs are
ShapeDtypeStructs (no allocation), and for every cell we record

  * memory_analysis()  — per-device bytes (fits / doesn't fit),
  * cost_analysis()    — HLO FLOPs + bytes accessed (roofline numerator),
  * collective bytes   — parsed from the post-SPMD HLO text per collective
    kind (the roofline's third term).

Usage:
  python -m repro.launch.dryrun --arch yi_34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
(--all spawns one subprocess per cell so an OOM/compile crash loses one
cell, not the run.)
"""

import argparse
import dataclasses
import functools
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import shardings as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules, make_rules
from repro.train.train_loop import TrainConfig, abstract_train_state, make_train_step

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

# `%name = dtype[d0,d1]{layout} all-gather(...)` (also -start async forms).
_COLL_RE = re.compile(
    r"=\s*\(?\s*(\w+)\[([0-9,]*)\][^=]*?\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
# computation headers may have tuple-typed params with nested parens/brackets
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),.*?(?:condition=%?([\w\.\-]+)).*?(?:body=%?([\w\.\-]+))"
    r"|while\(.*?\),.*?(?:body=%?([\w\.\-]+)).*?(?:condition=%?([\w\.\-]+))"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+) = \(?(\w+)\[([0-9,]*)\][^=]*?\s([\w\-]+)\("
)
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "after-all", "partition-id", "replica-id"}
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_DOT_RE = re.compile(r"\bdot\(")
_LCD_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def hlo_metrics(hlo_text: str) -> Dict[str, Any]:
    """Loop-aware per-device FLOPs / HBM-byte / collective-byte totals.

    XLA's ``compiled.cost_analysis()`` counts every while body ONCE, so a
    scan over 12 pattern blocks under-reports 12×.  We re-derive the terms
    from the post-SPMD HLO text: dot FLOPs from output/contracting shapes,
    shallow bytes (operands+outputs of top-level ops — fusion internals
    never touch HBM), collective bytes by kind; while bodies are scaled by
    the trip count read from their condition's comparison constant.
    """
    comps: Dict[str, list] = {}
    shapes: Dict[str, float] = {}       # tensor name -> bytes
    dims_of: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        dm = _DEF_RE.match(line)
        if dm:
            name, dt, dims = dm.group(1), dm.group(2), dm.group(3)
            nbytes = _DTYPE_BYTES.get(dt, 4)
            dl = [int(d) for d in dims.split(",") if d]
            for d in dl:
                nbytes *= d
            shapes[name] = float(nbytes)
            dims_of[name] = dl

    def local_metrics(name: str) -> Dict[str, float]:
        out: Dict[str, float] = {"flops": 0.0, "bytes": 0.0}
        for line in comps.get(name, ()):
            dm = _DEF_RE.match(line)
            if dm is not None:
                refs = _OPND_RE.findall(line)[1:]
                if dm.group(4) not in _FREE_OPS:
                    # shallow bytes: output + array operands referenced
                    out["bytes"] += shapes.get(dm.group(1), 0.0)
                    out["bytes"] += sum(shapes.get(r, 0.0) for r in refs
                                        if r in shapes)
                if _DOT_RE.search(line):
                    outel = 1.0
                    for d in dims_of.get(dm.group(1), []):
                        outel *= d
                    k = 1.0
                    lcd = _LCD_RE.search(line)
                    if lcd and refs:
                        lhs_dims = dims_of.get(refs[0], [])
                        for ci in lcd.group(1).split(","):
                            if ci and int(ci) < len(lhs_dims):
                                k *= lhs_dims[int(ci)]
                    out["flops"] += 2.0 * outel * k
            cm = _COLL_RE.search(line)
            if cm:
                dt, dims, kind = cm.group(1), cm.group(2), cm.group(3)
                nbytes = _DTYPE_BYTES.get(dt, 4)
                for d in dims.split(","):
                    if d:
                        nbytes *= int(d)
                out[f"coll::{kind}"] = out.get(f"coll::{kind}", 0.0) + float(nbytes)
                if dt == "f32" and kind == "all-gather":
                    out["coll::f32_all_gather"] = (
                        out.get("coll::f32_all_gather", 0.0) + float(nbytes))
        return out

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for line in comps.get(cond_name, ())
                  for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    def resolve(name: str, seen, for_flops: bool) -> Dict[str, float]:
        if name in seen:
            return {}
        seen = seen | {name}
        total = dict(local_metrics(name))
        if not for_flops:
            total.pop("flops", None)
        else:
            total.pop("bytes", None)
        for line in comps.get(name, ()):
            subs = []
            wm = _WHILE_RE.search(line)
            if wm:
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                subs.append((resolve(body, seen, for_flops), trip_count(cond)))
            else:
                bm = _BRANCH_RE.search(line)
                if bm:
                    for b in _OPND_RE.findall(bm.group(1)):
                        subs.append((resolve(b, seen, for_flops), 1))
                elif for_flops:
                    # descend into fusions/calls for dot flops only; fusion
                    # internals never touch HBM so bytes stay shallow.
                    for cm2 in _CALL_RE.finditer(line):
                        subs.append((resolve(cm2.group(1), seen, True), 1))
            for sub, t in subs:
                for k, v in sub.items():
                    total[k] = total.get(k, 0.0) + t * v
        return total

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}
    fl = resolve(entry, frozenset(), True)
    by = resolve(entry, frozenset(), False)
    colls = {k.split("::")[1]: v for k, v in by.items() if k.startswith("coll::")}
    colls["total"] = sum(v for k, v in colls.items() if k != "f32_all_gather")
    return {
        "flops": fl.get("flops", 0.0),
        "bytes": by.get("bytes", 0.0),
        "collectives": colls,
    }


def saved_stack_bytes(cfg: ModelConfig, seq: int, batch: int, n_micro: int,
                      batch_shards: int = 16, seq_sharded: bool = False) -> float:
    """Per-device remat-saved layer-input stack for one microbatch.

    Empirical dtype factor 6 B/elem: the bf16 carry stack plus the f32 copy
    XLA materializes around the backward while-loop (measured; the f32
    roundtrip is an XLA artifact — JAX emits bf16 saves, see EXPERIMENTS).
    """
    tokens_dev = seq * batch / batch_shards / n_micro
    per_layer = tokens_dev * cfg.d_model * 6
    if seq_sharded:
        per_layer /= 16
    return per_layer * cfg.n_layers


def microbatches_for(cfg: ModelConfig, seq: int, batch: int,
                     seq_sharded: bool = False, batch_shards: int = 16) -> int:
    """Grad-accumulation count: bound the remat-saved stack at ~4 GB/device.

    n must divide the global batch and leave >= 1 row per batch shard
    (batch/n >= batch_shards: 16 single-pod, 32 multi-pod).  Fewer
    microbatches when SP already shards the stack — every extra microbatch
    re-gathers the FSDP weights.
    """
    n = 1
    while (saved_stack_bytes(cfg, seq, batch, n, batch_shards=batch_shards,
                             seq_sharded=seq_sharded) > 4e9
           and n < batch // batch_shards):
        n += 1
        while batch % n:
            n += 1
    return n


def needs_seq_shard(cfg: ModelConfig, seq: int, batch: int) -> bool:
    """Even at max microbatching the stack exceeds ~6 GB -> sequence-shard
    the residual stream over 'model' between layers (Ulysses-style SP)."""
    return saved_stack_bytes(cfg, seq, batch, max(1, batch // 16)) > 6e9


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    seq, batch, kind = configs.SHAPES[shape_name]
    f32, i32 = jnp.float32, jnp.int32
    if kind == "train":
        b: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), f32),
        }
        if cfg.n_patches:
            b["patches"] = jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.patch_dim), f32)
        if cfg.is_encdec:
            b["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_frames, cfg.d_model), f32)
        return b
    if kind == "prefill":
        b = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
        if cfg.n_patches:
            b["patches"] = jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.patch_dim), f32)
        if cfg.is_encdec:
            b["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_frames, cfg.d_model), f32)
        return b
    # decode: one new token against a seq-long cache
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), i32),
        "idx": jax.ShapeDtypeStruct((), i32),
        "caches": model.abstract_decode_caches(cfg, batch, seq),
    }


def rules_for(cfg: ModelConfig, shape_name: str, mesh) -> ShardingRules:
    overrides: Dict[str, Any] = {}
    overrides["fsdp"] = "data" if shlib.wants_fsdp(cfg) else None
    model_size = mesh.shape["model"]
    if 0 < cfg.n_heads < model_size:
        # Fewer query heads than the model axis (gemma3: 4 q / 1 kv on 16):
        # explicit head constraints fight GSPMD propagation (measured
        # 163 GB/dev collective-permute), and full replication trades it
        # for 3.5x replicated compute.  Leave attention internals
        # unconstrained; propagation from the sharded projections keeps a
        # consistent (heads x head_dim) factorization end-to-end.
        overrides["attn_unconstrained"] = True
        overrides["kv_heads"] = None      # cache in_shardings: batch-only
        overrides["kv_head_dim"] = None
        # NOTE: rules.heads stays 'model' — the WEIGHT shardings (wq/wo)
        # are what GSPMD propagates from; only activation constraints are
        # skipped via attn_unconstrained.
    if cfg.n_experts and cfg.n_experts % model_size != 0:
        # mixtral 8e on a 16-way model axis: intra-expert TP instead of EP
        overrides["experts"] = None
    if 0 < cfg.n_heads < model_size:
        pass  # handled above
    elif 0 < cfg.n_kv_heads < model_size:
        # kv_heads don't cover the model axis (GQA kv=8 on 16): shard
        # head_dim instead — contraction over the sharded dim psums, and
        # the KV cache actually splits instead of padding 2×.
        overrides["kv_heads"] = None
        overrides["kv_head_dim"] = "model"
    seq, batch, kind = configs.SHAPES[shape_name]
    if kind in ("decode", "prefill") and overrides.get("attn_unconstrained"):
        # small-head archs at decode: the CACHE still wants dh-sharding
        # (16x smaller per-device reads); q aligns in attn_decode.
        overrides["kv_head_dim"] = "model"
    if kind == "train" and needs_seq_shard(cfg, seq, batch):
        overrides["seq"] = "model"
    if kind == "decode" and batch < 16:
        # long_500k: batch=1 cannot shard; shard the KV-cache sequence
        # dimension over 'data' instead (ring-attention-style cache reads).
        overrides["batch"] = None
        overrides["cache_seq"] = "data"
    return make_rules(mesh, **overrides)


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build + lower + compile one cell; returns the result record."""
    cfg = configs.get_config(arch)
    ok, reason = configs.shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}
    seq, batch, kind = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, shape_name, mesh)
    t0 = time.time()

    with mesh:
        if kind == "train":
            n_micro = microbatches_for(
                cfg, seq, batch, seq_sharded=(rules.seq is not None),
                batch_shards=32 if multi_pod else 16)
            tcfg = TrainConfig(
                n_microbatches=n_micro,
                optimizer=OptimizerConfig(
                    moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32"
                ),
            )
            cfg = dataclasses.replace(cfg, remat_policy="full")
            state = abstract_train_state(cfg, tcfg)
            state_sh = shlib.tree_shardings(state, mesh, rules)
            batch_specs = input_specs(cfg, shape_name)
            batch_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, P(rules.batch, *([None] * (l.ndim - 1)))),
                batch_specs,
            )
            param_pspecs = shlib.tree_specs(state["params"], rules)
            step_fn = make_train_step(cfg, tcfg, rules,
                                      param_pspecs=param_pspecs)
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            ).lower(state, batch_specs)
        elif kind == "prefill":
            params = model.abstract_params(cfg)
            params_sh = shlib.tree_shardings(params, mesh, rules)
            ins = input_specs(cfg, shape_name)
            ins_sh = jax.tree.map(
                lambda l: NamedSharding(mesh, P(rules.batch, *([None] * (l.ndim - 1)))),
                ins,
            )

            def prefill_fn(params, batch):
                return model.prefill(
                    cfg, params, batch["tokens"], rules,
                    patches=batch.get("patches"), frames=batch.get("frames"),
                )

            lowered = jax.jit(
                prefill_fn, in_shardings=(params_sh, ins_sh)
            ).lower(params, ins)
        else:  # decode
            params = model.abstract_params(cfg)
            params_sh = shlib.tree_shardings(params, mesh, rules)
            ins = input_specs(cfg, shape_name)
            caches_sh = shlib.tree_shardings(ins["caches"], mesh, rules)
            tok_sh = NamedSharding(mesh, P(rules.batch, None))

            def serve_step(params, tokens, idx, caches):
                return model.decode_step(cfg, params, tokens, idx, caches, rules)

            lowered = jax.jit(
                serve_step,
                in_shardings=(params_sh, tok_sh, NamedSharding(mesh, P()), caches_sh),
                out_shardings=(
                    NamedSharding(mesh, P(rules.batch, rules.vocab)),
                    caches_sh,
                ),
            ).lower(params, ins["tokens"], ins["idx"], ins["caches"])

        compiled = lowered.compile()

    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_rec: Dict[str, Any] = {}
    for attr in (
        "temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
        "generated_code_size_in_bytes", "alias_size_in_bytes",
    ):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))
    metrics = hlo_metrics(compiled.as_text())
    coll = metrics["collectives"]

    n_chips = 512 if multi_pod else 256
    # loop-aware HLO metrics (XLA cost_analysis counts while bodies once)
    flops = metrics["flops"]
    bytes_acc = metrics["bytes"]
    seq, batch, kind = configs.SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if kind == "train":
        model_flops = 6.0 * n_active * seq * batch       # fwd+bwd, all tokens
    elif kind == "prefill":
        model_flops = 2.0 * n_active * seq * batch       # fwd only
    else:
        model_flops = 2.0 * n_active * batch             # fwd, one new token

    # CPU-backend artifact correction: XLA:CPU float-normalization upcasts
    # bf16 dots to f32, so weight/activation all-gathers feeding dots are
    # measured at 2x their TPU size (JAX-level dtypes verified bf16, see
    # EXPERIMENTS.md §Perf hillclimb B).  Conservative correction: halve
    # f32 all-gathers only; f32 all-reduces (which include genuinely-f32
    # gradient reductions) stay uncorrected.
    f32_ag = coll.get("f32_all_gather", 0.0)
    coll_corrected = coll.get("total", 0.0) - 0.5 * f32_ag

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": n_chips,
        "compile_s": round(t_compile, 1),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": mem_rec,
        "model_flops_total": model_flops,
        "compute_term_s": flops / PEAK_FLOPS,
        "memory_term_s": bytes_acc / HBM_BW,
        "collective_term_s": coll.get("total", 0.0) / ICI_BW,
        "collective_term_corrected_s": coll_corrected / ICI_BW,
    }
    terms = {"compute_term_s": rec["compute_term_s"],
             "memory_term_s": rec["memory_term_s"],
             "collective_term_s": rec["collective_term_corrected_s"]}
    rec["dominant_term"] = max(terms, key=terms.get)
    total_device_flops = flops * n_chips
    rec["useful_flops_ratio"] = (
        model_flops / total_device_flops if total_device_flops else 0.0
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if not args.all:
        assert args.arch and args.shape
        for mp in meshes:
            rec = lower_cell(args.arch, args.shape, mp)
            fn = f"{args.out}/{args.arch}.{args.shape}.{rec['mesh']}.json"
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            print(json.dumps(rec, indent=1))
        return

    # --all: one subprocess per cell (a crash loses one cell, not the run)
    cells = [
        (a, s) for a in configs.ARCH_IDS for s in configs.SHAPES
    ]
    for a, s in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            fn = f"{args.out}/{a}.{s}.{mesh_name}.json"
            if os.path.exists(fn):
                print(f"[skip cached] {fn}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", a, "--shape", s, "--mesh", mesh_name, "--out", args.out,
            ]
            print(f"[run] {a} × {s} × {mesh_name}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if r.returncode != 0:
                with open(fn, "w") as f:
                    json.dump({"arch": a, "shape": s, "mesh": mesh_name,
                               "status": "error",
                               "stderr": r.stderr[-4000:]}, f, indent=1)
                print(f"  ERROR (see {fn})", flush=True)
            else:
                print(r.stdout.splitlines()[-1][:120] if r.stdout else "  ok",
                      flush=True)


if __name__ == "__main__":
    main()
