"""Observability layer: spans, registry, dispatch/recompile accounting,
online recall probe, export surfaces — and the acceptance criterion that
turning all of it ON leaves engine results bit-identical.

Span/registry tests use private ``Tracer``/``MetricsRegistry`` instances
so they cannot interfere with the process-global ones the library
instrumentation writes to; dispatch-accounting tests read the global
registry through counter *deltas* for the same reason.
"""

import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.types import ForestConfig, SearchParams
from repro.data import ann_datasets
from repro.index import HilbertIndex, IndexConfig, MutableHilbertIndex
from repro.obs import (
    LatencyRecorder,
    MetricsRegistry,
    MetricsServer,
    RecallProbe,
    RecallProbeConfig,
    Tracer,
    default_registry,
    dispatch_counts,
    exact_topk,
    install_compile_listener,
    live_points,
    percentile_label,
    percentiles,
    recall_at_k,
    recompile_counts,
)
from repro import obs
from repro.obs.dispatch import dispatch_scope
from repro.serve import RetrievalEngine

N, D, Q = 2000, 32, 48

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0),
    query_chunk=16,
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return np.asarray(data), np.asarray(queries)


@pytest.fixture(scope="module")
def static_index(dataset):
    data, _ = dataset
    return HilbertIndex.build(data, config=CFG)


# -- spans -------------------------------------------------------------------


def test_span_nesting_records_parent_chain():
    tr = Tracer(enabled=True)
    with tr.span("outer", phase="a") as outer:
        with tr.span("mid") as mid:
            with tr.span("inner"):
                pass
        assert tr.current() is outer
    assert tr.current() is None
    spans = tr.spans()
    # completion order: innermost exits first
    assert [s.name for s in spans] == ["inner", "mid", "outer"]
    inner, mid_s, outer_s = spans
    assert inner.parent_id == mid_s.span_id
    assert mid_s.parent_id == outer_s.span_id
    assert outer_s.parent_id is None
    assert outer_s.attrs == {"phase": "a"}
    assert all(s.wall_ms is not None and s.wall_ms >= 0 for s in spans)


def test_span_trees_stay_separate_across_threads():
    """Serve/maintenance-style interleaving: each thread roots its own
    tree; neither thread's spans parent into the other's."""
    tr = Tracer(enabled=True)
    barrier = threading.Barrier(2)

    def worker(tag):
        barrier.wait()
        for i in range(5):
            with tr.span(f"{tag}.outer", i=i):
                with tr.span(f"{tag}.inner"):
                    time.sleep(0.001)

    threads = [
        threading.Thread(target=worker, args=(t,)) for t in ("serve", "maint")
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == 20
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        tag = s.name.split(".")[0]
        if s.parent_id is None:
            assert s.name == f"{tag}.outer"
        else:
            parent = by_id[s.parent_id]
            # parent is the same thread's outer span, never cross-thread
            assert parent.thread == s.thread
            assert parent.name == f"{tag}.outer"


def test_disabled_tracer_is_noop_and_enable_preserves_buffer():
    tr = Tracer(enabled=False)
    with tr.span("never") as s:
        s.set(k=1)  # noop span swallows attrs
    assert tr.spans() == []
    # global enable() must keep already-recorded spans (it resizes the
    # deque in place rather than replacing the tracer)
    prev = obs.default_tracer().enabled
    try:
        obs.enable()
        with obs.span("kept"):
            pass
        obs.enable(capacity=8192)
        assert any(s.name == "kept" for s in obs.default_tracer().spans())
    finally:
        obs.default_tracer().enabled = prev


def test_span_buffer_is_bounded():
    tr = Tracer(capacity=4, enabled=True)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]


def test_chrome_trace_is_valid_monotonic_json():
    tr = Tracer(enabled=True)

    def worker():
        with tr.span("t2.root"):
            pass

    with tr.span("root", rows=3) as root:
        with tr.span("child"):
            pass
    t = threading.Thread(target=worker)
    t.start()
    t.join()
    doc = json.loads(json.dumps(tr.chrome_trace()))  # round-trips as JSON
    events = doc["traceEvents"]
    assert len(events) == 3
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts), "timestamps must be monotonic"
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0 and isinstance(e["tid"], int)
    by_name = {e["name"]: e for e in events}
    assert by_name["child"]["args"]["parent"] == root.span_id
    assert by_name["root"]["args"]["rows"] == 3
    # the two threads land on different tracks
    assert by_name["t2.root"]["tid"] != by_name["root"]["tid"]


# -- registry ----------------------------------------------------------------


def test_percentile_label_generalizes():
    assert percentile_label(50) == "p50"
    assert percentile_label(99) == "p99"
    assert percentile_label(99.9) == "p999"
    assert percentile_label(99.99) == "p9999"
    assert percentile_label(99.5) == "p995"
    assert percentile_label(0.5) == "p05"


def test_percentiles_nearest_rank():
    s = list(range(1, 101))  # 1..100
    out = percentiles(s, points=(50.0, 99.0, 99.9))
    assert out == {"p50": 50.0, "p99": 99.0, "p999": 100.0}
    assert percentiles([]) == {}


def test_latency_recorder_consistent_snapshot_under_writers():
    """The (count, window) pair must come from one lock acquisition:
    count below capacity implies exactly count retained samples."""
    rec = LatencyRecorder(capacity=10_000)
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set() and i < 5_000:
            rec.record(float(i))
            i += 1

    threads = [threading.Thread(target=writer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for _ in range(200):
            n, window = rec._consistent()
            assert window.size == min(n, 10_000)
            snap = rec.snapshot()
            assert snap["count"] >= window.size or snap["count"] >= n
    finally:
        stop.set()
        for t in threads:
            t.join()
    n, window = rec._consistent()
    assert window.size == min(n, 10_000)


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("x_total", site="a")
    assert reg.counter("x_total", site="a") is c
    assert reg.counter("x_total", site="b") is not c
    with pytest.raises(TypeError):
        reg.gauge("x_total", site="a")
    g = reg.gauge("depth", fn=lambda: 7.0)
    assert g.value == 7.0
    # re-registering replaces the callback (newest owner wins)
    reg.gauge("depth", fn=lambda: 9.0)
    assert g.value == 9.0
    bad = reg.gauge("boom", fn=lambda: 1 / 0)
    assert np.isnan(bad.value)


def test_registry_snapshot_consistent_under_concurrent_writers():
    reg = MetricsRegistry()
    n_threads, n_incs = 4, 500
    lat = reg.latency("lat_ms", capacity=n_threads * n_incs)
    start = threading.Barrier(n_threads + 1)

    def writer(i):
        c = reg.counter("hits_total", worker=str(i))
        start.wait()
        for j in range(n_incs):
            c.inc()
            reg.counter("all_total").inc()
            lat.record(float(j))

    threads = [
        threading.Thread(target=writer, args=(i,)) for i in range(n_threads)
    ]
    for t in threads:
        t.start()
    start.wait()
    # snapshot + exposition concurrently with the writers: must not raise,
    # and every observed counter value must be internally plausible
    for _ in range(50):
        snap = reg.snapshot()
        total = snap.get("all_total", 0)
        assert 0 <= total <= n_threads * n_incs
        reg.prometheus_text()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["all_total"] == n_threads * n_incs
    for i in range(n_threads):
        assert snap[f'hits_total{{worker="{i}"}}'] == n_incs
    assert snap["lat_ms"]["count"] == n_threads * n_incs


def test_prometheus_text_exposition_shape():
    reg = MetricsRegistry()
    reg.counter("reqs_total", site="s").inc(3)
    reg.gauge("depth").set(2.5)
    lat = reg.latency("lat_ms")
    for v in (1.0, 2.0, 3.0, 4.0):
        lat.record(v)
    text = reg.prometheus_text()
    lines = text.strip().splitlines()
    assert "# TYPE reqs_total counter" in lines
    assert 'reqs_total{site="s"} 3' in lines
    assert "# TYPE depth gauge" in lines
    assert "depth 2.5" in lines
    assert "# TYPE lat_ms summary" in lines
    assert 'lat_ms{quantile="0.5"} 2.0' in lines
    assert "lat_ms_count 4" in lines
    # every non-comment line is `name[{labels}] value`
    import re

    pat = re.compile(
        r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? (NaN|[-+0-9.eE]+)$"
    )
    for line in lines:
        if not line.startswith("#"):
            assert pat.match(line), line


# -- dispatch / recompile accounting -----------------------------------------


def test_recompile_detector_fresh_shape_fires_bucket_hit_silent():
    """The live version of the pow2-bucket invariant: a fresh query-count
    bucket compiles once; re-hitting the bucket dispatches silently.

    jit caches are process-global, so "fresh" must hold against every test
    that ran before this one — the index here uses a dimensionality (29)
    no other test in the suite touches, making each bucket's first
    dispatch a guaranteed cache miss regardless of suite order."""
    if not install_compile_listener():
        pytest.skip("jax.monitoring duration listener unavailable")
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        1100, Q, 29, n_clusters=8, seed=3
    )
    cfg = IndexConfig(
        forest=ForestConfig(
            n_trees=4, bits=4, key_bits=96, leaf_size=16, seed=0
        ),
        query_chunk=16,
    )
    index = HilbertIndex.build(np.asarray(data), config=cfg)
    queries = np.asarray(queries)
    site = "hilbert.search"

    def delta(fn):
        d0 = dispatch_counts().get(site, 0)
        r0 = recompile_counts().get(site, 0)
        fn()
        return (
            dispatch_counts().get(site, 0) - d0,
            recompile_counts().get(site, 0) - r0,
        )

    # warm the 16-bucket (3 chunks of 16; at most the first compiles)
    d, r = delta(lambda: index.search(queries, SP))
    assert d == 3 and r <= 1
    # same bucket again: dispatches tick, recompiles must not
    d, r = delta(lambda: index.search(queries[16:32], SP))
    assert d == 1 and r == 0
    # fresh pow2 bucket (5 -> pad 8): exactly one recompile
    d, r = delta(lambda: index.search(queries[:5], SP))
    assert d == 1 and r == 1
    # bucket hit (7 -> pad 8): silent
    d, r = delta(lambda: index.search(queries[:7], SP))
    assert d == 1 and r == 0


def test_dispatch_scope_attributes_compiles_to_the_dispatching_thread():
    """A compile on the maintenance thread must not leak into a scope
    concurrently open on the serve thread (thread-local deltas)."""
    if not install_compile_listener():
        pytest.skip("jax.monitoring duration listener unavailable")
    import jax
    import jax.numpy as jnp

    compiled = threading.Event()
    entered = threading.Event()

    def compiler():
        entered.wait(5.0)
        with dispatch_scope("obs.test.compiler"):
            # fresh callable + odd shape: guaranteed cache miss
            jax.jit(lambda x: x * 3 + 1)(jnp.arange(37))
        compiled.set()

    t = threading.Thread(target=compiler)
    t.start()
    r0 = recompile_counts()
    with dispatch_scope("obs.test.bystander"):
        entered.set()
        assert compiled.wait(30.0)
    t.join()
    r1 = recompile_counts()
    assert r1.get("obs.test.compiler", 0) - r0.get("obs.test.compiler", 0) == 1
    assert r1.get("obs.test.bystander", 0) == r0.get("obs.test.bystander", 0)


# -- online recall probe -----------------------------------------------------


def test_live_points_masks_tombstones(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=256, max_segments=8)
    mut.insert(data[:1500])
    mut.delete(np.arange(0, 100, dtype=np.int64))
    mut.insert(data[1500:1510])  # lands in the write buffer
    ids, pts = live_points(mut)
    assert ids.size == 1500 - 100 + 10
    assert np.intersect1d(ids, np.arange(100)).size == 0
    assert 1505 in ids  # buffered rows included
    # points round-trip: every live id maps back to its source row
    lookup = {int(i): p for i, p in zip(ids, pts)}
    np.testing.assert_allclose(lookup[200], data[200], rtol=1e-6)
    np.testing.assert_allclose(lookup[1505], data[1505], rtol=1e-6)


def test_exact_topk_and_recall_at_k():
    pts = np.array([[0.0, 0.0], [1.0, 0.0], [2.0, 0.0], [3.0, 0.0]])
    ids = np.array([10, 11, 12, 13], dtype=np.int64)
    q = np.array([[0.1, 0.0]])
    exact = exact_topk(q, ids, pts, k=2)
    np.testing.assert_array_equal(exact, [[10, 11]])
    # k beyond the live count pads with -1 and recall divides by full k
    exact4 = exact_topk(q, ids[:1], pts[:1], k=3)
    np.testing.assert_array_equal(exact4, [[10, -1, -1]])
    r = recall_at_k(np.array([[10, 12]]), np.array([[10, 11]]))
    assert r.tolist() == [0.5]


def test_online_recall_matches_offline(dataset):
    """Acceptance criterion: the probe's rolling recall@k equals an
    offline exact evaluation of the same served results (±0.02; with a
    100% sample and a quiescent index they agree exactly)."""
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=256, max_segments=8)
    mut.insert(data[:1500])
    mut.delete(np.arange(0, 50, dtype=np.int64))

    eng = RetrievalEngine(
        mut, SP, max_batch=16,
        recall=RecallProbeConfig(fraction=1.0, max_pending=16, seed=0),
    )
    direct_i, _ = mut.search(queries, SP)
    tickets = [eng.submit(queries[a:b]) for a, b in [(0, 16), (16, 48)]]
    while eng.step():
        pass
    scored = eng.score_recall()
    assert scored == Q
    online = eng.recall_probe.recall()

    ids, pts = live_points(mut)
    exact = exact_topk(queries, ids, pts, SP.k)
    offline = float(recall_at_k(np.asarray(direct_i), exact).mean())
    assert abs(online - offline) <= 0.02
    snap = default_registry().snapshot()
    assert snap["engine_recall_samples_total"] >= Q
    assert abs(snap["engine_recall_at_k"] - online) <= 1e-12
    for t in tickets:
        assert t.ids is not None


def test_recall_probe_sampling_and_backpressure(static_index, dataset):
    _, queries = dataset
    reg = MetricsRegistry()
    probe = RecallProbe(
        RecallProbeConfig(fraction=1.0, max_pending=2, seed=0), registry=reg
    )
    ids, _ = static_index.search(queries[:4], SP)
    for _ in range(5):
        probe.offer(queries[:4], np.asarray(ids), SP.k, static_index)
    snap = reg.snapshot()
    assert snap["engine_recall_batches_sampled_total"] == 2
    assert snap["engine_recall_batches_dropped_total"] == 3
    assert snap["engine_recall_pending_batches"] == 2
    assert probe.score_pending() == 8
    assert 0.0 <= probe.recall() <= 1.0
    # fraction=0 never samples
    never = RecallProbe(RecallProbeConfig(fraction=0.0), registry=MetricsRegistry())
    assert not never.offer(queries[:4], np.asarray(ids), SP.k, static_index)


# -- engine bit-identity with full observability on --------------------------


def test_step_mode_bit_identical_with_observability_enabled(
    static_index, dataset
):
    """Tracing + metrics + dispatch accounting + a 100% recall probe must
    not perturb results: every row equals the direct search, bit for bit."""
    _, queries = dataset
    direct_i, direct_d = static_index.search(queries, SP)
    tracer = obs.default_tracer()
    prev = tracer.enabled
    try:
        obs.enable()
        eng = RetrievalEngine(
            static_index, SP, max_batch=16,
            recall=RecallProbeConfig(fraction=1.0, max_pending=16, seed=0),
        )
        cuts = [0, 5, 8, 20, 21, 37, Q]
        tickets = [
            eng.submit(queries[a:b]) for a, b in zip(cuts[:-1], cuts[1:])
        ]
        while eng.step():
            pass
        eng.score_recall()
    finally:
        tracer.enabled = prev
    got_i = np.concatenate([t.ids for t in tickets])
    got_d = np.concatenate([t.dists for t in tickets])
    np.testing.assert_array_equal(got_i, np.asarray(direct_i))
    np.testing.assert_array_equal(got_d, np.asarray(direct_d))
    names = {s.name for s in tracer.spans()}
    assert {"engine.batch", "engine.search"} <= names
    snap = default_registry().snapshot()
    assert snap["engine_completed_total"] >= len(tickets)
    assert not np.isnan(snap["engine_recall_at_k"])


# -- export surface (HTTP) ---------------------------------------------------


def test_metrics_http_endpoint_serves_all_three_views():
    reg = MetricsRegistry()
    reg.counter("up_total").inc()
    reg.latency("ping_ms").record(1.5)
    tr = Tracer(enabled=True)
    with tr.span("http.test"):
        pass
    with MetricsServer(port=0, registry=reg, tracer=tr) as srv:
        text = urllib.request.urlopen(
            srv.url + "/metrics", timeout=10
        ).read().decode()
        assert "up_total 1" in text
        assert 'ping_ms{quantile="0.5"} 1.5' in text
        snap = json.loads(
            urllib.request.urlopen(srv.url + "/metrics.json", timeout=10).read()
        )
        assert snap["up_total"] == 1
        trace_doc = json.loads(
            urllib.request.urlopen(srv.url + "/trace", timeout=10).read()
        )
        assert [e["name"] for e in trace_doc["traceEvents"]] == ["http.test"]
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(srv.url + "/nope", timeout=10)
    # closed: further requests fail fast
    with pytest.raises(Exception):
        urllib.request.urlopen(srv.url + "/metrics", timeout=2)


# -- engine metrics mirror ---------------------------------------------------


def test_engine_metrics_mirror_into_registry():
    from repro.serve.metrics import EngineMetrics

    reg = MetricsRegistry()
    m = EngineMetrics(registry=reg)
    m.bump("admitted", 3)
    m.latency.record(4.0)
    m.queue_wait.record(1.0)
    assert m.counter("admitted") == 3
    snap = reg.snapshot()
    assert snap["engine_admitted_total"] == 3
    assert snap["engine_request_ms"]["count"] == 1.0
    assert snap["engine_queue_wait_ms"]["count"] == 1.0
    assert m.snapshot()["queue_wait_ms"]["count"] == 1.0
    # a second engine resets the per-engine view but the registry counter
    # keeps climbing (Prometheus monotonicity)
    m2 = EngineMetrics(registry=reg)
    m2.bump("admitted")
    assert m2.counter("admitted") == 1
    assert reg.snapshot()["engine_admitted_total"] == 4
