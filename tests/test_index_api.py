"""Tests for the unified, self-describing ``repro.index.HilbertIndex`` API.

Covers the facade's contract: config travels with the index (no config
argument at search time — the legacy mismatch footgun is structurally
gone), save/load reproduces search bit-exactly, deprecation shims warn yet
match the facade exactly, and the index behaves as a JAX pytree.
"""

import inspect
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knn_graph as legacy_knn_graph
from repro.core import search as legacy_search
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    GraphParams,
    HilbertIndex,
    IndexConfig,
    SearchParams,
    resolve_backend,
)

N, D, Q = 3000, 64, 32

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=256, leaf_size=16, seed=0)
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)
GP = GraphParams(n_orders=4, k1=16, k2=32, k=8, seed=0)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return jnp.asarray(data), jnp.asarray(queries)


@pytest.fixture(scope="module")
def index(dataset):
    data, _ = dataset
    return HilbertIndex.build(data, CFG)


def test_search_returns_valid_topk(dataset, index):
    _, queries = dataset
    ids, d2 = index.search(queries, SP)
    ids, d2 = np.asarray(ids), np.asarray(d2)
    assert ids.shape == (Q, SP.k) and d2.shape == (Q, SP.k)
    assert ((ids >= 0) & (ids < N)).all()
    assert np.all(np.diff(d2, axis=1) >= -1e-5)  # sorted ascending
    for row in ids:
        assert len(set(row.tolist())) == len(row)  # deduped


def test_index_is_self_describing_no_config_at_search(dataset, index):
    """Regression: a mismatched config can no longer be injected at search.

    The legacy ``search(index, queries, params, forest_cfg)`` let callers
    pass a ForestConfig that disagreed with build time, silently corrupting
    results.  The facade has no such parameter at all.
    """
    _, queries = dataset
    sig = inspect.signature(HilbertIndex.search)
    assert "forest_cfg" not in sig.parameters
    assert "cfg" not in sig.parameters
    wrong_cfg = ForestConfig(n_trees=4, bits=2, key_bits=64, leaf_size=16)
    with pytest.raises(TypeError):
        index.search(queries, SP, wrong_cfg)  # no third positional exists
    sig_g = inspect.signature(HilbertIndex.knn_graph)
    assert "forest_cfg" not in sig_g.parameters
    # and the carried config is the one from build time
    assert index.config == CFG


def test_save_load_roundtrip_bit_identical(tmp_path, dataset, index):
    _, queries = dataset
    ids, d2 = index.search(queries, SP)
    index.save(str(tmp_path / "idx"))
    loaded = HilbertIndex.load(str(tmp_path / "idx"))
    assert loaded.config == index.config
    ids2, d22 = loaded.search(queries, SP)
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert np.array_equal(np.asarray(d2), np.asarray(d22))
    # Task 2 off the loaded artifact is bit-identical too.
    g1 = index.knn_graph(GP)
    g2 = loaded.knn_graph(GP)
    assert np.array_equal(np.asarray(g1[0]), np.asarray(g2[0]))
    assert np.array_equal(np.asarray(g1[1]), np.asarray(g2[1]))


def test_load_rejects_non_index_checkpoint(tmp_path):
    from repro import checkpoint

    checkpoint.save(str(tmp_path / "w"), 0, {"w": np.zeros(3)}, extra={})
    with pytest.raises(ValueError, match="not a HilbertIndex"):
        HilbertIndex.load(str(tmp_path / "w"))
    with pytest.raises(FileNotFoundError):
        HilbertIndex.load(str(tmp_path / "missing"))


def test_legacy_search_shim_warns_and_matches(dataset, index):
    data, queries = dataset
    with pytest.warns(DeprecationWarning):
        legacy_idx = legacy_search.build_index(data, CFG.forest)
    with pytest.warns(DeprecationWarning):
        lids, ld2 = legacy_search.search(legacy_idx, queries, SP, CFG.forest)
    ids, d2 = index.search(queries, SP)
    assert np.array_equal(np.asarray(ids), np.asarray(lids))
    assert np.array_equal(np.asarray(d2), np.asarray(ld2))


def test_legacy_knn_graph_shim_warns_and_matches(dataset, index):
    data, _ = dataset
    with pytest.warns(DeprecationWarning):
        lids, ld2 = legacy_knn_graph.build_knn_graph(
            data, GP, forest_cfg=CFG.forest
        )
    ids, d2 = index.knn_graph(GP)
    assert np.array_equal(np.asarray(ids), np.asarray(lids))
    assert np.array_equal(np.asarray(d2), np.asarray(ld2))


def test_knn_graph_requires_stored_points(dataset):
    data, _ = dataset
    slim = HilbertIndex.build(
        data, IndexConfig(forest=CFG.forest, store_points=False)
    )
    assert slim.points is None
    with pytest.raises(ValueError, match="store_points"):
        slim.knn_graph(GP)
    # search is unaffected by dropping the raw points
    _, queries = dataset
    ids, _ = slim.search(queries, SP)
    assert np.asarray(ids).shape == (Q, SP.k)


def test_backend_routing(dataset, index):
    _, queries = dataset
    with pytest.raises(ValueError, match="backend"):
        index.search(queries, SP, backend="cuda")
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("auto") in ("xla", "pallas")
    # explicit xla and auto agree on CPU test hosts
    ids_auto, _ = index.search(queries, SP, backend="auto")
    ids_xla, _ = index.search(queries, SP, backend="xla")
    if jax.default_backend() != "tpu":
        assert np.array_equal(np.asarray(ids_auto), np.asarray(ids_xla))


def test_index_is_a_pytree(index):
    leaves = jax.tree_util.tree_leaves(index)
    assert len(leaves) >= 12  # forest(6) + quant(2) + 4 master arrays + points
    mapped = jax.tree_util.tree_map(lambda x: x, index)
    assert isinstance(mapped, HilbertIndex)
    assert mapped.config == index.config  # config is static aux data
    assert np.array_equal(
        np.asarray(mapped.master_order), np.asarray(index.master_order)
    )


def test_memory_report(index):
    rep = index.memory_report()
    assert rep["combined_stage2_bytes"] < rep["sketch_bytes"] + rep["quantized_bytes"]
    assert rep["forest_bytes"] > 0
    assert rep["points_bytes"] == N * D * 4
    assert rep["total_bytes"] >= rep["forest_bytes"] + rep["combined_stage2_bytes"]


def test_config_dict_roundtrip():
    d = CFG.to_dict()
    assert IndexConfig.from_dict(d) == CFG
    # forward-compat: unknown keys ignored
    d["forest"]["future_field"] = 123
    d["unknown"] = "x"
    assert IndexConfig.from_dict(d) == CFG


def test_retrieval_store_on_facade(tmp_path, dataset):
    from repro.serve.retrieval import RetrievalStore

    data, queries = dataset
    values = jnp.arange(N, dtype=jnp.int32) % 97
    store = RetrievalStore.build(
        data, values, IndexConfig(forest=CFG.forest, store_points=False)
    )
    ids, d2 = store.lookup(queries, SP)
    assert np.asarray(ids).shape == (Q, SP.k)
    store.save(str(tmp_path / "store"))
    loaded = RetrievalStore.load(str(tmp_path / "store"))
    ids2, d22 = loaded.lookup(queries, SP)
    assert np.array_equal(np.asarray(ids), np.asarray(ids2))
    assert np.array_equal(np.asarray(d2), np.asarray(d22))
    assert np.array_equal(np.asarray(loaded.values), np.asarray(values))
