"""Tests for ``repro.index.MutableHilbertIndex`` (LSM streaming mutation).

Core contract: after ANY insert/delete/flush/compact sequence, search over
the mutable index is at least as good as a from-scratch
``HilbertIndex.build`` over the surviving points — and after a full
``compact()`` it is *equivalent* (same sorted distance profile; same ids up
to ADC-distance ties), because compaction rebuilds over the live points in
insertion order via the same fast path.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.data import ann_datasets
from repro.index import (
    ForestConfig,
    HilbertIndex,
    IndexConfig,
    MutableHilbertIndex,
    SearchParams,
)

N, D, Q = 2000, 32, 24

CFG = IndexConfig(
    forest=ForestConfig(n_trees=4, bits=4, key_bits=128, leaf_size=16, seed=0)
)
SP = SearchParams(k1=16, k2=64, h=1, k=10)


@pytest.fixture(scope="module")
def dataset():
    data, queries = ann_datasets.lowrank_dataset_with_queries(
        N, Q, D, n_clusters=8, seed=0
    )
    return np.asarray(data), jnp.asarray(queries)


def _recall_vs_exact(ext_ids, live_ids, live_pts, queries, k):
    """recall@k of external-id results against exact kNN over live points."""
    gt, _ = ann_datasets.exact_knn(live_pts, np.asarray(queries), k)
    pos_of = {int(e): i for i, e in enumerate(live_ids)}
    pos = np.asarray(
        [[pos_of.get(int(e), -1) for e in row] for row in np.asarray(ext_ids)]
    )
    return ann_datasets.recall_at_k(pos, gt), pos


# -- streaming equivalence ---------------------------------------------------


def test_streamed_equals_fresh_build_after_compact(dataset):
    """Insert in batches + delete + compact == fresh build over survivors."""
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=300, max_segments=4)
    ids_a = mut.insert(data[:1200])
    mut.delete(ids_a[50:150])
    ids_b = mut.insert(data[1200:])
    mut.compact()
    assert mut.n_segments == 1
    assert mut.n_live == N - 100

    live_mask = np.ones(N, bool)
    live_mask[50:150] = False
    fresh = HilbertIndex.build(jnp.asarray(data[live_mask]), CFG)
    fids, fd2 = fresh.search(queries, SP)
    mids, md2 = mut.search(queries, SP)
    # Identical sorted distance profiles...
    assert np.array_equal(np.asarray(md2), np.asarray(fd2))
    # ...and identical ids: fresh position p holds the point whose external
    # id is live_ids[p], so mapping fresh results through live_ids must
    # reproduce the mutable results exactly.
    live_ids = np.concatenate([ids_a, ids_b])[live_mask]
    assert np.array_equal(live_ids[np.asarray(fids)], np.asarray(mids))


def test_multisegment_recall_at_least_fresh(dataset):
    """Un-compacted LSM state (segments + buffer + tombstones) loses nothing."""
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=256, max_segments=6)
    ids = mut.insert(data)
    rng = np.random.default_rng(1)
    dead = rng.choice(N, 200, replace=False)
    mut.delete(ids[dead])
    mut.insert(data[:100])  # re-add some points (new ids, still live)
    assert mut.n_segments > 1

    live_mask = np.ones(N, bool)
    live_mask[dead] = False
    live_ids = np.concatenate([ids[live_mask], np.arange(N, N + 100)])
    live_pts = np.concatenate([data[live_mask], data[:100]])
    rec_mut, _ = _recall_vs_exact(
        mut.search(queries, SP)[0], live_ids, live_pts, queries, SP.k
    )
    fresh = HilbertIndex.build(jnp.asarray(live_pts), CFG)
    rec_fresh, _ = _recall_vs_exact(
        np.arange(len(live_pts))[np.asarray(fresh.search(queries, SP)[0])],
        np.arange(len(live_pts)), live_pts, queries, SP.k,
    )
    assert rec_mut >= rec_fresh


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    batches=st.lists(st.integers(40, 300), min_size=1, max_size=4),
    delete_frac=st.floats(0.0, 0.4),
    capacity=st.integers(64, 256),
)
def test_streaming_equivalence_property(seed, batches, delete_frac, capacity):
    """Property: any insert/delete/compact stream matches a fresh build."""
    rng = np.random.default_rng(seed)
    n = sum(batches)
    data = rng.normal(size=(n, 16)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    cfg = IndexConfig(
        forest=ForestConfig(n_trees=2, bits=4, key_bits=64, leaf_size=8, seed=0)
    )
    sp = SearchParams(k1=8, k2=32, h=1, k=5)

    mut = MutableHilbertIndex(cfg, buffer_capacity=capacity, max_segments=3)
    all_ids, start = [], 0
    for b in batches:
        ids = mut.insert(data[start : start + b])
        all_ids.append(ids)
        n_del = int(delete_frac * b)
        if n_del:
            mut.delete(rng.choice(ids, n_del, replace=False))
        start += b
    mut.compact()

    all_ids = np.concatenate(all_ids)
    live = mut._alive[all_ids]
    assert mut.n_live == int(live.sum())
    if mut.n_live == 0:
        mids, md2 = mut.search(queries, sp)
        assert (np.asarray(mids) == -1).all()
        return
    fresh = HilbertIndex.build(jnp.asarray(data[live]), cfg)
    _, fd2 = fresh.search(queries, sp)
    mids, md2 = mut.search(queries, sp)
    k_pad = max(0, sp.k - mut.n_live)  # fresh build has no -1 padding
    if k_pad == 0:
        assert np.array_equal(np.asarray(md2), np.asarray(fd2))
    else:
        assert np.isinf(np.asarray(md2)[:, sp.k - k_pad :]).all()
    # every returned non-padding id is live
    ret = np.asarray(mids)
    assert mut._alive[ret[ret >= 0]].all()


# -- tombstone edge cases ----------------------------------------------------


def test_delete_then_reinsert(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=128)
    ids = mut.insert(data[:64])
    assert mut.delete(ids[:32]) == 32
    assert mut.delete(ids[:32]) == 0  # idempotent
    ids2 = mut.insert(data[:32])  # same vectors, NEW identities
    assert not np.intersect1d(ids, ids2).size or (ids2 > ids.max()).all()
    q = jnp.asarray(data[:4])
    hits, d2 = mut.search(q, dataclasses.replace(SP, k=4))
    hits = np.asarray(hits)
    assert not np.isin(hits, ids[:32]).any()  # tombstoned ids never surface
    # each query point's own reinserted copy comes back at distance ~0
    assert np.asarray(d2)[:, 0] == pytest.approx(0.0, abs=1e-3)
    assert (hits[np.arange(4), 0] == ids2[np.arange(4)]).all()


def test_delete_entire_segment_and_compact(dataset):
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=100, max_segments=10)
    ids_a = mut.insert(data[:100])  # seals segment A exactly
    ids_b = mut.insert(data[100:200])  # seals segment B
    assert mut.n_segments == 2
    mut.delete(ids_a)  # entire segment A dead
    hits, _ = mut.search(queries, SP)
    hits = np.asarray(hits)
    assert not np.isin(hits, ids_a).any()
    assert np.isin(hits[hits >= 0], ids_b).all()
    mut.compact()
    assert mut.n_segments == 1  # dead segment physically gone
    assert mut.segments[0].n_points == 100
    assert np.array_equal(mut.segments[0].ids, ids_b)


def test_search_k_exceeds_live_points(dataset):
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=16)
    ids = mut.insert(data[:24])  # one segment of 16 + 8 buffered
    mut.delete(ids[20:])
    params = dataclasses.replace(SP, k=30)  # k=30 > 20 live
    hits, d2 = mut.search(queries, params)
    hits, d2 = np.asarray(hits), np.asarray(d2)
    assert hits.shape == (Q, 30)
    # exactly the 20 live ids come back, then -1/inf padding
    for row, drow in zip(hits, d2):
        assert set(row[row >= 0].tolist()) == set(ids[:20].tolist())
        assert (row[20:] == -1).all() and np.isinf(drow[20:]).all()
    # empty index: all padding
    empty = MutableHilbertIndex(CFG)
    ehits, ed2 = empty.search(queries, SP)
    assert (np.asarray(ehits) == -1).all() and np.isinf(np.asarray(ed2)).all()


def test_flush_drops_dead_buffer_rows(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=512)
    ids = mut.insert(data[:64])
    mut.delete(ids)
    assert mut.flush() is None  # fully tombstoned buffer seals nothing
    assert mut.n_segments == 0 and mut.n_buffered == 0


# -- persistence and values --------------------------------------------------


def test_save_load_roundtrip_and_continue(tmp_path, dataset):
    data, queries = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=300, max_segments=4)
    ids = mut.insert(data[:1000], values=np.arange(1000, dtype=np.int32) % 17)
    mut.delete(ids[::7])
    mut.insert(data[1000:1100],
               values=np.arange(1000, 1100, dtype=np.int32) % 17)
    h1, d1 = mut.search(queries, SP)
    mut.save(str(tmp_path / "m"))
    loaded = MutableHilbertIndex.load(str(tmp_path / "m"))
    assert loaded.config == mut.config
    assert loaded.n_live == mut.n_live and loaded.n_segments == mut.n_segments
    h2, d2 = loaded.search(queries, SP)
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(
        np.asarray(loaded.values_at(h1)), np.asarray(mut.values_at(h1))
    )
    # the loaded index keeps streaming: insert/delete/compact all work
    loaded.insert(data[1100:1200],
                  values=np.arange(1100, 1200, dtype=np.int32) % 17)
    loaded.compact()
    assert loaded.n_segments == 1
    with pytest.raises(ValueError, match="kind"):
        from repro.index import load_mutable_bundle

        load_mutable_bundle(str(tmp_path / "m"), kind="retrieval_store")
    with pytest.raises(FileNotFoundError):
        MutableHilbertIndex.load(str(tmp_path / "missing"))


def test_resave_to_same_path_is_nondestructive(tmp_path, dataset):
    """A newer save never rewrites bundles an older manifest references."""
    import shutil

    data, queries = dataset
    path = str(tmp_path / "m")
    mut = MutableHilbertIndex(CFG, buffer_capacity=200, max_segments=8)
    ids = mut.insert(data[:500])
    mut.save(path)
    h1, d1 = mut.search(queries, SP)
    manifest_v1 = (tmp_path / "m" / "mutable_manifest.json").read_bytes()
    # mutate heavily and save again over the same directory
    mut.delete(ids[:250])
    mut.insert(data[500:900])
    mut.compact()
    mut.save(path)
    h2, d2 = mut.search(queries, SP)
    loaded2 = MutableHilbertIndex.load(path)
    assert np.array_equal(np.asarray(loaded2.search(queries, SP)[0]),
                          np.asarray(h2))
    # simulate a crash BEFORE the v2 manifest rename: restore the v1
    # manifest — everything it references must still be intact on disk.
    (tmp_path / "m" / "mutable_manifest.json").write_bytes(manifest_v1)
    loaded1 = MutableHilbertIndex.load(path)
    assert loaded1.n_live == 500 and loaded1.n_deleted == 0
    assert np.array_equal(np.asarray(loaded1.search(queries, SP)[0]),
                          np.asarray(h1))
    assert np.array_equal(np.asarray(loaded1.search(queries, SP)[1]),
                          np.asarray(d1))
    shutil.rmtree(path)


def test_save_over_foreign_checkpoint_never_keeps_stale_segments(tmp_path,
                                                                 dataset):
    """Segment dedup is content-addressed: same path + same shape/ids but
    different points must be rewritten, not skipped."""
    data, queries = dataset
    path = str(tmp_path / "m")
    a = MutableHilbertIndex(CFG, buffer_capacity=512)
    a.bulk_load(data[:200])
    a.save(path)
    # a different process rebuilds from a different corpus of the SAME size:
    # identical gen, n_points, and external ids 0..199.
    b = MutableHilbertIndex(CFG, buffer_capacity=512)
    b.bulk_load(data[200:400])
    b.save(path)
    loaded = MutableHilbertIndex.load(path)
    hb, db = b.search(queries, SP)
    hl, dl = loaded.search(queries, SP)
    assert np.array_equal(np.asarray(hb), np.asarray(hl))
    assert np.array_equal(np.asarray(db), np.asarray(dl))


def test_saves_prune_unreferenced_bundles(tmp_path, dataset):
    """Disk usage is bounded: only current+previous manifest bundles remain."""
    import os

    data, _ = dataset
    path = str(tmp_path / "m")
    mut = MutableHilbertIndex(CFG, buffer_capacity=100, max_segments=10)
    for i in range(4):
        mut.insert(data[i * 100 : (i + 1) * 100])
        mut.compact()  # new gen each round; older segment becomes garbage
        mut.save(path)
    state_steps = [n for n in os.listdir(os.path.join(path, "state"))
                   if n.startswith("step_")]
    seg_dirs = os.listdir(os.path.join(path, "segments"))
    assert len(state_steps) <= 2 and len(seg_dirs) <= 2
    assert MutableHilbertIndex.load(path).n_live == 400


def test_heavily_tombstoned_segment_rewritten_on_read(dataset):
    """Once tombstones exceed the stage-2 pool, search rewrites the segment
    instead of letting dead candidates crowd out live neighbors."""
    data, queries = dataset
    cfg = IndexConfig(forest=CFG.forest)
    sp = dataclasses.replace(SP, k2=32, h=1, k=10)  # pool cap = 96
    mut = MutableHilbertIndex(cfg, buffer_capacity=200)
    ids = mut.insert(data[:200])  # one sealed segment
    assert mut.n_segments == 1
    gen_before = mut.segments[0].gen
    mut.delete(ids[:150])  # dead=150 > cap-k=86
    hits, d2 = mut.search(queries, sp)
    assert mut.segments[0].gen != gen_before  # rewritten in place
    assert mut.segments[0].n_points == 50  # tombstones physically dropped
    hits = np.asarray(hits)
    assert np.isin(hits[hits >= 0], ids[150:]).all()
    # store_points=False can't rewrite: must degrade gracefully, not crash
    slim = MutableHilbertIndex(
        IndexConfig(forest=CFG.forest, store_points=False), buffer_capacity=200
    )
    sids = slim.insert(data[:200])
    slim.delete(sids[:150])
    shits, _ = slim.search(queries, sp)
    assert not np.isin(np.asarray(shits), sids[:150]).any()


def test_legacy_static_retrieval_checkpoint_still_loads(tmp_path, dataset):
    """One-release compat: PR-1-format store bundles load via from_index."""
    from repro.index import save_index_bundle
    from repro.serve.retrieval import RetrievalStore

    data, queries = dataset
    static = HilbertIndex.build(
        jnp.asarray(data[:500]),
        IndexConfig(forest=CFG.forest, store_points=False),
    )
    values = np.arange(500, dtype=np.int32) % 11
    save_index_bundle(  # exactly what the old RetrievalStore.save wrote
        static, str(tmp_path / "old"), kind="retrieval_store",
        extra_arrays={"values": jnp.asarray(values)},
    )
    store = RetrievalStore.load(str(tmp_path / "old"))
    ids, _ = store.lookup(queries, SP)
    sids, _ = static.search(queries, SP)
    assert np.array_equal(np.asarray(ids), np.asarray(sids))
    assert np.array_equal(np.asarray(store.values), values)
    store.append(jnp.asarray(data[500:510]),
                 jnp.asarray(np.arange(10, dtype=np.int32)))
    assert store.index.n_live == 510


def test_failed_first_insert_does_not_pin_values_mode(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG)
    with pytest.raises(ValueError, match="values must be"):
        mut.insert(data[:10], values=np.arange(3))
    mut.insert(data[:10])  # valueless mode still available
    assert mut._track_values is False


def test_failed_insert_leaves_state_unchanged(dataset):
    """A rejected insert must not advance ids or desync values/alive."""
    data, _ = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=128)
    mut.insert(data[:10], values=np.arange(10, dtype=np.int32))
    with pytest.raises(ValueError, match="values must be"):
        mut.insert(data[10:20], values=np.arange(7, dtype=np.int32))
    assert mut.n_live == 10 and mut._next_id == 10
    ids = mut.insert(data[10:20], values=np.arange(10, 20, dtype=np.int32))
    assert np.array_equal(ids, np.arange(10, 20))
    assert np.array_equal(
        np.asarray(mut.values_at(ids)), np.arange(10, 20)
    )


def test_from_index_without_values_pins_valueless_mode(dataset):
    data, _ = dataset
    base = HilbertIndex.build(jnp.asarray(data[:100]), CFG)
    mut = MutableHilbertIndex.from_index(base)
    with pytest.raises(ValueError, match="values"):
        mut.insert(data[100:110], values=np.arange(10))
    assert mut._next_id == 100  # the rejected insert assigned nothing


def test_store_points_false_serves_but_cannot_compact(dataset):
    """store_points=False saves segment RAM; compaction degrades gracefully."""
    data, queries = dataset
    slim_cfg = IndexConfig(forest=CFG.forest, store_points=False)
    mut = MutableHilbertIndex(slim_cfg, buffer_capacity=100, max_segments=2)
    mut.insert(data[:500])  # exceeds max_segments; tier merge must not crash
    assert mut.n_segments >= 2
    assert all(s.index.points is None for s in mut.segments)
    hits, _ = mut.search(queries, SP)
    assert np.asarray(hits).shape == (Q, SP.k)
    with pytest.raises(ValueError, match="store_points"):
        mut.compact()
    fat = MutableHilbertIndex(CFG, buffer_capacity=100)
    fat.insert(data[:500])
    slim_b = mut.memory_report()["segments_bytes"]
    fat_b = fat.memory_report()["segments_bytes"]
    assert slim_b < fat_b  # the raw points are the difference


def test_values_tracking_is_all_or_nothing(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG)
    mut.insert(data[:8], values=np.arange(8))
    with pytest.raises(ValueError, match="values"):
        mut.insert(data[8:16])
    plain = MutableHilbertIndex(CFG)
    plain.insert(data[:8])
    with pytest.raises(ValueError, match="values"):
        plain.insert(data[8:16], values=np.arange(8))
    with pytest.raises(ValueError, match="values"):
        plain.values_at(np.array([0]))


def test_from_index_adoption(dataset):
    data, queries = dataset
    base = HilbertIndex.build(jnp.asarray(data[:500]), CFG)
    mut = MutableHilbertIndex.from_index(base, buffer_capacity=64)
    assert mut.n_live == 500 and mut.n_segments == 1
    new_ids = mut.insert(data[500:550])
    mut.delete(np.arange(10))
    hits, _ = mut.search(queries, SP)
    hits = np.asarray(hits)
    assert not np.isin(hits, np.arange(10)).any()
    assert mut.n_live == 540
    assert (new_ids >= 500).all()


# -- reporting / repr / defaults --------------------------------------------


def test_memory_report_accounts_everything(dataset):
    data, _ = dataset
    mut = MutableHilbertIndex(CFG, buffer_capacity=256)
    mut.insert(data[:600], values=np.arange(600, dtype=np.int32))
    rep = mut.memory_report()
    assert rep["segments_bytes"] == sum(rep["per_segment"])
    assert rep["buffer_bytes"] > 0  # preallocated buffer counted
    assert rep["values_bytes"] == 600 * 4
    assert rep["tombstone_bytes"] == 600
    assert rep["total_bytes"] == (
        rep["segments_bytes"] + rep["buffer_bytes"]
        + rep["values_bytes"] + rep["tombstone_bytes"]
    )
    # segment accounting includes the stored points + codes + sketches
    seg = mut.segments[0]
    seg_rep = seg.index.memory_report()
    assert seg_rep["resident_bytes"] >= (
        seg_rep["points_bytes"] + seg_rep["codes_bytes"]
        + seg_rep["sketch_bytes"] + seg_rep["order_bytes"]
    )


def test_reprs_are_legible(dataset):
    data, _ = dataset
    idx = HilbertIndex.build(jnp.asarray(data[:300]), CFG)
    r = repr(idx)
    assert "n_points=300" in r and "MB" in r and "forest" not in r.lower()
    mut = MutableHilbertIndex(CFG, buffer_capacity=128)
    mut.insert(data[:300])
    mr = repr(mut)
    assert "n_segments=2" in mr and "n_live=300" in mr
    # segment lists print legibly (one short line per segment index)
    assert "n_points=128" in repr(mut.segments)


def test_no_shared_mutable_default_config(dataset):
    """``build(points)`` uses a None sentinel, not a shared default instance."""
    import inspect

    data, _ = dataset
    for fn in (HilbertIndex.build,):
        assert inspect.signature(fn).parameters["config"].default is None
    from repro.index.facade import build_with_timings
    assert (
        inspect.signature(build_with_timings).parameters["config"].default
        is None
    )
    from repro.serve.retrieval import RetrievalStore
    assert (
        inspect.signature(RetrievalStore.build).parameters["config"].default
        is None
    )
    idx = HilbertIndex.build(jnp.asarray(data[:100]))
    assert idx.config == IndexConfig()


# -- serving store -----------------------------------------------------------


def test_retrieval_store_append_delete(tmp_path, dataset):
    from repro.serve.retrieval import RetrievalStore

    data, queries = dataset
    vals = np.arange(1000, dtype=np.int32) % 31
    store = RetrievalStore.build(
        jnp.asarray(data[:1000]), jnp.asarray(vals),
        IndexConfig(forest=CFG.forest), buffer_capacity=256,
    )
    ids1, _ = store.lookup(queries, SP)
    # grow while serving: appended entries are searchable immediately
    new_ids = store.append(
        jnp.asarray(queries), jnp.asarray(np.full(Q, 7, np.int32))
    )
    ids2, d22 = store.lookup(queries, SP)
    assert (np.asarray(ids2)[:, 0] == new_ids).all()  # exact self-match
    assert np.asarray(d22)[:, 0] == pytest.approx(0.0, abs=1e-3)
    assert (np.asarray(store.index.values_at(ids2[:, :1])) == 7).all()
    # shrink while serving
    store.delete(new_ids)
    ids3, _ = store.lookup(queries, SP)
    assert np.array_equal(np.asarray(ids3), np.asarray(ids1))
    # persistence round-trip, then keep appending
    store.compact()
    store.save(str(tmp_path / "rs"))
    loaded = RetrievalStore.load(str(tmp_path / "rs"))
    ids4, _ = loaded.lookup(queries, SP)
    assert np.array_equal(np.asarray(ids4), np.asarray(ids1))
    loaded.append(jnp.asarray(data[:10]), jnp.asarray(vals[:10]))
    assert loaded.index.n_live == 1010
