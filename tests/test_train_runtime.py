"""Training runtime: convergence, microbatching, fault tolerance, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import model
from repro.optim import OptimizerConfig
from repro.sharding import ShardingRules
from repro.train.train_loop import (
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
)

RULES = ShardingRules()
ARCH = "granite_3_8b"  # representative dense smoke config


def _setup(n_micro=1, compression="none", steps_total=50):
    cfg = configs.get_config(ARCH, smoke=True)
    tcfg = TrainConfig(
        n_microbatches=n_micro,
        optimizer=OptimizerConfig(
            lr=3e-3, warmup_steps=5, total_steps=steps_total, compression=compression
        ),
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, global_batch=8, seq_len=32, seed=0)
    pipe = TokenPipeline(dcfg)
    state = init_train_state(cfg, tcfg, jax.random.key(0))
    step_fn = jax.jit(make_train_step(cfg, tcfg, RULES))
    return cfg, tcfg, pipe, state, step_fn


def test_loss_decreases():
    cfg, tcfg, pipe, state, step_fn = _setup()
    losses = []
    for s in range(30):
        state, m = step_fn(state, pipe.jax_batch(s))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:5] + losses[-5:]


def test_microbatch_accumulation_matches_full_batch():
    cfg, _, pipe, state, _ = _setup()
    tcfg1 = TrainConfig(n_microbatches=1, optimizer=OptimizerConfig(lr=1e-3))
    tcfg4 = TrainConfig(n_microbatches=4, optimizer=OptimizerConfig(lr=1e-3))
    f1 = jax.jit(make_train_step(cfg, tcfg1, RULES))
    f4 = jax.jit(make_train_step(cfg, tcfg4, RULES))
    b = pipe.jax_batch(0)
    s1, m1 = f1(dict(state), b)
    s4, m4 = f4(dict(state), b)
    # same data -> losses agree; grads close (fp32 accumulate) -> params close
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    a = jax.tree.leaves(s1["params"])
    bvs = jax.tree.leaves(s4["params"])
    for x, y in zip(a, bvs):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=2e-3, atol=2e-4
        )


def test_checkpoint_restart_bitexact(tmp_path):
    """Crash at step 10, restore, continue -> identical trajectory."""
    ckdir = str(tmp_path / "ck")
    cfg, tcfg, pipe, state, step_fn = _setup()

    ref_losses = []
    for s in range(20):
        state, m = step_fn(state, pipe.jax_batch(s))
        ref_losses.append(float(m["loss"]))
        if s == 9:
            save(ckdir, 10, state)

    # "crash" -> fresh process state; discover + restore latest
    assert latest_step(ckdir) == 10
    abstract = abstract_train_state(cfg, tcfg)
    restored, manifest = restore(ckdir, 10, abstract)
    assert manifest["step"] == 10
    losses2 = []
    st = restored
    for s in range(10, 20):
        st, m = step_fn(st, pipe.jax_batch(s))
        losses2.append(float(m["loss"]))
    np.testing.assert_allclose(losses2, ref_losses[10:], rtol=0, atol=0)


def test_atomic_save_ignores_partial(tmp_path):
    ckdir = str(tmp_path / "ck")
    cfg, tcfg, pipe, state, step_fn = _setup()
    save(ckdir, 5, {"x": jnp.ones((3,))})
    # simulate a crash mid-write: stale .tmp dir must be invisible
    os.makedirs(os.path.join(ckdir, "step_00000007.tmp"))
    assert latest_step(ckdir) == 5


def test_async_checkpointer(tmp_path):
    ckdir = str(tmp_path / "ck")
    ck = AsyncCheckpointer(ckdir)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    ck.save(3, tree)
    ck.wait()
    assert latest_step(ckdir) == 3
    got, _ = restore(ckdir, 3, jax.eval_shape(lambda: tree))
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5))


def test_compression_still_converges():
    for comp in ("bf16", "topk"):
        cfg, tcfg, pipe, state, step_fn = _setup(compression=comp)
        losses = []
        for s in range(25):
            state, m = step_fn(state, pipe.jax_batch(s))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), comp
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, (comp, losses)


def test_moment_dtype_bf16_state_is_bf16():
    cfg = configs.get_config(ARCH, smoke=True)
    tcfg = TrainConfig(optimizer=OptimizerConfig(moment_dtype="bfloat16"))
    st = init_train_state(cfg, tcfg, jax.random.key(0))
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(st["opt"]["m"]))


def test_pipeline_deterministic_and_host_sharded():
    dcfg = DataConfig(vocab_size=977, global_batch=8, seq_len=16, seed=3)
    p1, p2 = TokenPipeline(dcfg), TokenPipeline(dcfg)
    b1, b2 = p1.batch_at(7), p2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(8)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
