"""Guarded hypothesis import shared by the property-test modules.

``hypothesis`` is a dev-only dependency (the ``[dev]`` extra).  When absent,
these stand-ins make ``@given``-decorated tests collect as skips while the
example-based tests in the same modules still run — so the tier-1 suite
collects everywhere.  Usage::

    from _hypothesis_compat import given, settings, st

(``tests/`` is on sys.path via pytest's rootdir insertion; there is no
``tests/__init__.py`` on purpose.)
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
