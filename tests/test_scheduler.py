"""Continuous-batching engine: ragged requests, correctness vs sequential."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model
from repro.serve.scheduler import ContinuousBatchingEngine, Request
from repro.sharding import ShardingRules

RULES = ShardingRules()


def _setup():
    cfg = dataclasses.replace(
        configs.get_config("granite_3_8b", smoke=True), compute_dtype="float32")
    params = model.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_engine_matches_sequential_decode():
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32)
               for L in (9, 5, 12, 7, 10)]  # ragged; more requests than slots

    eng = ContinuousBatchingEngine(cfg, params, RULES, n_slots=2, max_seq=32)
    for uid, p in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    done = eng.run()
    assert len(done) == len(prompts)
    assert all(len(r.output) == 7 for r in done.values())  # prefill tok + 6

    # oracle: sequential greedy decode per request
    for uid, p in enumerate(prompts):
        toks = jnp.asarray(p[None, :], jnp.int32)
        logits, caches = model.prefill(cfg, params, toks, RULES)
        caches = model.pad_caches(cfg, caches, 32)
        out = [int(jnp.argmax(logits[0]))]
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        for t in range(len(p), len(p) + 6):
            lg, caches = model.decode_step(cfg, params, tok, jnp.int32(t),
                                           caches, RULES)
            out.append(int(jnp.argmax(lg[0])))
            tok = jnp.asarray([[out[-1]]], jnp.int32)
        assert done[uid].output == out, (uid, done[uid].output, out)


def test_engine_eos_and_refill():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    # force quick termination via eos on whatever token comes first
    p0 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    eng = ContinuousBatchingEngine(cfg, params, RULES, n_slots=1, max_seq=32)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=p0, max_new_tokens=3))
    done = eng.run()
    assert len(done) == 3
    # single slot processed all three sequentially via refill
    assert eng.active == 0
