"""Fault-tolerance tier-1 tests: WAL framing, self-verifying checkpoints,
the fault-injection harness, bit-equal crash recovery, and the engine's
degraded/deadline behavior.  The exhaustive subprocess crash matrix lives
in ``scripts/crash_check.py`` (CI fault-tolerance job); this module keeps
a representative kill subset plus the in-process invariants."""

import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import checkpoint
from repro.checkpoint import WalConfig
from repro.checkpoint import wal as wal_lib
from repro.core.types import ForestConfig, SearchParams
from repro.index import IndexConfig, MutableHilbertIndex
from repro.testing import faults

FCFG = ForestConfig(n_trees=4, bits=4, key_bits=32, leaf_size=16)
CFG = IndexConfig(forest=FCFG)
PARAMS = SearchParams(k1=16, k2=32, h=1, k=8)
DIM = 8

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CRASH_CHECK = os.path.join(REPO, "scripts", "crash_check.py")


def _rows(seed, m):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(m, DIM)).astype(np.float32),
            rng.integers(0, 100, size=(m,)).astype(np.int32))


@pytest.fixture(autouse=True)
def _no_fault_plan():
    faults.reset()
    yield
    faults.reset()


# ---------------------------------------------------------------- framing


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "wal.log")
    w = wal_lib.WriteAheadLog(path, WalConfig(sync_every=2))
    pts, vals = _rows(0, 5)
    s1 = w.append("insert", {"points": pts, "values": vals}, {"next_id": 0})
    s2 = w.append("delete", {"ids": np.arange(3, dtype=np.int32)},
                  {"next_id": 5})
    w.close()
    records, _, torn = wal_lib.read_records(path)
    assert not torn and [r.seq for r in records] == [s1, s2]
    assert records[0].op == "insert" and records[1].op == "delete"
    np.testing.assert_array_equal(records[0].arrays["points"], pts)
    np.testing.assert_array_equal(records[0].arrays["values"], vals)
    assert records[0].meta == {"next_id": 0}
    assert records[1].meta == {"next_id": 5}


def _one_record_file(tmp_path) -> str:
    path = str(tmp_path / "wal.log")
    w = wal_lib.WriteAheadLog(path)
    pts, vals = _rows(1, 4)
    w.append("insert", {"points": pts, "values": vals}, {"next_id": 0})
    w.close()
    return path


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_wal_any_single_bitflip_rejected(tmp_path_factory, data):
    """CRC framing rejects a flip of ANY single bit — including the seq
    field (covered by seeding the CRC with it)."""
    tmp_path = tmp_path_factory.mktemp("wal_flip")
    path = _one_record_file(tmp_path)
    size = os.path.getsize(path)
    bit = data.draw(st.integers(min_value=0, max_value=size * 8 - 1))
    with open(path, "r+b") as f:
        f.seek(bit // 8)
        b = f.read(1)
        f.seek(bit // 8)
        f.write(bytes([b[0] ^ (1 << (bit % 8))]))
    try:
        records, _, torn = wal_lib.read_records(path)
    except wal_lib.WalError:
        return                                # flip landed in the magic
    assert records == [] and torn


def test_wal_bitflip_rejected_fixed_positions(tmp_path):
    """Non-hypothesis smoke of the same property at a few offsets."""
    for frac in (0.1, 0.3, 0.5, 0.9):
        path = _one_record_file(tmp_path)
        size = os.path.getsize(path)
        pos = max(8, min(size - 1, int(frac * size)))  # past the magic
        with open(path, "r+b") as f:
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0x01]))
        records, _, torn = wal_lib.read_records(path)
        assert records == [] and torn
        os.remove(path)


def test_wal_torn_tail_truncated_and_seq_continues(tmp_path):
    path = str(tmp_path / "wal.log")
    w = wal_lib.WriteAheadLog(path)
    pts, vals = _rows(2, 3)
    w.append("insert", {"points": pts}, {"next_id": 0})
    s2 = w.append("insert", {"points": pts}, {"next_id": 3})
    w.close()
    good = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b"\xff\x00\x00\x00torn-partial-frame")
    records, wal = wal_lib.open_and_recover(path)
    assert [r.seq for r in records] == [s2 - 1, s2]
    assert os.path.getsize(path) == good      # torn tail truncated
    s3 = wal.append("delete", {"ids": np.zeros(1, np.int32)}, {"next_id": 6})
    wal.close()
    assert s3 == s2 + 1                       # numbering continues


# ----------------------------------------------------- checkpoint digests


def test_checkpoint_bitflip_detected_quarantined_fallback(tmp_path):
    ckpt = str(tmp_path / "bundle")
    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(64, 32)).astype(np.float32)}
    checkpoint.save(ckpt, step=0, tree=tree, extra={})
    checkpoint.save(ckpt, step=1, tree=tree, extra={})
    assert checkpoint.verify_step(ckpt, 1) == []
    npz = os.path.join(ckpt, "step_00000001", "host0.npz")
    size = os.path.getsize(npz)
    with open(npz, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0x04]))
    assert checkpoint.verify_step(ckpt, 1)    # detected
    with pytest.raises(checkpoint.CorruptBundleError):
        checkpoint.restore(ckpt, 1, tree)
    # restore quarantined the rotten bundle; resolution falls back
    assert checkpoint.latest_step(ckpt) == 0
    assert checkpoint.latest_verifiable_step(ckpt) == 0
    assert os.path.isdir(
        os.path.join(ckpt, "step_00000001.quarantine")
    )
    restored, _ = checkpoint.restore(ckpt, 0, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


# ------------------------------------------------------------ fault plans


def test_fault_plan_parse_and_raise(tmp_path):
    plan = faults.parse_plan("a.b@3=kill; c.d=raise;e.f=torn:7;g=bitflip")
    assert plan == {"a.b": (3, "kill"), "c.d": (1, "raise"),
                    "e.f": (1, "torn:7"), "g": (1, "bitflip")}
    with pytest.raises(ValueError):
        faults.parse_plan("x=explode")
    trace = str(tmp_path / "trace.txt")
    faults.install_plan({"p.q": (2, "raise")}, trace_path=trace)
    faults.fault_point("p.q")                 # hit 1: armed for hit 2
    with pytest.raises(faults.FaultInjected):
        faults.fault_point("p.q")
    faults.reset()
    faults.fault_point("p.q")                 # disarmed: no-op
    with open(trace) as f:
        assert f.read().splitlines() == ["p.q", "p.q"]
    assert faults.registered_points() == {}


# --------------------------------------------- WAL recovery (in-process)


def _churned_index(path, *, save_midway=True):
    idx = MutableHilbertIndex(CFG, buffer_capacity=16, max_segments=4)
    idx.enable_wal(path, WalConfig(sync_every=4))
    pts, vals = _rows(10, 40)
    idx.insert(pts, vals)
    idx.delete(np.asarray([1, 17, 33], np.int32))
    if save_midway:
        idx.save(path)
    pts2, vals2 = _rows(11, 21)
    idx.insert(pts2, vals2)
    idx.delete(np.asarray([0, 45], np.int32))
    return idx


def test_mutable_wal_recovery_bit_equal(tmp_path):
    """Reload after an unflushed tail == the index that never went down."""
    path = str(tmp_path / "ckpt")
    live = _churned_index(path)
    live.wal.sync()
    rec = MutableHilbertIndex.load(path)
    assert rec._lsm.next_id == live._lsm.next_id
    np.testing.assert_array_equal(rec._lsm.alive, live._lsm.alive)
    np.testing.assert_array_equal(rec._lsm.values, live._lsm.values)
    q = np.random.default_rng(3).normal(size=(8, DIM)).astype(np.float32)
    ids_a, d_a = (np.asarray(x) for x in live.search(q, PARAMS))
    ids_b, d_b = (np.asarray(x) for x in rec.search(q, PARAMS))
    np.testing.assert_array_equal(ids_a, ids_b)
    assert d_a.tobytes() == d_b.tobytes()


def test_save_truncates_wal_and_load_recovers_writes_after(tmp_path):
    path = str(tmp_path / "ckpt")
    idx = _churned_index(path, save_midway=False)
    idx.save(path)
    records, _, _ = wal_lib.read_records(wal_lib.wal_path(path))
    assert records == []                      # truncated at the commit point
    pts, vals = _rows(12, 5)
    idx.insert(pts, vals)                     # post-save tail
    rec = MutableHilbertIndex.load(path)
    assert rec._lsm.next_id == idx._lsm.next_id


def test_mutations_after_load_work(tmp_path):
    """Regression: restored state must be writable (device_get hands back
    read-only views) — post-restore deletes/replays mutate it in place."""
    path = str(tmp_path / "ckpt")
    idx = MutableHilbertIndex(CFG, buffer_capacity=16)
    pts, vals = _rows(13, 30)
    idx.insert(pts, vals)
    idx.save(path)
    rec = MutableHilbertIndex.load(path)
    assert rec.delete(np.asarray([4, 9], np.int32)) == 2
    rec.insert(*_rows(14, 3))


def test_degrade_sharded_to_mutable_replays_wal(tmp_path):
    import jax

    from repro.index import (
        ShardedMutableHilbertIndex,
        load_sharded_mutable_as_mutable,
    )

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (sharded facade)")
    path = str(tmp_path / "ckpt")
    pts, vals = _rows(15, 64)
    idx = ShardedMutableHilbertIndex.build(
        pts, CFG, values=vals, buffer_capacity=8, max_segments=4
    )
    idx.enable_wal(path, WalConfig(sync_every=1))
    idx.save(path)
    idx.insert(*_rows(16, 5))                 # unflushed WAL tail
    mut = load_sharded_mutable_as_mutable(path)
    assert mut._lsm.next_id == idx._lsm.next_id
    np.testing.assert_array_equal(mut._lsm.values, idx._lsm.values)


# ------------------------------------------------------------- pow2 seals


def test_seal_pow2_pads_flush_and_compact_unpads():
    cfg = IndexConfig(forest=FCFG, seal_pow2=True)
    idx = MutableHilbertIndex(cfg, buffer_capacity=24, max_segments=8)
    pts, vals = _rows(20, 24)                 # one exact flush of 24 rows
    ids = idx.insert(pts, vals)
    seg = idx.segments[0]
    assert seg.n_real == 24 and seg.n_points == 32       # pow2-padded
    q = pts[:6]
    got, _ = idx.search(q, PARAMS)
    got = np.asarray(got)
    assert (got[:, 0] == ids[:6]).all()       # self-NN despite padding
    for row in got:                           # padding never duplicates ids
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid)
    idx.compact()
    assert idx.segments[0].n_pad == 0         # compaction builds exact


# ----------------------------------------------------- engine resilience


def test_engine_deadline_expired_dropped_before_dispatch():
    import time

    from repro.serve.engine import DeadlineExceeded, RetrievalEngine

    idx = MutableHilbertIndex(CFG, buffer_capacity=32)
    idx.insert(*_rows(21, 20))
    eng = RetrievalEngine(idx, PARAMS, maintenance=None, start=False)
    q = np.random.default_rng(5).normal(size=(4, DIM)).astype(np.float32)
    ticket = eng.submit(q, deadline_ms=1.0)
    time.sleep(0.02)
    assert eng.step() == 0                    # expired: nothing dispatched
    with pytest.raises(DeadlineExceeded):
        ticket.result(timeout=0)
    assert eng.metrics.snapshot()["counters"]["deadline_expired"] == 1
    ok = eng.submit(q)                        # no deadline: serves normally
    assert eng.step() == 1 and ok.result(0)[0].shape == (4, PARAMS.k)


def test_engine_enters_degraded_on_wal_failure(tmp_path):
    from repro.serve.engine import EngineDegraded, RetrievalEngine

    idx = MutableHilbertIndex(CFG, buffer_capacity=32)
    idx.enable_wal(str(tmp_path / "ckpt"), WalConfig(sync_every=1))
    idx.insert(*_rows(22, 20))
    eng = RetrievalEngine(idx, PARAMS, maintenance=None, start=False)
    faults.install_plan({"wal.append.pre_write": (1, "raise")})
    with pytest.raises(EngineDegraded):
        eng.insert(*_rows(23, 4))
    faults.reset()
    assert eng.degraded and "fault injected" in eng.degraded_reason
    with pytest.raises(EngineDegraded):       # fail-fast, no index touch
        eng.delete(np.asarray([0], np.int32))
    q = np.random.default_rng(6).normal(size=(2, DIM)).astype(np.float32)
    ids, _ = eng.search(q)                    # reads keep serving
    assert ids.shape == (2, PARAMS.k)
    eng.reset_degraded()
    eng.insert(*_rows(23, 4))                 # healthy again
    c = eng.metrics.snapshot()["counters"]
    assert c["degraded_entered"] == 1 and c["writes_rejected_degraded"] == 1


# ------------------------------------------- subprocess crash-kill subset


def _crash_env(**extra):
    env = dict(os.environ)
    env.pop("REPRO_FAULTS", None)
    env.pop("REPRO_FAULT_TRACE", None)
    env["JAX_PLATFORMS"] = "cpu"
    env.update(extra)
    return env


@pytest.mark.parametrize("point,hit", [
    ("wal.append.post_write", 5),   # mid-stream append, record in flight
    ("ckpt.json.pre_rename", 1),    # first manifest commit torn away
    ("wal.truncate.pre", 1),        # between commit and WAL truncate
])
def test_crash_kill_then_bit_equal_recovery(tmp_path, point, hit):
    """SIGKILL the workload child at a registered fault point; a fresh
    process must recover bit-equal with zero acknowledged-write loss
    (full matrix: ``scripts/crash_check.py``)."""
    wd = str(tmp_path / "crash")
    os.makedirs(wd)
    cmd = [sys.executable, CRASH_CHECK, "--child", "run",
           "--scenario", "mutable", "--workdir", wd]
    r = subprocess.run(
        cmd, env=_crash_env(REPRO_FAULTS=f"{point}@{hit}=kill"),
        capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == -signal.SIGKILL, r.stdout + r.stderr
    v = subprocess.run(
        [sys.executable, CRASH_CHECK, "--child", "verify",
         "--scenario", "mutable", "--workdir", wd],
        env=_crash_env(), capture_output=True, text=True, timeout=300,
    )
    assert v.returncode == 0, v.stdout + v.stderr
    assert "VERIFIED" in v.stdout


def test_crash_kill_sharded_recovery(tmp_path):
    wd = str(tmp_path / "crash")
    os.makedirs(wd)
    env = _crash_env(
        REPRO_FAULTS="wal.append.post_write@3=kill",
        XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                   + " --xla_force_host_platform_device_count=8").strip(),
    )
    cmd = [sys.executable, CRASH_CHECK, "--child", "run",
           "--scenario", "sharded", "--workdir", wd]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=300)
    assert r.returncode == -signal.SIGKILL, r.stdout + r.stderr
    env.pop("REPRO_FAULTS")
    v = subprocess.run(
        [sys.executable, CRASH_CHECK, "--child", "verify",
         "--scenario", "sharded", "--workdir", wd],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert v.returncode == 0, v.stdout + v.stderr
    assert "VERIFIED" in v.stdout


def test_acks_ledger_written_fsynced(tmp_path):
    """The battery's zero-loss argument rests on the ack ledger being
    durable before the next op; sanity-check the helper used there."""
    sys.path.insert(0, os.path.dirname(CRASH_CHECK))
    try:
        import crash_check
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "acks.jsonl")
    crash_check._ack(path, 0)
    crash_check._ack(path, 1)
    with open(path) as f:
        assert [json.loads(x)["i"] for x in f] == [0, 1]
