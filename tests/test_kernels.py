"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per the brief: sweep shapes/dtypes for each kernel and assert_allclose
against the ref.py oracle.  Interpret mode executes the kernel body in
Python on CPU — same program the Mosaic compiler would lower on TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # guarded dev-only import

from repro.core import quantize
from repro.kernels.hamming import hamming_matrix, hamming_matrix_ref
from repro.kernels.qdist import (
    qdist,
    qdist_from_packed,
    qdist_windows_from_packed,
)
from repro.kernels.qdist.ref import qdist_u8_ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# hamming
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "q,c,w",
    [
        (1, 1, 1),
        (7, 129, 12),      # non-multiples force padding
        (128, 128, 12),    # exact single tile
        (130, 257, 16),    # multi-tile + ragged edge
        (64, 512, 3),
    ],
)
def test_hamming_kernel_matches_ref(q, c, w):
    a = jnp.asarray(RNG.integers(0, 2**32, size=(q, w), dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, size=(c, w), dtype=np.uint32))
    got = hamming_matrix(a, b, use_kernel=True, interpret=True)
    ref = hamming_matrix_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_hamming_known_values():
    a = jnp.asarray(np.array([[0x0, 0xFFFFFFFF]], np.uint32))
    b = jnp.asarray(np.array([[0x0, 0xFFFFFFFF], [0xF, 0xFFFFFFFF], [0x0, 0x0]], np.uint32))
    got = np.asarray(hamming_matrix(a, b, use_kernel=True, interpret=True))
    np.testing.assert_array_equal(got, [[0, 4, 32]])


@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 40),
    c=st.integers(1, 160),
    w=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_hamming_kernel_property(q, c, w, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 2**32, size=(q, w), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, size=(c, w), dtype=np.uint32))
    got = np.asarray(hamming_matrix(a, b, use_kernel=True, interpret=True))
    ref = np.asarray(hamming_matrix_ref(a, b))
    np.testing.assert_array_equal(got, ref)
    # metric properties: symmetry on identical args, range
    assert got.min() >= 0 and got.max() <= 32 * w


# ---------------------------------------------------------------------------
# qdist
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "q,c,d",
    [
        (1, 1, 8),
        (5, 200, 48),
        (128, 128, 384),   # paper shape, exact tiles
        (130, 300, 384),
        (16, 64, 100),     # d not multiple of 8/128
    ],
)
def test_qdist_u8_kernel_matches_ref(q, c, d):
    data = RNG.normal(size=(c, d)).astype(np.float32)
    queries = jnp.asarray(RNG.normal(size=(q, d)).astype(np.float32))
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    got = qdist(queries, codes, quant.centroids, use_kernel=True, interpret=True)
    ref = qdist_u8_ref(queries, codes, quant.centroids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,c,d", [(4, 100, 48), (128, 128, 384), (9, 257, 128)])
def test_qdist_packed_kernel_matches_ref(q, c, d):
    data = RNG.normal(size=(c, d)).astype(np.float32)
    queries = jnp.asarray(RNG.normal(size=(q, d)).astype(np.float32))
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    packed = quantize.pack_codes(codes)
    got = qdist_from_packed(
        queries, packed, quant.centroids, d=d, use_kernel=True, interpret=True
    )
    ref = qdist_u8_ref(queries, codes, quant.centroids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("q,c,d", [(1, 1, 8), (3, 100, 48), (9, 130, 384), (5, 260, 128)])
def test_qdist_windows_kernel_matches_ref(q, c, d):
    """Per-query candidate sets (Q, C, W) — the fused stage-2 shape."""
    data = RNG.normal(size=(q * c, d)).astype(np.float32)
    queries = jnp.asarray(RNG.normal(size=(q, d)).astype(np.float32))
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    windows = jax.vmap(quantize.pack_codes)(codes.reshape(q, c, d))
    got = qdist_windows_from_packed(
        queries, windows, quant.centroids, d=d, use_kernel=True, interpret=True
    )
    per_query_ref = [
        np.asarray(
            qdist_u8_ref(queries[i : i + 1], codes.reshape(q, c, d)[i], quant.centroids)
        )[0]
        for i in range(q)
    ]
    np.testing.assert_allclose(
        np.asarray(got), np.stack(per_query_ref), rtol=1e-5, atol=1e-5
    )


def test_qdist_zero_distance_to_self_centroids():
    """A query equal to a reconstructed vector has (near-)zero distance."""
    d = 64
    data = RNG.normal(size=(32, d)).astype(np.float32)
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    recon = quantize.decode(quant, codes)
    got = np.asarray(
        qdist(recon, codes, quant.centroids, use_kernel=True, interpret=True)
    )
    np.testing.assert_allclose(np.diag(got), 0.0, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    q=st.integers(1, 16),
    c=st.integers(1, 64),
    d=st.sampled_from([8, 16, 48, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qdist_property_nonneg_and_exact(q, c, d, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(c, d)).astype(np.float32)
    queries = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    quant = quantize.fit(jnp.asarray(data), bits=4)
    codes = quantize.encode(quant, jnp.asarray(data))
    got = np.asarray(
        qdist(queries, codes, quant.centroids, use_kernel=True, interpret=True)
    )
    ref = np.asarray(qdist_u8_ref(queries, codes, quant.centroids))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert (got > -1e-4).all()


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

from repro.kernels.bitpack import pack_bits, pack_bits_ref  # noqa: E402


@pytest.mark.parametrize("n,k", [(1, 32), (7, 100), (256, 128), (300, 448), (64, 31)])
def test_bitpack_kernel_matches_ref(n, k):
    bits = jnp.asarray(RNG.integers(0, 2, size=(n, k), dtype=np.uint8))
    got = pack_bits(bits, use_kernel=True, interpret=True)
    ref = pack_bits(bits, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bitpack_msb_first():
    bits = jnp.zeros((1, 32), jnp.uint8).at[0, 0].set(1)
    out = np.asarray(pack_bits(bits, use_kernel=True, interpret=True))
    assert out[0, 0] == 1 << 31


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 80), k=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_bitpack_property(n, k, seed):
    rng = np.random.default_rng(seed)
    bits = jnp.asarray(rng.integers(0, 2, size=(n, k), dtype=np.uint8))
    got = np.asarray(pack_bits(bits, use_kernel=True, interpret=True))
    ref = np.asarray(pack_bits_ref(jnp.asarray(np.pad(
        np.asarray(bits), ((0, 0), (0, (-k) % 32))))))[:, : -(-k // 32)]
    np.testing.assert_array_equal(got, ref)
