"""CLI driver smoke tests: train / serve entrypoints run end-to-end."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=ROOT,
    )


def test_train_driver_runs_and_resumes(tmp_path):
    ck = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "granite_3_8b", "--smoke",
              "--steps", "25", "--batch", "4", "--seq", "32",
              "--ckpt", ck, "--ckpt-every", "10"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[done]" in r.stdout
    # resume run picks up the latest checkpoint
    r2 = _run(["repro.launch.train", "--arch", "granite_3_8b", "--smoke",
               "--steps", "30", "--batch", "4", "--seq", "32",
               "--ckpt", ck])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step" in r2.stdout


def test_serve_driver_decodes(tmp_path):
    r = _run(["repro.launch.serve", "--arch", "gemma3_1b", "--smoke",
              "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[decode]" in r.stdout


def test_serve_driver_with_retrieval(tmp_path):
    r = _run(["repro.launch.serve", "--arch", "granite_3_8b", "--smoke",
              "--batch", "2", "--prompt-len", "16", "--gen", "3",
              "--retrieval"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[retrieval] datastore" in r.stdout
    assert "[decode]" in r.stdout
