"""Hilbert sort correctness: oracle + structural properties.

The defining property of a Hilbert curve on the full b-bit grid: sorting all
grid cells by Hilbert index yields a Hamiltonian path where consecutive cells
differ by exactly 1 in exactly one axis.  We assert that for d in {2, 3} and
several depths — a complete, oracle-free characterization of the curve.
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hilbert


def _full_grid(d, bits):
    pts = np.array(list(itertools.product(range(1 << bits), repeat=d)), np.float64)
    return pts


@pytest.mark.parametrize("d,bits", [(2, 1), (2, 2), (2, 3), (3, 1), (3, 2)])
def test_full_grid_is_hamiltonian_path(d, bits):
    pts = _full_grid(d, bits)
    lo = jnp.zeros((d,))
    hi = jnp.full((d,), float((1 << bits) - 1))
    order, _ = hilbert.hilbert_sort(
        jnp.asarray(pts), bits=bits, key_bits=d * bits, lo=lo, hi=hi
    )
    walk = pts[np.asarray(order)]
    steps = np.abs(np.diff(walk, axis=0))
    # each consecutive pair differs by exactly 1 in exactly one coordinate
    assert np.all(steps.sum(axis=1) == 1), "not a unit-step walk"
    assert np.all(steps.max(axis=1) == 1)
    # visits every cell exactly once
    assert len(np.unique(np.asarray(order))) == len(pts)


@pytest.mark.parametrize("d,bits,key_bits", [(2, 2, 4), (3, 2, 6), (16, 2, 32),
                                             (48, 4, 192)])
def test_hilbert_keys_jit_matches_eager(d, bits, key_bits):
    """jitted keys == op-by-op keys.

    Regression test for an XLA:CPU miscompile: ``lax.associative_scan``
    (the Gray-encode prefix-XOR) fused with ``_level_pass`` produced
    colliding, non-Hamiltonian keys at d=2, bits=2 under jit only — the
    seed-era ``test_full_grid_is_hamiltonian_path[2-2]`` failure.  Fixed by
    the Hillis-Steele ``_prefix_xor`` formulation.
    """
    rng = np.random.default_rng(7)
    pts = jnp.asarray(rng.normal(size=(257, d)).astype(np.float32))
    lo = jnp.full((d,), -4.0)
    hi = jnp.full((d,), 4.0)
    with jax.disable_jit():
        ref = np.asarray(
            hilbert.hilbert_keys(pts, bits=bits, key_bits=key_bits, lo=lo, hi=hi)
        )
    got = np.asarray(
        hilbert.hilbert_keys(pts, bits=bits, key_bits=key_bits, lo=lo, hi=hi)
    )
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("d,bits", [(2, 4), (5, 3), (16, 2), (48, 4)])
def test_transpose_roundtrip(d, bits):
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 1 << bits, size=(257, d)).astype(np.uint32)
    tr = hilbert.axes_to_transpose(jnp.asarray(coords), bits)
    back = hilbert.transpose_to_axes(tr, bits)
    np.testing.assert_array_equal(np.asarray(back), coords)


def test_truncated_key_prefix_consistency():
    """Sorting by a longer key refines (never contradicts) a shorter key."""
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.normal(size=(512, 8)).astype(np.float32))
    lo = jnp.full((8,), -4.0)
    hi = jnp.full((8,), 4.0)
    k_short = hilbert.hilbert_keys(pts, bits=6, key_bits=32, lo=lo, hi=hi)
    k_long = hilbert.hilbert_keys(pts, bits=6, key_bits=48, lo=lo, hi=hi)
    # first word identical
    np.testing.assert_array_equal(np.asarray(k_short[:, 0]), np.asarray(k_long[:, 0]))


def test_lex_searchsorted_matches_numpy_bigint():
    rng = np.random.default_rng(2)
    m, q, w = 1000, 128, 3
    sorted_np = rng.integers(0, 2**32, size=(m, w), dtype=np.uint32)
    as_int = [tuple(int(x) for x in row) for row in sorted_np]
    as_int.sort()
    sorted_np = np.array(as_int, dtype=np.uint32)
    queries = rng.integers(0, 2**32, size=(q, w), dtype=np.uint32)
    # include exact hits
    queries[:10] = sorted_np[rng.integers(0, m, 10)]
    got = np.asarray(
        hilbert.lex_searchsorted(jnp.asarray(sorted_np), jnp.asarray(queries))
    )
    ref = np.searchsorted(
        np.array([int.from_bytes(r.tobytes(), "little") for r in sorted_np[:, ::-1]]),
        np.array([int.from_bytes(r.tobytes(), "little") for r in queries[:, ::-1]]),
        side="left",
    )
    np.testing.assert_array_equal(got, ref)


def test_locality_better_than_random():
    """Hilbert-order neighbors are closer in L2 than random pairs (on average)."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(4096, 16)).astype(np.float32)
    lo = jnp.full((16,), float(pts.min()))
    hi = jnp.full((16,), float(pts.max()))
    order, _ = hilbert.hilbert_sort(
        jnp.asarray(pts), bits=8, key_bits=128, lo=lo, hi=hi
    )
    walk = pts[np.asarray(order)]
    adj = np.linalg.norm(np.diff(walk, axis=0), axis=1).mean()
    perm = rng.permutation(4096)
    rand = np.linalg.norm(np.diff(pts[perm], axis=0), axis=1).mean()
    # In d=16 the NN-distance floor is ~2.7 and random pairs ~5.6; a single
    # Hilbert order lands in between (~4.2) — partial locality is exactly why
    # the paper uses a *forest* of orders.  Assert a clear locality signal.
    assert adj < 0.8 * rand, (adj, rand)


def test_perm_and_flip_change_order_but_not_set():
    rng = np.random.default_rng(4)
    pts = jnp.asarray(rng.normal(size=(512, 12)).astype(np.float32))
    lo = jnp.full((12,), -4.0)
    hi = jnp.full((12,), 4.0)
    o1, _ = hilbert.hilbert_sort(pts, bits=6, key_bits=64, lo=lo, hi=hi)
    perm = jnp.asarray(rng.permutation(12).astype(np.int32))
    flip = jnp.asarray(rng.integers(0, 2, 12).astype(bool))
    o2, _ = hilbert.hilbert_sort(
        pts, bits=6, key_bits=64, lo=lo, hi=hi, perm=perm, flip=flip
    )
    assert not np.array_equal(np.asarray(o1), np.asarray(o2))
    assert sorted(np.asarray(o2).tolist()) == list(range(512))
